"""CapStore core: analysis invariants, energy-model properties, DSE
orderings (the paper's qualitative claims), PMU schedule correctness."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import analysis, dse, energy as E
from repro.core.pmu import PhaseRequirement, build_schedule
from repro.core.planner import (CAPSNET_WORKLOADS, MatmulWorkload,
                                VMEM_BYTES, plan_matmul)

PROFILES = analysis.capsnet_profiles()
ORGS = dse.design_organizations(PROFILES)
EVALS = {n: dse.evaluate(o, PROFILES) for n, o in ORGS.items()}


# ---------------------------------------------------------------------------
# Fig. 4 analysis invariants
# ---------------------------------------------------------------------------

def test_five_operations():
    assert [p.name for p in PROFILES] == [
        "Conv1", "PrimaryCaps", "ClassCaps-FC", "Sum+Squash", "Update+Sum"]


def test_primarycaps_is_peak_footprint():
    peak = max(PROFILES, key=lambda p: p.total_mem)
    assert peak.name == "PrimaryCaps"          # paper Fig. 4a


def test_accumulator_dominates_every_operation():
    for p in PROFILES:                          # paper Sec. 3.1
        assert p.accum_mem >= p.data_mem
        assert p.accum_mem >= p.weight_mem


def test_routing_ops_have_no_offchip_traffic():
    for p in PROFILES[3:]:                      # paper Sec. 3.1 / Eq. (1,2)
        assert p.offchip_reads == 0 and p.offchip_writes == 0


def test_offchip_equations():
    # Eq. (1): off-chip reads of op i = on-chip fills of op i.
    for p in PROFILES[:3]:
        assert p.offchip_reads == p.weight_writes + p.data_writes
    # Eq. (2): off-chip writes of op i = data fills of op i+1.
    assert PROFILES[0].offchip_writes == PROFILES[1].data_writes
    assert PROFILES[1].offchip_writes == PROFILES[2].data_writes


def test_primarycaps_dominates_cycles():
    pc = PROFILES[1]
    assert pc.total_cycles > 0.5 * analysis.total_cycles(PROFILES)


def test_macs_match_capsnet_shapes():
    assert PROFILES[0].macs == 20 * 20 * 256 * 81          # Conv1
    assert PROFILES[1].macs == 36 * 256 * 81 * 256         # PrimaryCaps
    assert PROFILES[2].macs == 1152 * 10 * 16 * 8          # votes
    assert PROFILES[3].macs == 1152 * 10 * 16              # Sum
    assert PROFILES[3].repeats == 3                        # routing iters


def test_weight_reuse_ordering():
    # Convs reuse weights (tiny weight mem); ClassCaps-FC cannot.
    assert PROFILES[1].weight_mem < PROFILES[2].weight_mem
    assert PROFILES[0].weight_mem < PROFILES[2].weight_mem


# ---------------------------------------------------------------------------
# Energy model properties
# ---------------------------------------------------------------------------

@given(cap=st.integers(1024, 8 * 2**20), ports=st.integers(1, 3),
       banks=st.sampled_from([1, 4, 8, 16]))
@settings(max_examples=50, deadline=None)
def test_access_energy_positive_and_monotone_in_ports(cap, ports, banks):
    base = E.SRAMConfig("t", cap, ports=1, banks=banks)
    multi = E.SRAMConfig("t", cap, ports=ports, banks=banks)
    assert multi.access_energy_pj() >= base.access_energy_pj() > 0


@given(cap=st.integers(1024, 2**23))
@settings(max_examples=30, deadline=None)
def test_leakage_scales_with_capacity(cap):
    a = E.SRAMConfig("a", cap)
    b = E.SRAMConfig("b", 2 * cap)
    assert b.leakage_mw() == pytest.approx(2 * a.leakage_mw())


@given(frac=st.floats(0.0, 1.0), sectors=st.sampled_from([1, 8, 64, 256]))
@settings(max_examples=50, deadline=None)
def test_power_gating_never_increases_leakage(frac, sectors):
    s = E.SRAMConfig("t", 2**20, power_gated=True, sectors_per_bank=sectors)
    q = s.quantize_on_fraction(frac)
    assert q >= frac - 1e-9              # never gate needed sectors
    assert s.leakage_mw(q) <= s.leakage_mw(1.0) + 1e-12


def test_banking_reduces_access_energy():
    flat = E.SRAMConfig("f", 2**20, banks=1)
    banked = E.SRAMConfig("b", 2**20, banks=16)
    assert banked.access_energy_pj() < flat.access_energy_pj()


# ---------------------------------------------------------------------------
# DSE: the paper's Table 2 orderings and headline claims
# ---------------------------------------------------------------------------

def test_sep_sizes_exceed_smp():
    assert ORGS["SEP"].total_bytes > ORGS["SMP"].total_bytes   # Sec. 5.1


def test_sep_cheaper_than_smp_dynamic():
    assert EVALS["SEP"].dynamic_mj < EVALS["SMP"].dynamic_mj   # Fig. 10c(1)


def test_pg_reduces_static_energy():
    for base in ("SMP", "SEP", "HY"):                          # Fig. 10c(2)
        assert EVALS[f"PG-{base}"].static_mj <= EVALS[base].static_mj


def test_pg_sep_is_best_design():
    best = dse.best_design(PROFILES)
    assert best.org_name == "PG-SEP"                            # Sec. 5.2


def test_pg_benefits_sep_more_than_smp():
    gain_sep = 1 - EVALS["PG-SEP"].total_mj / EVALS["SEP"].total_mj
    gain_smp = 1 - EVALS["PG-SMP"].total_mj / EVALS["SMP"].total_mj
    assert gain_sep > gain_smp                                  # Sec. 5.1


def test_wakeup_overhead_negligible():
    for ev in EVALS.values():                                   # Sec. 5.1
        assert ev.wakeup_mj < 0.01 * max(ev.total_mj, 1e-12)


def test_hierarchy_beats_all_onchip():
    a = dse.all_onchip_system(PROFILES)
    b = dse.hierarchy_system(PROFILES, EVALS["SMP"])
    saving = 1 - b.total_mj / a.total_mj
    assert 0.45 < saving < 0.80                                 # paper: 66%


def test_memory_dominates_total_energy():
    b = dse.hierarchy_system(PROFILES, EVALS["SMP"])
    assert b.memory_fraction > 0.9                              # paper: 96%


def test_pg_sep_onchip_reduction_vs_smp():
    red = 1 - EVALS["PG-SEP"].total_mj / EVALS["SMP"].total_mj
    assert 0.6 < red < 0.95                                     # paper: 86%


def test_complete_accelerator_reduction():
    a = dse.all_onchip_system(PROFILES)
    best = dse.best_design(PROFILES)
    c = dse.hierarchy_system(PROFILES, best.evaluation)
    assert 1 - c.total_mj / a.total_mj > 0.7                    # paper: 78%
    assert 1 - c.total_area_mm2 / a.total_area_mm2 > 0.15       # paper: 25%


def test_hy_between_smp_and_sep():
    assert EVALS["SEP"].total_mj < EVALS["HY"].total_mj < EVALS["SMP"].total_mj


# ---------------------------------------------------------------------------
# PMU schedule
# ---------------------------------------------------------------------------

def test_pmu_wakes_exactly_needed_sectors():
    mem = E.SRAMConfig("m", 1024 * 16, power_gated=True, banks=16,
                       sectors_per_bank=8)
    phases = [PhaseRequirement("a", 1024 * 4, 1000),
              PhaseRequirement("b", 1024 * 16, 1000),
              PhaseRequirement("c", 1024 * 2, 1000)]
    sched = build_schedule(mem, phases)
    fr = [p.on_fraction for p in sched.phases]
    assert fr[0] == pytest.approx(0.25)
    assert fr[1] == pytest.approx(1.0)
    assert fr[2] == pytest.approx(0.125)
    # transitions: 2 sectors, then +6, then down (no wake)
    assert [p.sectors_woken for p in sched.phases] == [2, 6, 0]


def test_pmu_non_gated_always_on():
    mem = E.SRAMConfig("m", 1024, power_gated=False)
    sched = build_schedule(mem, [PhaseRequirement("a", 10, 100)])
    assert sched.phases[0].on_fraction == 1.0
    assert sched.total_transitions == 0


@given(req=st.floats(0, 2e6), cap=st.integers(1024, 2**20),
       sectors=st.sampled_from([1, 4, 32, 128]))
@settings(max_examples=60, deadline=None)
def test_pmu_on_fraction_covers_requirement(req, cap, sectors):
    mem = E.SRAMConfig("m", cap, power_gated=True, sectors_per_bank=sectors)
    sched = build_schedule(mem, [PhaseRequirement("x", req, 100)])
    wanted = min(req / cap, 1.0)
    assert sched.phases[0].on_fraction >= wanted - 1e-9


# ---------------------------------------------------------------------------
# Planner (TPU adaptation)
# ---------------------------------------------------------------------------

def test_planner_respects_vmem_budget():
    for name, w in CAPSNET_WORKLOADS:
        p = plan_matmul(w)
        assert p.vmem_total <= VMEM_BYTES
        assert 0.0 <= p.gated_fraction < 1.0


def test_planner_alignment():
    p = plan_matmul(MatmulWorkload(m=1000, k=3000, n=5000))
    assert p.block_m % 8 == 0
    assert p.block_k % 128 == 0
    assert p.block_n % 128 == 0


@given(m=st.integers(8, 4096), k=st.integers(128, 8192),
       n=st.integers(128, 8192))
@settings(max_examples=25, deadline=None)
def test_planner_hbm_lower_bound(m, k, n):
    """Traffic can never be below compulsory (read once, write once)."""
    w = MatmulWorkload(m=m, k=k, n=n)
    p = plan_matmul(w)
    compulsory = (m * k + k * n + m * n) * w.in_bytes
    assert p.hbm_bytes >= compulsory - 1e-6


def test_planner_bigger_budget_never_more_traffic():
    w = MatmulWorkload(m=2048, k=4096, n=4096)
    small = plan_matmul(w, vmem_budget=VMEM_BYTES // 8)
    big = plan_matmul(w, vmem_budget=VMEM_BYTES)
    assert big.hbm_bytes <= small.hbm_bytes


# ---------------------------------------------------------------------------
# Dataflow ablation (benchmarks/bench_dataflow.py)
# ---------------------------------------------------------------------------

def test_linebuf_dataflow_selects_pg_sep_too():
    profiles = analysis.capsnet_profiles("linebuf")
    assert dse.best_design(profiles).org_name == "PG-SEP"


def test_linebuf_matches_paper_pg_claim():
    """The line-buffered dataflow reproduces the paper's -86% on-chip
    claim within a few points (the 'resident' default lands at -76%)."""
    profiles = analysis.capsnet_profiles("linebuf")
    orgs = dse.design_organizations(profiles)
    evs = {n: dse.evaluate(o, profiles) for n, o in orgs.items()}
    red = 1 - evs["PG-SEP"].total_mj / evs["SMP"].total_mj
    assert 0.8 < red < 0.95          # paper: 0.86


def test_linebuf_smaller_conv_footprint():
    res = analysis.capsnet_profiles("resident")
    lb = analysis.capsnet_profiles("linebuf")
    assert lb[1].total_mem < res[1].total_mem      # PrimaryCaps shrinks


def test_unknown_dataflow_rejected():
    with pytest.raises(ValueError):
        analysis.capsnet_profiles("bogus")
