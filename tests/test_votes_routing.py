"""Fused votes+routing megakernel: parity vs the jnp reference and the
split caps_votes->routing path (ragged i-blocks, non-power-of-two capsule
counts, batch>1, both schedules), the plan's resident-vs-streamed
decision, PlanError boundaries, and the modeled u_hat HBM savings."""

import jax
import numpy as np
import pytest

from repro.core import capsnet, execplan
from repro.core.capsnet import CapsNetConfig
from repro.core.execplan import (FUSED_NAME, PlanError, compile_plan,
                                 plan_votes_routing,
                                 split_votes_routing_hbm_bytes,
                                 votes_routing_hbm_bytes)
from repro.core.planner import VMEM_BYTES
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)

# Odd image + 24 capsule groups (the NONPOW2 config of test_execplan):
# num_primary = 600, every dimension non-power-of-two.
NONPOW2 = CapsNetConfig(image_hw=15, conv1_channels=24, conv1_kernel=5,
                        pc_kernel=3, pc_stride=2, num_primary_groups=24,
                        primary_dim=4, class_dim=8, use_decoder=False)


def _uv(b, i, c, jd, seed=0):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, seed))
    u = 0.5 * jax.random.normal(k1, (b, i, c))
    w = 0.3 * jax.random.normal(k2, (i, jd, c))
    return u, w


# ---------------------------------------------------------------------------
# Kernel parity: fused == jnp reference == split caps_votes -> routing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["resident", "streamed"])
@pytest.mark.parametrize("b,i,c,j,d,bi", [
    (1, 64, 8, 10, 16, 32),       # divisible blocks
    (2, 100, 8, 10, 16, 32),      # ragged final i-block (100 % 32)
    (3, 135, 8, 5, 8, 64),        # batch > 1 + ragged tail
    (2, 27, 4, 4, 8, 8),          # odd non-power-of-two capsule count
])
def test_fused_matches_reference_and_split(mode, b, i, c, j, d, bi):
    u, w = _uv(b, i, c, j * d, seed=i)
    got = ops.votes_routing(u, w, iters=3, num_classes=j, mode=mode,
                            block_i=bi)
    want = ref.routing(ref.caps_votes(u, w).reshape(b, i, j, d),
                       3).reshape(b, j * d)
    split = ops.routing(ops.caps_votes(u, w, block_i=bi), iters=3,
                        num_classes=j)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(split),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", ["resident", "streamed"])
@pytest.mark.parametrize("iters", [1, 2, 5])
def test_fused_iteration_sweep(mode, iters):
    u, w = _uv(2, 96, 8, 40, seed=iters)
    got = ops.votes_routing(u, w, iters=iters, num_classes=5, mode=mode,
                            block_i=32)
    want = ref.routing(ref.caps_votes(u, w).reshape(2, 96, 5, 8),
                       iters).reshape(2, 40)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_fused_rejects_bad_mode_and_classes():
    u, w = _uv(1, 16, 4, 20)
    with pytest.raises(ValueError, match="unknown mode"):
        ops.votes_routing(u, w, num_classes=5, mode="hybrid", block_i=8)
    with pytest.raises(ValueError, match="not divisible"):
        ops.votes_routing(u, w, num_classes=3, mode="resident", block_i=8)


def test_fused_planless_wrapper_picks_schedule():
    """Without a plan the wrapper resolves (mode, block_i) through the
    memoized plan decision and still matches the reference."""
    u, w = _uv(2, 150, 8, 80, seed=7)
    got = ops.votes_routing(u, w, iters=3, num_classes=10)
    want = ref.routing(ref.caps_votes(u, w).reshape(2, 150, 10, 8),
                       3).reshape(2, 80)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    mode, bi = ops.planned_votes_routing(150, 8, 80, 10, 3, 2)
    assert mode == "resident"               # MNIST-scale votes fit VMEM
    assert 1 <= bi <= 150


# ---------------------------------------------------------------------------
# Plan decision: resident by default, streamed under pressure, PlanError
# only when even streamed block_i=1 cannot fit
# ---------------------------------------------------------------------------

def test_small_budget_flips_plan_to_streamed():
    args = dict(batch=2, iters=3)
    roomy = plan_votes_routing(600, 4, 80, 10, **args)
    assert roomy.mode == "resident" and roomy.n_passes == 1
    tight = plan_votes_routing(600, 4, 80, 10, vmem_budget=150_000, **args)
    # fused s+b pass: W streams once per iteration + the final readout,
    # NOT the old 2-pass schedule's 2*iters+1
    assert tight.mode == "streamed" and tight.n_passes == 3 + 1
    assert tight.vmem_bytes <= 150_000
    # the flip is forced: no resident i-tile fits this budget
    assert execplan._fused_resident_vmem(2, 600, 1, 4, 80, 10) > 150_000


def test_plan_error_only_when_streamed_block1_unfit():
    floor = execplan._fused_streamed_vmem(2, 600, 1, 4, 80, 10)
    at_floor = plan_votes_routing(600, 4, 80, 10, batch=2,
                                  vmem_budget=floor)
    assert at_floor.mode == "streamed" and at_floor.block_i == 1
    with pytest.raises(PlanError, match="streamed block_i=1"):
        plan_votes_routing(600, 4, 80, 10, batch=2, vmem_budget=floor - 1)


def test_streamed_plan_executes_config_old_path_could_not():
    """num_primary >> budget: the votes (and the old resident-only routing
    state) exceed VMEM, so the pre-fusion path raised; the streamed
    schedule compiles AND matches the jnp reference end to end."""
    budget = 150_000
    plan = compile_plan(NONPOW2, batch=2, vmem_budget=budget)
    fused = plan.op(FUSED_NAME)
    assert fused.mode == "streamed"
    assert fused.vmem_bytes <= budget
    # the old path's floor: votes resident per batch element
    dims_votes = NONPOW2.num_primary * NONPOW2.num_classes \
        * NONPOW2.class_dim * execplan.ELEM_BYTES
    assert dims_votes > budget
    params = capsnet.init_params(KEY, NONPOW2)
    imgs = jax.random.uniform(KEY, (2, 15, 15, 1))
    want = capsnet.forward(params, imgs, NONPOW2)
    got = capsnet.forward(params, imgs, NONPOW2, backend="pallas", plan=plan)
    np.testing.assert_allclose(np.asarray(got["lengths"]),
                               np.asarray(want["lengths"]),
                               rtol=1e-4, atol=1e-4)


def test_fused_wrapper_rejects_batch_over_plan():
    """A batch larger than the plan's would scale the VMEM scratch past
    the validated footprint; smaller batches are within the bound."""
    plan = compile_plan(CapsNetConfig(use_decoder=False), batch=2)
    cfg = CapsNetConfig()
    u, w = _uv(4, cfg.num_primary, cfg.primary_dim,
               cfg.num_classes * cfg.class_dim, seed=11)
    with pytest.raises(ValueError, match="exceeds the plan's batch"):
        ops.votes_routing(u, w, plan=plan)
    out = ops.votes_routing(u[:1], w, plan=plan)          # smaller: fine
    assert out.shape == (1, cfg.num_classes * cfg.class_dim)


def test_fused_modes_agree_on_same_network():
    """Resident and streamed schedules are numerically interchangeable."""
    u, w = _uv(2, 600, 4, 80, seed=3)
    res = ops.votes_routing(u, w, iters=3, num_classes=10, mode="resident",
                            block_i=128)
    stre = ops.votes_routing(u, w, iters=3, num_classes=10, mode="streamed",
                             block_i=16)
    np.testing.assert_allclose(np.asarray(res), np.asarray(stre),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Fused s+b streamed pass vs the 2-pass oracle (mode="streamed-2pass")
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,i,c,j,d,bi,iters", [
    (1, 64, 8, 10, 16, 32, 3),       # divisible blocks
    (2, 100, 8, 10, 16, 32, 3),      # ragged final i-block + batch>1
    (3, 135, 8, 5, 8, 64, 2),        # batch > 1 + ragged tail
    (2, 27, 4, 4, 8, 8, 1),          # odd non-power-of-two capsule count
    (2, 96, 8, 5, 8, 32, 5),         # deeper iteration count
])
def test_fused_streamed_pass_matches_2pass_oracle(b, i, c, j, d, bi, iters):
    """The one-iteration software pipeline (b-update folded into the
    s-accumulation stream) is numerically identical to the unfused
    schedule that streams W separately for each."""
    u, w = _uv(b, i, c, j * d, seed=i + iters)
    fused = ops.votes_routing(u, w, iters=iters, num_classes=j,
                              mode="streamed", block_i=bi)
    oracle = ops.votes_routing(u, w, iters=iters, num_classes=j,
                               mode="streamed-2pass", block_i=bi)
    want = ref.routing(ref.caps_votes(u, w).reshape(b, i, j, d),
                       iters).reshape(b, j * d)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(oracle),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_oracle_mode_never_plan_chosen():
    """The 2-pass schedule exists only as a test oracle: every plan mode
    is resident or streamed, and validate() rejects the oracle name."""
    from repro.kernels.votes_routing import ALL_MODES, MODES, ORACLE_MODE
    assert ORACLE_MODE not in MODES and ORACLE_MODE in ALL_MODES
    plan = compile_plan(NONPOW2, batch=2, vmem_budget=150_000)
    assert plan.op(FUSED_NAME).mode in MODES
    import dataclasses
    bad = dataclasses.replace(
        plan, ops=tuple(dataclasses.replace(op, mode=ORACLE_MODE)
                        if op.name == FUSED_NAME else op
                        for op in plan.ops))
    with pytest.raises(PlanError, match="unknown mode"):
        bad.validate()


def test_streamed_w_traffic_halved_vs_2pass():
    """Forward W traffic drops from 2*iters+1 to iters+1 passes; the
    modeled per-forward savings is exactly iters W sweeps."""
    iters = 3
    tight = plan_votes_routing(600, 4, 80, 10, batch=2, iters=iters,
                               vmem_budget=150_000)
    fused_bytes = votes_routing_hbm_bytes(2, 600, 4, 80, tight.n_passes)
    oracle_bytes = votes_routing_hbm_bytes(2, 600, 4, 80, 2 * iters + 1)
    w_sweep = 600 * 80 * 4 * execplan.ELEM_BYTES
    assert tight.n_passes == iters + 1
    assert oracle_bytes - fused_bytes == iters * w_sweep
    # the plan's streamed ClassCaps-Routing entry models the fused count
    # at the lowering's padded i-grid (W rows pad to the block_i tiles)
    plan = compile_plan(NONPOW2, batch=2, vmem_budget=150_000)
    fused_op = plan.op(FUSED_NAME)
    assert fused_op.mode == "streamed"
    assert fused_op.uhat_hbm_bytes == 0
    jd = NONPOW2.num_classes * NONPOW2.class_dim
    assert fused_op.hbm_bytes == votes_routing_hbm_bytes(
        2, NONPOW2.num_primary, NONPOW2.primary_dim, jd,
        NONPOW2.routing_iters + 1, block_i=fused_op.block_i)


# ---------------------------------------------------------------------------
# Modeled HBM traffic: the u_hat round-trip is gone
# ---------------------------------------------------------------------------

def test_plan_reports_zero_uhat_traffic_and_savings():
    plan = compile_plan(CapsNetConfig(), batch=8)
    fused = plan.op(FUSED_NAME)
    assert fused.uhat_hbm_bytes == 0
    dims = (8, CapsNetConfig().num_primary, CapsNetConfig().primary_dim,
            CapsNetConfig().num_classes * CapsNetConfig().class_dim)
    split_total, uhat = split_votes_routing_hbm_bytes(*dims)
    # u_hat is written once and read back once by the split pair
    assert uhat == 2 * 8 * 1152 * 160 * execplan.ELEM_BYTES
    assert fused.mode == "resident"
    fused_total = votes_routing_hbm_bytes(*dims, n_passes=1)
    assert fused.hbm_bytes == fused_total
    assert split_total - fused_total == uhat    # savings == the round-trip


# ---------------------------------------------------------------------------
# Satellite: plan-less split-path pick respects batch + budget, caches
# bounded
# ---------------------------------------------------------------------------

def test_planned_block_i_shrinks_with_batch():
    bi1 = ops.planned_block_i(1152, 8, 160)
    bi_big = ops.planned_block_i(1152, 8, 160, batch=4096)
    assert bi_big <= bi1
    for batch, bi in ((1, bi1), (4096, bi_big)):
        assert execplan._votes_vmem(batch, bi, 8, 160) <= VMEM_BYTES


def test_planned_block_i_respects_small_budget():
    budget = 200_000
    bi = ops.planned_block_i(1152, 8, 160, 4, budget)
    assert execplan._votes_vmem(4, bi, 8, 160) <= budget
    with pytest.raises(PlanError, match="largest feasible batch"):
        ops.planned_block_i(1152, 8, 160, 10_000, budget)


def test_plan_caches_are_bounded():
    assert ops.planned_block_i.cache_info().maxsize == 64
    assert ops.planned_votes_routing.cache_info().maxsize == 64
    assert ops.planned_conv_blocks.cache_info().maxsize == 64
