"""Seeded-violation tests for the contract lint (``verify.lint``).

Each rule gets one source snippet that MUST trip it and a minimally
corrected twin that must pass -- linted as strings, never imported, so
the seeds cannot leak into the package.
"""

import repro
from repro.verify import lint_repo, lint_source
from repro.verify.lint import _roles_for


def _rules(violations):
    return {v.rule for v in violations}


# ---------------------------------------------------------------------------
# unbounded-cache
# ---------------------------------------------------------------------------

class TestUnboundedCache:

    def test_functools_cache_is_flagged(self):
        src = (
            "import functools\n"
            "@functools.cache\n"
            "def plan(shape):\n"
            "    return shape\n")
        assert _rules(lint_source(src)) == {"unbounded-cache"}

    def test_bare_lru_cache_is_flagged(self):
        src = (
            "import functools\n"
            "@functools.lru_cache\n"
            "def plan(shape):\n"
            "    return shape\n")
        assert _rules(lint_source(src)) == {"unbounded-cache"}

    def test_maxsize_none_is_flagged(self):
        src = (
            "import functools\n"
            "@functools.lru_cache(maxsize=None)\n"
            "def plan(shape):\n"
            "    return shape\n")
        assert _rules(lint_source(src)) == {"unbounded-cache"}

    def test_finite_maxsize_passes(self):
        src = (
            "import functools\n"
            "@functools.lru_cache(maxsize=64)\n"
            "def plan(shape):\n"
            "    return shape\n")
        assert lint_source(src) == []


# ---------------------------------------------------------------------------
# nameless-plan-error
# ---------------------------------------------------------------------------

class TestNamelessPlanError:

    def test_bare_constant_message_is_flagged(self):
        src = (
            "def plan(op):\n"
            "    raise PlanError('no feasible schedule')\n")
        assert _rules(lint_source(src)) == {"nameless-plan-error"}

    def test_missing_message_is_flagged(self):
        src = (
            "def plan(op):\n"
            "    raise PlanError()\n")
        assert _rules(lint_source(src)) == {"nameless-plan-error"}

    def test_formatted_message_passes(self):
        src = (
            "def plan(op):\n"
            "    raise PlanError(f'{op.name}: no feasible schedule')\n")
        assert lint_source(src) == []


# ---------------------------------------------------------------------------
# eager-compute-in-kernel (role: kernels)
# ---------------------------------------------------------------------------

class TestEagerCompute:

    def test_lax_conv_is_flagged(self):
        src = (
            "import jax\n"
            "def forward(x, w):\n"
            "    return jax.lax.conv_general_dilated(x, w, (1, 1),"
            " 'VALID')\n")
        assert _rules(lint_source(src, roles={"kernels"})) \
            == {"eager-compute-in-kernel"}

    def test_pallas_call_inside_kernel_body_is_flagged(self):
        src = (
            "from jax.experimental import pallas as pl\n"
            "def _inner_kernel(x_ref, o_ref):\n"
            "    o_ref[...] = pl.pallas_call(lambda r, o: None)(x_ref)\n")
        assert _rules(lint_source(src, roles={"kernels"})) \
            == {"eager-compute-in-kernel"}

    def test_pallas_call_in_wrapper_passes(self):
        src = (
            "from jax.experimental import pallas as pl\n"
            "def forward(x):\n"
            "    return pl.pallas_call(lambda r, o: None)(x)\n")
        assert lint_source(src, roles={"kernels"}) == []

    def test_rule_scoped_to_kernel_role(self):
        src = (
            "import jax\n"
            "def forward(x, w):\n"
            "    return jax.lax.conv_general_dilated(x, w, (1, 1),"
            " 'VALID')\n")
        assert lint_source(src, roles={"ops"}) == []


# ---------------------------------------------------------------------------
# unjitted-custom-vjp-wrapper (role: kernels)
# ---------------------------------------------------------------------------

class TestUnjittedCustomVjp:

    CORE = (
        "import jax\n"
        "@jax.custom_vjp\n"
        "def _core(x):\n"
        "    return x\n")

    def test_unjitted_wrapper_is_flagged(self):
        src = self.CORE + (
            "def apply(x):\n"
            "    return _core(x)\n")
        assert _rules(lint_source(src, roles={"kernels"})) \
            == {"unjitted-custom-vjp-wrapper"}

    def test_jitted_wrapper_passes(self):
        src = self.CORE + (
            "from functools import partial\n"
            "@partial(jax.jit, static_argnames=())\n"
            "def apply(x):\n"
            "    return _core(x)\n")
        assert lint_source(src, roles={"kernels"}) == []

    def test_private_helper_is_exempt(self):
        src = self.CORE + (
            "def _debug(x):\n"
            "    return _core(x)\n")
        assert lint_source(src, roles={"kernels"}) == []


# ---------------------------------------------------------------------------
# unfaulted-wrapper (role: ops)
# ---------------------------------------------------------------------------

class TestUnfaultedWrapper:

    IMPORT = ("from repro.kernels.conv_im2col import"
              " conv2d_im2col as _conv2d\n")

    def test_wrapper_without_fault_site_is_flagged(self):
        src = self.IMPORT + (
            "def conv2d(x, w, b):\n"
            "    return _conv2d(x, w, b)\n")
        assert _rules(lint_source(src, roles={"ops"})) \
            == {"unfaulted-wrapper"}

    def test_wrapper_with_fault_site_passes(self):
        src = self.IMPORT + (
            "from repro.core import faults\n"
            "def conv2d(x, w, b):\n"
            "    y = _conv2d(x, w, b)\n"
            "    return faults.corrupt_array(y, site='ops.conv2d')\n")
        assert lint_source(src, roles={"ops"}) == []

    def test_planning_helper_without_kernels_is_exempt(self):
        src = self.IMPORT + (
            "def shapes(cfg):\n"
            "    return cfg.image_hw\n")
        assert lint_source(src, roles={"ops"}) == []


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------

class TestDrivers:

    def test_roles_for_paths(self):
        assert _roles_for("src/repro/kernels/conv_im2col.py") \
            == frozenset({"kernels"})
        assert _roles_for("src/repro/kernels/ops.py") \
            == frozenset({"kernels", "ops"})
        assert _roles_for("src/repro/core/execplan.py") == frozenset()

    def test_repo_lints_clean(self):
        # The CI gate: the shipped package must carry zero violations.
        root = list(repro.__path__)[0]
        assert lint_repo(root) == []
