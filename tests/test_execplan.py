"""ExecutionPlan: invariants, plan-derived PMU schedules, PMU edge cases,
and the plan-driven Pallas forward vs the jnp reference."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import analysis, capsnet, dse
from repro.core.capsnet import CapsNetConfig
from repro.core.energy import SRAMConfig
from repro.core.execplan import PlanError, compile_plan
from repro.core.planner import VMEM_BYTES
from repro.core.pmu import PhaseRequirement, build_schedule, schedule_from_plan

KEY = jax.random.PRNGKey(0)
CFG = CapsNetConfig()                     # the paper's MNIST network
SMOKE = CapsNetConfig(image_hw=14, conv1_channels=16, conv1_kernel=5,
                      pc_kernel=3, num_primary_groups=4, primary_dim=4,
                      class_dim=8, decoder_hidden=(32, 64))
# pc_out = (10 - 6)//2 + 1 = 3, groups = 3 -> num_primary = 27: odd and
# non-power-of-two, the case that used to collapse planned_block_i to 1.
ODD = CapsNetConfig(image_hw=14, conv1_channels=8, conv1_kernel=5,
                    pc_kernel=6, pc_stride=2, num_primary_groups=3,
                    primary_dim=4, class_dim=8, use_decoder=False)
# Odd image, 24 capsule groups: every conv im2col matmul dimension is
# non-power-of-two (Conv1 M = B*121, K = 25, N = 24; PrimaryCaps M = B*25,
# K = 216, N = 96), so the Pallas conv kernels run ragged final M/N blocks
# and K zero-padding end to end.
NONPOW2 = CapsNetConfig(image_hw=15, conv1_channels=24, conv1_kernel=5,
                        pc_kernel=3, pc_stride=2, num_primary_groups=24,
                        primary_dim=4, class_dim=8, use_decoder=False)


# ---------------------------------------------------------------------------
# Plan invariants
# ---------------------------------------------------------------------------

def test_plan_covers_all_five_operations():
    """Three EXECUTED ops (ClassCaps is one fused megakernel) covering the
    five dataflow-model operations."""
    plan = compile_plan(CFG)
    assert [op.name for op in plan.ops] == [
        "Conv1", "PrimaryCaps", "ClassCaps-Routing"]
    assert [p.name for p in plan.profiles] == [
        "Conv1", "PrimaryCaps", "ClassCaps-FC", "Sum+Squash", "Update+Sum"]
    assert plan.phase_groups() == (
        ("Conv1", ("Conv1",)),
        ("PrimaryCaps", ("PrimaryCaps",)),
        ("ClassCaps-Routing", ("ClassCaps-FC", "Sum+Squash", "Update+Sum")))
    assert [r.name for r in plan.phase_requirements()] == [
        op.name for op in plan.ops]


@pytest.mark.parametrize("cfg", [CFG, SMOKE, ODD],
                         ids=["mnist", "smoke", "odd"])
@pytest.mark.parametrize("batch", [1, 4])
def test_plan_footprints_fit_vmem(cfg, batch):
    plan = compile_plan(cfg, batch=batch)
    plan.validate()
    for op in plan.ops:
        assert op.vmem_bytes <= plan.vmem_budget <= VMEM_BYTES
        assert op.requirement.required_bytes > 0
        assert op.requirement.duration_cycles > 0
    assert plan.peak_vmem_bytes <= VMEM_BYTES


def test_plan_profiles_match_analysis():
    """The plan's dataflow profiles ARE the paper's Fig. 4 model."""
    plan = compile_plan(CFG)
    want = analysis.capsnet_profiles()
    assert [dataclasses.asdict(p) for p in plan.profiles] == [
        dataclasses.asdict(p) for p in want]


def test_plan_block_i_not_degenerate_for_odd_caps():
    plan = compile_plan(ODD)
    bi = plan.op("ClassCaps-Routing").block_i
    assert 1 < bi <= ODD.num_primary
    assert bi >= 8              # the old //=2 loop would have returned 1


@pytest.mark.parametrize("cfg", [CFG, SMOKE, ODD, NONPOW2],
                         ids=["mnist", "smoke", "odd", "nonpow2"])
def test_plan_runs_whole_network_through_pallas(cfg):
    """No conv2d.xla asterisk left, and no separate caps_votes+routing
    pair: the ClassCaps head is ONE fused votes_routing op."""
    plan = compile_plan(cfg, batch=2)
    kernels = {op.name: op.kernel for op in plan.ops}
    assert not any("xla" in k for k in kernels.values()), kernels
    assert kernels["Conv1"] == "conv_im2col"
    assert kernels["PrimaryCaps"].startswith("conv_im2col")
    assert kernels["ClassCaps-Routing"] == "votes_routing"
    assert "caps_votes" not in kernels.values()
    assert "routing" not in kernels.values()
    fused = plan.op("ClassCaps-Routing")
    assert fused.mode in ("resident", "streamed")
    assert fused.uhat_hbm_bytes == 0            # the votes never hit HBM
    for name in ("Conv1", "PrimaryCaps"):
        blk = plan.op(name).block
        assert blk is not None and blk.block_m >= 1 and blk.block_k >= 1


def test_primarycaps_squash_fuses_when_tile_capsule_aligned():
    plan = compile_plan(CFG)
    pc = plan.op("PrimaryCaps")
    assert pc.block.block_n % CFG.primary_dim == 0
    assert pc.kernel == "conv_im2col+squash" and pc.fuses_squash
    assert pc.block_rows is not None          # fallback tile still planned


def test_primarycaps_squash_fuses_on_clamped_tile():
    """Fusion keys on the CLAMPED n-tile: primary_dim=12 does not divide a
    planner block_n of 128, but the kernel clamps the tile to pc_cout=96,
    which 12 does divide."""
    cfg = CapsNetConfig(image_hw=14, conv1_channels=16, conv1_kernel=5,
                        pc_kernel=3, num_primary_groups=8, primary_dim=12,
                        class_dim=8, use_decoder=False)
    plan = compile_plan(cfg, batch=2)
    pc = plan.op("PrimaryCaps")
    assert cfg.pc_channels == 96
    assert min(pc.block.block_n, cfg.pc_channels) % cfg.primary_dim == 0
    assert pc.fuses_squash
    # and the forward still matches the reference through the fused path
    params = capsnet.init_params(KEY, cfg)
    imgs = jax.random.uniform(KEY, (2, 14, 14, 1))
    want = capsnet.forward(params, imgs, cfg)
    got = capsnet.forward(params, imgs, cfg, backend="pallas", plan=plan)
    np.testing.assert_allclose(np.asarray(got["lengths"]),
                               np.asarray(want["lengths"]),
                               rtol=1e-4, atol=1e-4)


def test_plan_rejects_impossible_budget():
    with pytest.raises(ValueError):          # PlanError or planner failure
        compile_plan(CFG, vmem_budget=1024)


def test_votes_block_i_raises_plan_error_at_source():
    """An infeasible batch fails in the split-path i-tile pick with a
    message naming the batch, the budget, and the largest feasible batch
    -- not later in validate() with a generic footprint complaint."""
    from repro.core.execplan import _votes_block_i_raw, _votes_max_batch
    dims = analysis.dims_from_config(SMOKE)
    out_dim = dims.num_classes * dims.class_dim
    budget = 200_000
    feasible = _votes_max_batch(dims.primary_dim, out_dim, budget)
    assert feasible > 0
    # boundary: the largest feasible batch plans, one past it raises
    bi = _votes_block_i_raw(dims.num_primary, dims.primary_dim, out_dim,
                            feasible, budget)
    assert bi >= 1
    with pytest.raises(PlanError) as exc:
        _votes_block_i_raw(dims.num_primary, dims.primary_dim, out_dim,
                           feasible + 1, budget)
    msg = str(exc.value)
    assert f"batch={feasible + 1}" in msg
    assert str(budget) in msg
    assert f"largest feasible batch is {feasible}" in msg


def test_compile_plan_surfaces_fused_plan_error():
    """compile_plan at a batch no fused schedule can serve reports the
    megakernel's message: PlanError names the streamed block_i=1 floor
    (the convs fit; the resident AND streamed footprints are what break)."""
    with pytest.raises(PlanError, match="streamed block_i=1"):
        compile_plan(SMOKE, batch=2000, vmem_budget=400_000)


def test_plan_validate_catches_oversized_op():
    plan = compile_plan(CFG)
    bad = dataclasses.replace(plan.ops[0], vmem_bytes=plan.vmem_budget + 1)
    broken = dataclasses.replace(plan, ops=(bad,) + plan.ops[1:])
    with pytest.raises(PlanError):
        broken.validate()


def test_plan_unknown_op_lookup():
    with pytest.raises(KeyError):
        compile_plan(CFG).op("nonexistent")


# ---------------------------------------------------------------------------
# One schedule: the DSE/PMU consume what the kernels execute
# ---------------------------------------------------------------------------

def test_dse_default_uses_plan_schedule():
    """The default DSE scores the plan's FUSED phases (one gating phase
    for the votes+routing megakernel); explicit profiles keep the paper's
    five-phase model."""
    via_plan = dse.best_design(plan=compile_plan(CFG))
    default = dse.best_design()
    assert via_plan.org_name == default.org_name
    assert via_plan.total_mj == pytest.approx(default.total_mj)
    grouped = via_plan.evaluation.schedules[0]
    assert [ph.name for ph in grouped.phases] == [
        "Conv1", "PrimaryCaps", "ClassCaps-Routing"]
    explicit = dse.best_design(analysis.capsnet_profiles())
    assert len(explicit.evaluation.schedules[0].phases) == 5


def test_dse_rejects_profiles_and_plan_together():
    with pytest.raises(ValueError):
        dse.explore(analysis.capsnet_profiles(), plan=compile_plan(CFG))


def test_schedule_from_plan_matches_manual_requirements():
    plan = compile_plan(CFG)
    mem = SRAMConfig("m", 1 << 20, power_gated=True, banks=16,
                     sectors_per_bank=64)
    got = schedule_from_plan(mem, plan)
    want = build_schedule(mem, plan.phase_requirements())
    assert got == want
    assert [p.name for p in got.phases] == [op.name for op in plan.ops]


def test_evaluate_plan_gates_fused_phases():
    """evaluate_plan == evaluate with the plan's phase groups: the fused
    megakernel is ONE gating phase with the peak demand and summed
    duration of the operations it covers, and identical dynamic energy."""
    plan = compile_plan(CFG)
    org = dse.design_organizations(list(plan.profiles))["PG-SEP"]
    via_plan = dse.evaluate_plan(org, plan)
    grouped = dse.evaluate(org, list(plan.profiles),
                           phase_groups=plan.phase_groups())
    ungrouped = dse.evaluate(org, list(plan.profiles))
    assert via_plan.total_mj == pytest.approx(grouped.total_mj)
    assert via_plan.dynamic_mj == pytest.approx(ungrouped.dynamic_mj)
    for sched, raw in zip(via_plan.schedules, ungrouped.schedules):
        assert len(sched.phases) == 3 and len(raw.phases) == 5
        fused, covered = sched.phases[-1], raw.phases[2:]
        assert fused.duration_s == pytest.approx(
            sum(ph.duration_s for ph in covered))
        assert fused.on_fraction == pytest.approx(
            max(ph.on_fraction for ph in covered))


# ---------------------------------------------------------------------------
# Training plans: backward OpPlans gated like the forward's
# ---------------------------------------------------------------------------

def test_train_plan_appends_backward_ops_in_reverse_order():
    plan = compile_plan(CFG, batch=2, train=True)
    assert plan.train
    assert [op.name for op in plan.ops] == [
        "Conv1", "PrimaryCaps", "ClassCaps-Routing",
        "ClassCaps-Routing-bwd", "PrimaryCaps-bwd", "Conv1-bwd"]
    assert [p.name for p in plan.profiles] == [
        "Conv1", "PrimaryCaps", "ClassCaps-FC", "Sum+Squash", "Update+Sum",
        "Update+Sum-bwd", "Sum+Squash-bwd", "ClassCaps-FC-bwd",
        "PrimaryCaps-bwd", "Conv1-bwd"]
    plan.validate()
    for op in plan.ops:
        assert op.vmem_bytes <= plan.vmem_budget
        assert op.requirement.duration_cycles > 0
    # conv backwards reuse the forward block tiles
    for name in ("Conv1", "PrimaryCaps"):
        assert plan.op(name + "-bwd").block == plan.op(name).block
        assert plan.op(name + "-bwd").kernel == "conv_im2col_bwd"
    bwd = plan.op("ClassCaps-Routing-bwd")
    assert bwd.mode in ("resident", "streamed")
    assert bwd.uhat_hbm_bytes == 0


def test_train_plan_gates_backward_phases_in_dse_and_pmu():
    plan = compile_plan(CFG, train=True)
    mem = SRAMConfig("m", 1 << 20, power_gated=True, banks=16,
                     sectors_per_bank=64)
    sched = schedule_from_plan(mem, plan)
    assert [ph.name for ph in sched.phases] == [op.name for op in plan.ops]
    org = dse.design_organizations(list(plan.profiles))["PG-SEP"]
    ev = dse.evaluate_plan(org, plan)
    for s in ev.schedules:
        assert len(s.phases) == 6            # 3 forward + 3 backward
    assert "ClassCaps-Routing-bwd" in ev.per_op_mj
    # the train=True default DSE sizes organizations for the full step
    via_train = dse.best_design(train=True)
    assert [ph.name for ph in
            via_train.evaluation.schedules[0].phases][-1] == "Conv1-bwd"


# ---------------------------------------------------------------------------
# PMU edge cases
# ---------------------------------------------------------------------------

def test_pmu_zero_capacity_memory():
    mem = SRAMConfig("m", 0, power_gated=True, sectors_per_bank=8)
    sched = build_schedule(mem, [PhaseRequirement("a", 1024, 100),
                                 PhaseRequirement("b", 0, 100)])
    for ph in sched.phases:
        assert ph.on_fraction == 0.0
        assert ph.sectors_woken == 0
        assert ph.leakage_mj == 0.0
        assert ph.wakeup_mj == 0.0
    assert np.isfinite(sched.static_mj)


def test_pmu_non_gated_always_fully_on_zero_wakeups():
    mem = SRAMConfig("m", 1 << 16, power_gated=False, sectors_per_bank=8)
    sched = build_schedule(mem, [PhaseRequirement("a", 10, 100),
                                 PhaseRequirement("b", 1 << 16, 100),
                                 PhaseRequirement("c", 0, 100)])
    for ph in sched.phases:
        assert ph.on_fraction == 1.0
        assert ph.sectors_woken == 0
        assert ph.wakeup_mj == 0.0
        assert ph.wakeup_latency_cycles == 0.0
    assert sched.total_transitions == 0
    assert sched.wakeup_mj == 0.0


def test_pmu_shrinking_phases_never_negative_wakeups():
    mem = SRAMConfig("m", 1 << 16, power_gated=True, sectors_per_bank=16)
    reqs = [PhaseRequirement(f"p{i}", b, 100)
            for i, b in enumerate([1 << 16, 1 << 14, 1 << 12, 256, 0])]
    sched = build_schedule(mem, reqs)
    assert all(ph.sectors_woken >= 0 for ph in sched.phases)
    assert [ph.sectors_woken for ph in sched.phases][1:] == [0, 0, 0, 0]
    fr = [ph.on_fraction for ph in sched.phases]
    assert fr == sorted(fr, reverse=True)


def test_pmu_quantization_granularity():
    mem = SRAMConfig("m", 1 << 20, power_gated=True, banks=16,
                     sectors_per_bank=4)
    for want in (0.01, 0.26, 0.5, 0.51, 0.99, 1.0):
        sched = build_schedule(
            mem, [PhaseRequirement("x", want * mem.capacity_bytes, 100)])
        frac = sched.phases[0].on_fraction
        assert frac >= want - 1e-9                    # covers the demand
        assert frac * 4 == pytest.approx(round(frac * 4))  # whole sectors


# ---------------------------------------------------------------------------
# Plan-driven Pallas forward == jnp reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [SMOKE, ODD, NONPOW2],
                         ids=["smoke", "odd", "nonpow2"])
def test_pallas_backend_matches_jnp(cfg):
    params = capsnet.init_params(KEY, cfg)
    imgs = jax.random.uniform(KEY, (3, cfg.image_hw, cfg.image_hw, 1))
    want = capsnet.forward(params, imgs, cfg)
    got = capsnet.forward(params, imgs, cfg, backend="pallas")
    np.testing.assert_allclose(np.asarray(got["class_caps"]),
                               np.asarray(want["class_caps"]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got["lengths"]),
                               np.asarray(want["lengths"]),
                               rtol=1e-4, atol=1e-4)
    if "reconstruction" in want:
        np.testing.assert_allclose(np.asarray(got["reconstruction"]),
                                   np.asarray(want["reconstruction"]),
                                   rtol=1e-4, atol=1e-4)


def test_pallas_backend_accepts_precompiled_plan():
    params = capsnet.init_params(KEY, SMOKE)
    imgs = jax.random.uniform(KEY, (2, 14, 14, 1))
    plan = compile_plan(SMOKE, batch=2)
    got = capsnet.forward(params, imgs, SMOKE, backend="pallas", plan=plan)
    want = capsnet.forward(params, imgs, SMOKE)
    np.testing.assert_allclose(np.asarray(got["lengths"]),
                               np.asarray(want["lengths"]),
                               rtol=1e-4, atol=1e-4)


def test_unknown_backend_rejected():
    params = capsnet.init_params(KEY, SMOKE)
    imgs = jax.random.uniform(KEY, (1, 14, 14, 1))
    with pytest.raises(ValueError):
        capsnet.forward(params, imgs, SMOKE, backend="torch")
