"""Model-substrate behaviour: decode==full-forward equivalence across all
families, mamba chunked-vs-recurrent oracle, MoE dispatch identities,
attention flavours (GQA grouping, sliding window, softcap, MLA absorbed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import LM_ARCHS, get_smoke_config
from repro.models import decode_step, forward, init_model, prefill
from repro.models import attention as attn_mod
from repro.models.config import MLAConfig, MoEConfig, ModelConfig
from repro.models.mamba import ssd_chunked, ssd_recurrent_step
from repro.models.moe import capacity_for, moe_forward, init_moe_params

KEY = jax.random.PRNGKey(0)
DECODE_ARCHS = [a for a in LM_ARCHS if get_smoke_config(a).has_decode]


def _dropfree(cfg):
    """MoE token dropping depends on batch composition (capacity is per
    dispatch), so exact decode==full equivalence requires drop-free
    capacity.  Real serving accepts the small routing drift instead."""
    import dataclasses
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_full_forward(arch):
    """prefill + N decode steps == teacher-forced full forward."""
    cfg = _dropfree(get_smoke_config(arch))
    params = init_model(KEY, cfg)
    inp = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    full, _, _ = forward(params, inp, cfg=cfg)
    lg, cache = prefill(params, inp[:, :8], cfg, max_len=16,
                        cache_dtype=jnp.float32)
    errs = [np.abs(np.asarray(lg[:, -1]) - np.asarray(full[:, 7])).max()]
    idx = jnp.asarray(8, jnp.int32)
    for s in range(8, 13):
        lg2, cache = decode_step(params, cache, inp[:, s:s + 1], idx, cfg)
        errs.append(np.abs(np.asarray(lg2[:, 0])
                           - np.asarray(full[:, s])).max())
        idx = idx + 1
    assert max(errs) < 5e-4, f"{arch}: decode diverges {max(errs)}"


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_vector_cache_index_matches_scalar(arch):
    cfg = _dropfree(get_smoke_config(arch))
    params = init_model(KEY, cfg)
    inp = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    _, cache = prefill(params, inp[:, :8], cfg, max_len=16,
                       cache_dtype=jnp.float32)
    tok = inp[:, 8:9]
    lg_s, _ = decode_step(params, cache, tok, jnp.asarray(8, jnp.int32), cfg)
    lg_v, _ = decode_step(params, cache, tok,
                          jnp.asarray([8, 8], jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------

def _naive_ssm(x, a, b, c):
    """Token-by-token oracle.  x: [B,T,H,P], a: [B,T,H], b/c: [B,T,H,N]."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    hstate = np.zeros((bsz, h, p, n))
    ys = []
    for i in range(t):
        decay = np.exp(np.asarray(a[:, i]))[..., None, None]
        hstate = decay * hstate + np.einsum("bhp,bhn->bhpn",
                                            np.asarray(x[:, i]),
                                            np.asarray(b[:, i]))
        ys.append(np.einsum("bhpn,bhn->bhp", hstate, np.asarray(c[:, i])))
    return np.stack(ys, 1), hstate


@pytest.mark.parametrize("t,chunk", [(16, 4), (32, 8), (24, 8), (8, 8)])
def test_ssd_chunked_matches_naive_recurrence(t, chunk):
    ks = jax.random.split(KEY, 4)
    b, h, p, n = 2, 3, 4, 8
    x = jax.random.normal(ks[0], (b, t, h, p))
    a = -jnp.abs(jax.random.normal(ks[1], (b, t, h))) * 0.5
    bm = jax.random.normal(ks[2], (b, t, h, n)) * 0.3
    cm = jax.random.normal(ks[3], (b, t, h, n)) * 0.3
    y, hf = ssd_chunked(x, a, bm, cm, chunk)
    y_ref, h_ref = _naive_ssm(x, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), h_ref, rtol=1e-4, atol=1e-4)


def test_ssd_recurrent_continues_chunked():
    """Chunked prefill state hand-off -> recurrent decode == full chunked."""
    ks = jax.random.split(KEY, 4)
    b, t, h, p, n = 1, 12, 2, 4, 8
    x = jax.random.normal(ks[0], (b, t, h, p))
    a = -jnp.abs(jax.random.normal(ks[1], (b, t, h))) * 0.5
    bm = jax.random.normal(ks[2], (b, t, h, n)) * 0.3
    cm = jax.random.normal(ks[3], (b, t, h, n)) * 0.3
    y_full, _ = ssd_chunked(x, a, bm, cm, chunk=4)
    y_pre, hstate = ssd_chunked(x[:, :8], a[:, :8], bm[:, :8], cm[:, :8],
                                chunk=4)
    outs = [y_pre]
    for i in range(8, t):
        y1, hstate = ssd_recurrent_step(x[:, i:i + 1], a[:, i:i + 1],
                                        bm[:, i:i + 1], cm[:, i:i + 1],
                                        hstate)
        outs.append(y1)
    got = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)


@given(chunk=st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=8, deadline=None)
def test_ssd_chunk_size_invariance(chunk):
    ks = jax.random.split(KEY, 4)
    b, t, h, p, n = 1, 16, 2, 4, 4
    x = jax.random.normal(ks[0], (b, t, h, p))
    a = -jnp.abs(jax.random.normal(ks[1], (b, t, h)))
    bm = jax.random.normal(ks[2], (b, t, h, n)) * 0.3
    cm = jax.random.normal(ks[3], (b, t, h, n)) * 0.3
    y1, h1 = ssd_chunked(x, a, bm, cm, chunk)
    y2, h2 = ssd_chunked(x, a, bm, cm, 16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_cfg(e=4, k=2, cap=8.0):
    return ModelConfig(
        name="t", family="moe", d_model=16, num_heads=2, num_kv_heads=2,
        head_dim=8, d_ff=32, vocab_size=64, pattern=("global",), repeats=1,
        moe=MoEConfig(num_experts=e, top_k=k, d_ff_expert=24,
                      capacity_factor=cap))


def test_moe_no_drop_matches_dense_computation():
    """With huge capacity, MoE == explicit per-token expert sum."""
    cfg = _moe_cfg(cap=100.0)
    p = init_moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, 16))
    out, aux = moe_forward(p, x, cfg=cfg)
    # oracle
    xf = np.asarray(x).reshape(-1, 16)
    logits = xf @ np.asarray(p["router"])
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    top_v, top_i = jax.lax.top_k(probs, 2)
    top_v = np.asarray(top_v / top_v.sum(-1, keepdims=True))
    want = np.zeros_like(xf)
    for tkn in range(xf.shape[0]):
        for j in range(2):
            e = int(top_i[tkn, j])
            g = np.asarray(jax.nn.silu(xf[tkn] @ np.asarray(
                p["experts_gate"][e])))
            u = xf[tkn] @ np.asarray(p["experts_up"][e])
            want[tkn] += top_v[tkn, j] * ((g * u) @ np.asarray(
                p["experts_down"][e]))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, 16), want,
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(cap=0.25)          # tiny capacity -> drops
    p = init_moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 32, 16))
    out, _ = moe_forward(p, x, cfg=cfg)
    assert np.isfinite(np.asarray(out)).all()
    # some tokens must have been dropped (zero output rows are possible)
    cfg_big = _moe_cfg(cap=100.0)
    out_big, _ = moe_forward(p, x, cfg=cfg_big)
    assert not np.allclose(np.asarray(out), np.asarray(out_big))


def test_moe_capacity_rounding():
    cfg = _moe_cfg()
    assert capacity_for(64, cfg.moe) % 8 == 0
    assert capacity_for(64, cfg.moe) >= 64 * 2 / 4


def test_moe_aux_loss_balanced_lower():
    cfg = _moe_cfg(cap=100.0)
    p = init_moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (4, 64, 16))
    _, aux_rand = moe_forward(p, x, cfg=cfg)
    assert float(aux_rand) > 0


# ---------------------------------------------------------------------------
# Attention flavours
# ---------------------------------------------------------------------------

def test_gqa_equals_mha_when_replicated():
    """GQA with duplicated KV heads == MHA."""
    b, t, h, dh = 1, 8, 4, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, h, dh))
    k2 = jax.random.normal(ks[1], (b, t, 2, dh))
    v2 = jax.random.normal(ks[2], (b, t, 2, dh))
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    out_gqa = attn_mod.grouped_attention(q, k2, v2, pos, pos, causal=True,
                                         window=None, softcap=None,
                                         scale=dh ** -0.5)
    k4 = jnp.repeat(k2, 2, axis=2)
    v4 = jnp.repeat(v2, 2, axis=2)
    out_mha = attn_mod.grouped_attention(q, k4, v4, pos, pos, causal=True,
                                         window=None, softcap=None,
                                         scale=dh ** -0.5)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_limits_context():
    """With window=1 every query attends only to itself -> out == v."""
    b, t, h, dh = 1, 8, 2, 4
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, h, dh))
    k = jax.random.normal(ks[1], (b, t, h, dh))
    v = jax.random.normal(ks[2], (b, t, h, dh))
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    out = attn_mod.grouped_attention(q, k, v, pos, pos, causal=True,
                                     window=1, softcap=None, scale=0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v), rtol=1e-5,
                               atol=1e-5)


def test_softcap_bounds_logits():
    """Softcapping changes attention when logits differ beyond the cap."""
    b, t, h, dh = 1, 4, 1, 4
    ks = jax.random.split(KEY, 3)
    q = 10.0 * jax.random.normal(ks[0], (b, t, h, dh))
    k = 10.0 * jax.random.normal(ks[1], (b, t, h, dh))
    v = jax.random.normal(ks[2], (b, t, h, dh))
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    a = attn_mod.grouped_attention(q, k, v, pos, pos, causal=True,
                                   window=None, softcap=5.0, scale=1.0)
    bb = attn_mod.grouped_attention(q, k, v, pos, pos, causal=True,
                                    window=None, softcap=None, scale=1.0)
    assert np.isfinite(np.asarray(a)).all()
    assert not np.allclose(np.asarray(a), np.asarray(bb))
    # capped rows are bounded mixtures: |out| <= max |v|
    assert np.abs(np.asarray(a)).max() <= np.abs(np.asarray(v)).max() + 1e-5


def test_mla_absorbed_equals_explicit():
    """MLA decode (latent-space absorbed) == explicit prefill math."""
    cfg = ModelConfig(
        name="t", family="dense", d_model=32, num_heads=2, num_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=64, pattern=("global",), repeats=1,
        mla=MLAConfig(kv_lora_rank=16, qk_nope_head_dim=8,
                      qk_rope_head_dim=4, v_head_dim=8))
    p = attn_mod.init_attn_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 6, 32))
    pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    out_explicit, _ = attn_mod.mla_forward(p, x, pos, cfg=cfg, cache=None,
                                           cache_index=None, shd=None)
    cache = attn_mod.init_cache(cfg, 2, 6, jnp.float32)
    out_absorbed, _ = attn_mod.mla_forward(p, x, pos, cfg=cfg, cache=cache,
                                           cache_index=jnp.asarray(0),
                                           shd=None)
    np.testing.assert_allclose(np.asarray(out_explicit),
                               np.asarray(out_absorbed), rtol=1e-4,
                               atol=1e-4)


def test_encoder_bidirectional_sees_future():
    cfg = get_smoke_config("hubert-xlarge")
    params = init_model(KEY, cfg)
    frames = jax.random.normal(KEY, (1, 8, cfg.frontend_dim))
    lg1, _, _ = forward(params, frames, cfg=cfg)
    frames2 = frames.at[:, -1].set(0.0)       # change only the LAST frame
    lg2, _, _ = forward(params, frames2, cfg=cfg)
    # position 0's logits must change (bidirectional attention)
    assert not np.allclose(np.asarray(lg1[:, 0]), np.asarray(lg2[:, 0]))


def test_shared_attn_weights_are_shared():
    cfg = get_smoke_config("zamba2-1.2b")
    params = init_model(KEY, cfg)
    assert "shared" in params
    # no per-slot weights for the shared slot
    assert params["blocks"][f"s{len(cfg.pattern)-1}"] == {}
