"""Serving engine: continuous batching vs full-forward oracle, slot
refill, EOS handling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_model
from repro.models.transformer import forward, greedy_generate
from repro.serve import Request, ServeEngine

KEY = jax.random.PRNGKey(0)
CFG = get_smoke_config("granite-3-2b")
PARAMS = init_model(KEY, CFG)


def _oracle_greedy(prompt, n):
    seq = list(prompt)
    out = []
    for _ in range(n):
        lg, _, _ = forward(PARAMS, jnp.asarray([seq], jnp.int32), cfg=CFG)
        tok = int(jnp.argmax(lg[0, -1]))
        out.append(tok)
        seq.append(tok)
    return out


def test_engine_matches_oracle_mixed_lengths():
    engine = ServeEngine(PARAMS, CFG, slots=2, max_len=64)
    prompts = [np.arange(5), np.arange(9) * 3, np.arange(3) * 7]
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=(p % CFG.vocab_size)
                              .astype(np.int32), max_new_tokens=5))
    done = engine.run()
    assert len(done) == 3
    for r in done:
        want = _oracle_greedy(list(prompts[r.rid] % CFG.vocab_size), 5)
        assert r.output == want, f"req {r.rid}"


def test_engine_slot_refill_more_requests_than_slots():
    engine = ServeEngine(PARAMS, CFG, slots=2, max_len=64)
    for i in range(5):
        engine.submit(Request(rid=i, prompt=np.arange(4, dtype=np.int32)
                              + i, max_new_tokens=3))
    done = engine.run()
    assert sorted(r.rid for r in done) == list(range(5))


def test_engine_eos_stops_early():
    # find what the model emits first, then use it as EOS
    probe = ServeEngine(PARAMS, CFG, slots=1, max_len=64)
    probe.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                         max_new_tokens=8))
    first = probe.run()[0].output[0]
    engine = ServeEngine(PARAMS, CFG, slots=1, max_len=64)
    engine.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                          max_new_tokens=8, eos_id=int(first)))
    done = engine.run()
    assert len(done[0].output) == 1          # stopped at EOS immediately


def test_greedy_generate_matches_oracle():
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    got = greedy_generate(PARAMS, prompt, 4, CFG)
    want = _oracle_greedy([1, 2, 3, 4], 4)
    assert list(np.asarray(got)[0]) == want


def test_engine_rejects_encoder():
    cfg = get_smoke_config("hubert-xlarge")
    p = init_model(KEY, cfg)
    with pytest.raises(ValueError):
        ServeEngine(p, cfg)
