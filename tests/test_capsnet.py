"""CapsuleNet (the paper's model) behaviour tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import capsnet
from repro.train.data import DataConfig, mnist_batch

CFG = capsnet.CapsNetConfig()
SMOKE = capsnet.CapsNetConfig(image_hw=14, conv1_channels=16,
                              conv1_kernel=5, pc_kernel=3,
                              num_primary_groups=4, primary_dim=4,
                              class_dim=8, decoder_hidden=(32, 64))
KEY = jax.random.PRNGKey(0)


def test_shapes_match_sabour():
    assert CFG.conv1_out == 20
    assert CFG.pc_out == 6
    assert CFG.num_primary == 1152
    assert CFG.pc_channels == 256


def test_forward_shapes():
    params = capsnet.init_params(KEY, SMOKE)
    imgs = jax.random.uniform(KEY, (3, 14, 14, 1))
    out = capsnet.forward(params, imgs, SMOKE)
    assert out["class_caps"].shape == (3, 10, 8)
    assert out["lengths"].shape == (3, 10)
    assert out["reconstruction"].shape == (3, 14 * 14)
    assert np.isfinite(np.asarray(out["lengths"])).all()


def test_squash_properties():
    x = jax.random.normal(KEY, (32, 16)) * 10
    v = capsnet.squash(x)
    norms = np.linalg.norm(np.asarray(v), axis=-1)
    assert (norms < 1.0 + 1e-5).all()
    # direction preserved
    cos = np.sum(np.asarray(v) * np.asarray(x), -1)
    assert (cos > 0).all()


@given(scale=st.floats(0.01, 50.0))
@settings(max_examples=20, deadline=None)
def test_squash_monotone_norm(scale):
    x = jnp.ones((1, 8))
    a = np.linalg.norm(np.asarray(capsnet.squash(x * scale)))
    b = np.linalg.norm(np.asarray(capsnet.squash(x * scale * 2)))
    assert b >= a - 1e-6


def test_routing_coupling_sums_to_one():
    uh = 0.1 * jax.random.normal(KEY, (2, 32, 10, 8))
    v = capsnet.routing_by_agreement(uh, 3)
    assert v.shape == (2, 10, 8)
    assert np.isfinite(np.asarray(v)).all()


def test_routing_more_iters_sharpens_agreement():
    # With one dominant vote direction, more routing iterations should not
    # reduce the winning capsule's length.
    k1, k2 = jax.random.split(KEY)
    uh = 0.01 * jax.random.normal(k1, (1, 64, 10, 8))
    strong = jnp.zeros((1, 64, 10, 8)).at[:, :, 3, 0].set(0.5)
    uh = uh + strong
    v1 = capsnet.routing_by_agreement(uh, 1)
    v3 = capsnet.routing_by_agreement(uh, 3)
    n1 = np.linalg.norm(np.asarray(v1[0, 3]))
    n3 = np.linalg.norm(np.asarray(v3[0, 3]))
    assert n3 >= n1 - 1e-4


def test_margin_loss_zero_when_perfect():
    lengths = jnp.array([[0.95, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05,
                          0.05, 0.05]])
    loss = capsnet.margin_loss(lengths, jnp.array([0]))
    assert float(loss) == pytest.approx(0.0, abs=1e-6)


def test_margin_loss_penalizes_wrong_class():
    lengths = jnp.array([[0.95, 0.8, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05,
                          0.05, 0.05]])
    loss = capsnet.margin_loss(lengths, jnp.array([0]))
    assert float(loss) > 0.1


def test_training_reduces_loss():
    params = capsnet.init_params(KEY, SMOKE)
    dc = DataConfig(kind="mnist", global_batch=16)
    losses, accs = [], []
    for step in range(120):
        b = mnist_batch(dc, step, image_hw=14)
        params, m = capsnet.train_step(params, b["images"], b["labels"],
                                       SMOKE, lr=3e-2)
        losses.append(float(m["loss"]))
        accs.append(float(m["accuracy"]))
    assert np.isfinite(losses).all()
    # plain-SGD margin loss falls slowly but monotonically on average
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.01
    assert np.mean(accs[-40:]) > 0.12      # above 10% chance (batch=16 noise)


def test_decoder_masks_with_labels_when_given():
    """Training semantics (Sabour et al.): the decoder reconstructs the
    LABELED capsule, not the argmax one."""
    params = capsnet.init_params(KEY, SMOKE)
    imgs = jax.random.uniform(KEY, (4, 14, 14, 1))
    out = capsnet.forward(params, imgs, SMOKE)
    pred = np.asarray(jnp.argmax(out["lengths"], -1))
    wrong = jnp.asarray((pred + 1) % SMOKE.num_classes)
    out_lbl = capsnet.forward(params, imgs, SMOKE, labels=wrong)
    # class capsules identical; only the decoder mask changes
    np.testing.assert_array_equal(np.asarray(out["class_caps"]),
                                  np.asarray(out_lbl["class_caps"]))
    diff = np.abs(np.asarray(out["reconstruction"])
                  - np.asarray(out_lbl["reconstruction"])).max()
    assert diff > 1e-6
    # masking with the predicted class reproduces the argmax behaviour
    out_pred = capsnet.forward(params, imgs, SMOKE, labels=jnp.asarray(pred))
    np.testing.assert_allclose(np.asarray(out_pred["reconstruction"]),
                               np.asarray(out["reconstruction"]),
                               rtol=1e-6, atol=1e-6)


def test_recon_gradient_flows_through_labeled_capsule():
    """d(recon loss)/d(class capsules) is nonzero ONLY at the labeled
    capsule -- the regression the unconditional-argmax mask broke."""
    params = capsnet.init_params(KEY, SMOKE)
    v = jax.random.normal(KEY, (2, SMOKE.num_classes, SMOKE.class_dim))
    labels = jnp.array([3, 7])

    def recon_sum(v):
        return jnp.sum(capsnet.decode(params, v, SMOKE, labels=labels))

    g = np.asarray(jax.grad(recon_sum)(v))
    for b, lbl in enumerate([3, 7]):
        assert np.abs(g[b, lbl]).max() > 0.0
        others = np.delete(g[b], lbl, axis=0)
        np.testing.assert_array_equal(others, np.zeros_like(others))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_total_loss_recon_grad_only_through_labeled_capsule(backend):
    """Through ``total_loss`` (not just ``decode``), the masked
    reconstruction term backpropagates ONLY through the labeled capsule's
    pose -- on BOTH backends, now that ``total_loss`` takes ``backend=``
    and the Pallas path is differentiable.  Isolate the term by
    differencing out the margin loss (margin depends only on lengths, so
    its gradient w.r.t. the class capsules is mask-independent)."""
    params = capsnet.init_params(KEY, SMOKE)
    imgs = jax.random.uniform(KEY, (2, 14, 14, 1))
    labels = jnp.array([3, 7])

    def loss(params, recon_weight):
        return capsnet.total_loss(params, imgs, labels, SMOKE,
                                  recon_weight=recon_weight,
                                  backend=backend)[0]

    g_with = jax.grad(loss)(params, 1.0)
    g_without = jax.grad(loss)(params, 0.0)
    # recon-term gradient w.r.t. the ClassCaps weights, per capsule j:
    # cc_w is [I, J, D, C], so axis 1 indexes the class capsule.
    g_rec = np.asarray(g_with["cc_w"]) - np.asarray(g_without["cc_w"])
    per_caps = np.abs(g_rec).max(axis=(0, 2, 3))
    labeled = sorted(np.asarray(labels).tolist())
    # unlabeled capsules sit at the fp32 differencing noise floor, three
    # orders of magnitude below the labeled ones
    nonzero = [j for j in range(SMOKE.num_classes)
               if per_caps[j] > 1e-2 * per_caps.max()]
    assert nonzero == labeled, (nonzero, per_caps)
    # and the decoder itself only sees the labeled poses
    assert np.abs(np.asarray(g_with["dec_w1"])).max() > 0.0


def test_total_loss_reconstructs_labeled_capsule():
    params = capsnet.init_params(KEY, SMOKE)
    imgs = jax.random.uniform(KEY, (3, 14, 14, 1))
    labels = jnp.array([1, 2, 3])
    _, metrics = capsnet.total_loss(params, imgs, labels, SMOKE)
    out = capsnet.forward(params, imgs, SMOKE, labels=labels)
    flat = imgs.reshape(3, -1)
    want = jnp.mean(jnp.sum(jnp.square(out["reconstruction"] - flat), -1))
    assert float(metrics["recon_loss"]) == pytest.approx(float(want))


def test_pallas_capsnet_head_equivalence():
    """core.capsnet votes+routing == kernels (caps_votes + fused routing)."""
    from repro.kernels import ops
    cfg = SMOKE
    params = capsnet.init_params(KEY, cfg)
    u = capsnet.squash(jax.random.normal(KEY, (2, cfg.num_primary,
                                               cfg.primary_dim)))
    want_votes = capsnet.compute_votes(u, params["cc_w"])
    w = params["cc_w"].transpose(0, 1, 2, 3).reshape(
        cfg.num_primary, cfg.num_classes * cfg.class_dim, cfg.primary_dim)
    got_votes = ops.caps_votes(u, w, block_i=16)
    np.testing.assert_allclose(
        np.asarray(got_votes),
        np.asarray(want_votes.reshape(2, cfg.num_primary, -1)),
        rtol=1e-5, atol=1e-5)
    want_v = capsnet.routing_by_agreement(want_votes, cfg.routing_iters)
    got_v = ops.routing(got_votes, iters=cfg.routing_iters,
                        num_classes=cfg.num_classes)
    np.testing.assert_allclose(np.asarray(got_v),
                               np.asarray(want_v.reshape(2, -1)),
                               rtol=1e-5, atol=1e-5)
