"""benchmarks/run.py --baseline gate: median speed normalization, gate=False
exclusion, and regression detection (the CI perf-trajectory check)."""

from benchmarks.run import compare_baseline


def _rows(**named_us):
    return [dict(name=n, us_per_call=us, derived="") for n, us in
            named_us.items()]


BASE = dict(rows=_rows(a=100.0, b=200.0, c=400.0, d=800.0, plan=0.0))


def test_identical_runs_pass():
    assert compare_baseline(BASE["rows"], BASE, 1.5) == []


def test_uniform_machine_slowdown_absorbed():
    slow = _rows(a=300.0, b=600.0, c=1200.0, d=2400.0, plan=0.0)
    assert compare_baseline(slow, BASE, 1.5) == []


def test_single_regression_flagged_against_median():
    bad = _rows(a=100.0, b=200.0, c=400.0, d=2400.0, plan=0.0)
    regs = compare_baseline(bad, BASE, 1.5)
    assert [r["name"] for r in regs] == ["d"]
    assert regs[0]["ratio"] == 3.0


def test_regression_survives_machine_slowdown():
    """2x slower machine AND one row 3x slower on top of that."""
    bad = _rows(a=200.0, b=400.0, c=800.0, d=4800.0, plan=0.0)
    regs = compare_baseline(bad, BASE, 1.5)
    assert [r["name"] for r in regs] == ["d"]
    assert regs[0]["ratio"] == 3.0


def test_speedups_and_new_rows_never_flag():
    cur = _rows(a=50.0, b=100.0, c=200.0, d=400.0, e=999.0)
    assert compare_baseline(cur, BASE, 1.5) == []


def test_zero_and_ungated_rows_excluded():
    cur = _rows(a=100.0, b=200.0, c=400.0, d=800.0)
    cur.append(dict(name="serving", us_per_call=5000.0, derived="",
                    gate=False))
    base = dict(rows=BASE["rows"]
                + [dict(name="serving", us_per_call=100.0, derived="")])
    assert compare_baseline(cur, base, 1.5) == []
    # the same row WITH gating would have been flagged
    cur[-1]["gate"] = True
    regs = compare_baseline(cur, base, 1.5)
    assert [r["name"] for r in regs] == ["serving"]


def test_empty_baseline_is_noop():
    assert compare_baseline(BASE["rows"], dict(rows=[]), 1.5) == []
