"""benchmarks/run.py --baseline gate: median speed normalization, gate=False
exclusion, regression detection (the CI perf-trajectory check), and the
artifact schema/staleness validation that keeps the gate honest."""

import pytest

from benchmarks.run import (BaselineSchemaError, check_baseline_schema,
                            compare_baseline)


def _rows(**named_us):
    return [dict(name=n, us_per_call=us, derived="") for n, us in
            named_us.items()]


BASE = dict(rows=_rows(a=100.0, b=200.0, c=400.0, d=800.0, plan=0.0))


def test_identical_runs_pass():
    assert compare_baseline(BASE["rows"], BASE, 1.5) == []


def test_uniform_machine_slowdown_absorbed():
    slow = _rows(a=300.0, b=600.0, c=1200.0, d=2400.0, plan=0.0)
    assert compare_baseline(slow, BASE, 1.5) == []


def test_single_regression_flagged_against_median():
    bad = _rows(a=100.0, b=200.0, c=400.0, d=2400.0, plan=0.0)
    regs = compare_baseline(bad, BASE, 1.5)
    assert [r["name"] for r in regs] == ["d"]
    assert regs[0]["ratio"] == 3.0


def test_regression_survives_machine_slowdown():
    """2x slower machine AND one row 3x slower on top of that."""
    bad = _rows(a=200.0, b=400.0, c=800.0, d=4800.0, plan=0.0)
    regs = compare_baseline(bad, BASE, 1.5)
    assert [r["name"] for r in regs] == ["d"]
    assert regs[0]["ratio"] == 3.0


def test_speedups_and_new_rows_never_flag():
    cur = _rows(a=50.0, b=100.0, c=200.0, d=400.0, e=999.0)
    assert compare_baseline(cur, BASE, 1.5) == []


def test_zero_and_ungated_rows_excluded():
    cur = _rows(a=100.0, b=200.0, c=400.0, d=800.0)
    cur.append(dict(name="serving", us_per_call=5000.0, derived="",
                    gate=False))
    base = dict(rows=BASE["rows"]
                + [dict(name="serving", us_per_call=100.0, derived="")])
    assert compare_baseline(cur, base, 1.5) == []
    # the same row WITH gating would have been flagged
    cur[-1]["gate"] = True
    regs = compare_baseline(cur, base, 1.5)
    assert [r["name"] for r in regs] == ["serving"]


def test_empty_baseline_is_noop():
    assert compare_baseline(BASE["rows"], dict(rows=[]), 1.5) == []


ALL_MODULES = ["capsule", "kernels"]


def _artifact(rows, modules=ALL_MODULES):
    return dict(modules=modules, failures=[], python="3.12", rows=rows)


class TestBaselineSchema:
    def test_healthy_baseline_passes(self):
        check_baseline_schema(_artifact(BASE["rows"]), BASE["rows"],
                              ALL_MODULES)

    def test_missing_rows_list_rejected(self):
        with pytest.raises(BaselineSchemaError, match="no 'rows' list"):
            check_baseline_schema(dict(modules=ALL_MODULES), BASE["rows"],
                                  ALL_MODULES)
        with pytest.raises(BaselineSchemaError, match="no 'rows' list"):
            check_baseline_schema(dict(rows={"a": 1.0}), BASE["rows"],
                                  ALL_MODULES)

    def test_nameless_row_rejected(self):
        bad = _artifact(BASE["rows"] + [dict(us_per_call=5.0)])
        with pytest.raises(BaselineSchemaError, match="no string 'name'"):
            check_baseline_schema(bad, BASE["rows"], ALL_MODULES)

    def test_malformed_us_per_call_rejected(self):
        for us in ("12.0", -1.0, True):
            bad = _artifact(BASE["rows"] + [dict(name="x", us_per_call=us)])
            with pytest.raises(BaselineSchemaError,
                               match="non-negative number"):
                check_baseline_schema(bad, BASE["rows"], ALL_MODULES)

    def test_duplicate_name_rejected(self):
        bad = _artifact(BASE["rows"] + [dict(name="a", us_per_call=1.0)])
        with pytest.raises(BaselineSchemaError, match="'a' appears twice"):
            check_baseline_schema(bad, BASE["rows"], ALL_MODULES)

    def test_stale_row_named_in_error(self):
        """A renamed benchmark leaves its old row gating nothing."""
        stale = _artifact(BASE["rows"]
                          + [dict(name="old_name", us_per_call=50.0)])
        with pytest.raises(BaselineSchemaError,
                           match=r"stale.*old_name.*refresh"):
            check_baseline_schema(stale, BASE["rows"], ALL_MODULES)

    def test_subset_module_run_never_flags_stale(self):
        """A run covering fewer modules than the baseline recorded
        legitimately misses rows -- no staleness signal."""
        stale = _artifact(BASE["rows"]
                          + [dict(name="old_name", us_per_call=50.0)])
        check_baseline_schema(stale, BASE["rows"], ["capsule"])

    def test_untimed_and_ungated_rows_never_stale(self):
        """0.0-us derived rows and gate=False observations carry no perf
        signal, so their absence from a run is not staleness."""
        base = _artifact(BASE["rows"]
                         + [dict(name="derived_only", us_per_call=0.0),
                            dict(name="wall_clock", us_per_call=9.0,
                                 gate=False)])
        check_baseline_schema(base, BASE["rows"], ALL_MODULES)
