"""Deep routing-capsule stacks: the layer-graph plan compiler.

Property tests over random 1-4-block stacks (ragged / non-power-of-two
capsule counts): pallas-vs-jnp forward parity, gradient parity through
the REVERSIBLE backward (which recomputes each residual block's input
from its output instead of saving activations), per-layer ``PlanError``s
naming the offending layer instance and the largest feasible batch,
per-instance PMU phase naming for repeated layers, and the
flat-in-depth activation-residency model.  The empty-stack (MNIST)
config must compile to the SAME plan as the historical fixed-3-op
pipeline -- schedules and outputs bit-identical.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import analysis, capsnet, dse, execplan, pmu
from repro.core.capsnet import (CapsLayerSpec, CapsNetConfig, ResCapsBlock,
                                routing_stack_ref)
from repro.core.execplan import (BWD_SUFFIX, FUSED_NAME, PlanError,
                                 activation_residency_bytes, compile_plan)

KEY = jax.random.PRNGKey(0)
TOL = 1e-5


@pytest.fixture(scope="module", autouse=True)
def _release_jit_caches():
    # This module jits many large interpret-mode stacks; drop the traced/
    # compiled executables afterwards so the full-suite process does not
    # carry the accumulated allocator state into the LM compile tests.
    yield
    jax.clear_caches()

# Odd image -> pc grid 4x4; groups=3 gives 48 primary capsules (ragged
# against every power-of-two i-tile the planner prefers).
BASE = dict(image_hw=14, conv1_channels=16, conv1_kernel=5, pc_kernel=3,
            num_primary_groups=3, primary_dim=4, class_dim=8,
            use_decoder=False)


def _data(cfg, batch=1):
    params = capsnet.init_params(KEY, cfg)
    imgs = jax.random.uniform(KEY, (batch, cfg.image_hw, cfg.image_hw,
                                    cfg.in_channels))
    labels = jax.random.randint(KEY, (batch,), 0, cfg.num_classes)
    return params, imgs, labels


# ---------------------------------------------------------------------------
# Stack resolution / parameter shapes
# ---------------------------------------------------------------------------

def test_empty_stack_is_single_classcaps_layer():
    stack = CapsNetConfig(**BASE).routing_stack()
    assert len(stack) == 1
    (lay,) = stack
    assert lay.name == FUSED_NAME and lay.param == "cc_w"
    assert not lay.residual


def test_rescaps_block_halves_split_the_capsule_axis():
    cfg = CapsNetConfig(**BASE, caps_layers=(ResCapsBlock(),))
    f, g, final = cfg.routing_stack()
    assert (f.half, g.half) == ("f", "g")
    # 48 capsules -> uneven-safe split 24/24; F consumes x2, emits x1
    assert f.in_caps + f.num_caps == cfg.num_primary
    assert g.in_caps == f.num_caps and g.num_caps == f.in_caps
    assert final.in_caps == cfg.num_primary
    params = capsnet.init_params(KEY, cfg)
    assert params["cc0_w"].shape == (f.in_caps, f.num_caps, f.caps_dim,
                                     f.in_dim)
    assert params["cc_w"].shape == (final.in_caps, final.num_caps,
                                    final.caps_dim, final.in_dim)


def test_plain_layer_rewires_final_weight_shape():
    cfg = CapsNetConfig(**BASE, caps_layers=(CapsLayerSpec(10, 6),))
    params = capsnet.init_params(KEY, cfg)
    assert params["cc0_w"].shape == (cfg.num_primary, 10, 6,
                                     cfg.primary_dim)
    assert params["cc_w"].shape == (10, cfg.num_classes, cfg.class_dim, 6)


def test_bad_stack_entries_raise():
    with pytest.raises(TypeError, match="caps_layers\\[0\\]"):
        CapsNetConfig(**BASE, caps_layers=("nope",)).routing_stack()
    with pytest.raises(ValueError, match="caps_layers\\[1\\]"):
        CapsNetConfig(**BASE, caps_layers=(
            CapsLayerSpec(1, 4), ResCapsBlock())).routing_stack()


# ---------------------------------------------------------------------------
# MNIST (empty stack) unchanged: same plan, same outputs
# ---------------------------------------------------------------------------

def test_mnist_plan_schedules_unchanged_by_graph_compiler():
    """The one-layer case must reduce to the historical fixed pipeline:
    same op names, same fused schedule, same profile coverage."""
    plan = compile_plan(CapsNetConfig(), batch=4, train=True)
    assert [op.name for op in plan.ops] == [
        "Conv1", "PrimaryCaps", FUSED_NAME,
        FUSED_NAME + BWD_SUFFIX, "PrimaryCaps" + BWD_SUFFIX,
        "Conv1" + BWD_SUFFIX]
    want = [p.name for p in analysis.capsnet_profiles()]
    assert [p.name for p in plan.profiles][:5] == want


def test_stack_profiles_single_layer_matches_fixed_model():
    assert (analysis.capsnet_stack_profiles()
            == analysis.capsnet_profiles())


# ---------------------------------------------------------------------------
# Property: random 1-4-block stacks, ragged dims -- forward + grad parity
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(num_blocks=st.integers(min_value=1, max_value=4),
       groups=st.sampled_from([3, 4]),
       lead=st.sampled_from([None, (14, 6), (11, 4)]))
def test_stack_forward_and_grad_parity(num_blocks, groups, lead):
    """pallas == jnp through arbitrary residual stacks; the gradient runs
    the reversible segment VJP (inputs recomputed, not saved)."""
    layers = (() if lead is None else (CapsLayerSpec(*lead),)) \
        + (ResCapsBlock(routing_iters=2),) * num_blocks
    cfg = CapsNetConfig(**{**BASE, "num_primary_groups": groups},
                        caps_layers=layers)
    params, imgs, labels = _data(cfg, batch=2)

    want = capsnet.forward(params, imgs, cfg)
    plan = compile_plan(cfg, batch=2)
    got = capsnet.forward(params, imgs, cfg, backend="pallas", plan=plan)
    np.testing.assert_allclose(np.asarray(got["lengths"]),
                               np.asarray(want["lengths"]),
                               rtol=TOL, atol=TOL)

    tplan = compile_plan(cfg, batch=2, train=True)
    gp = jax.grad(lambda p: capsnet.total_loss(
        p, imgs, labels, cfg, backend="pallas", plan=tplan)[0])(params)
    gj = jax.grad(lambda p: capsnet.total_loss(
        p, imgs, labels, cfg)[0])(params)
    for k in gj:
        ref = np.asarray(gj[k])
        scale = max(np.abs(ref).max(), 1e-3)
        np.testing.assert_allclose(np.asarray(gp[k]) / scale, ref / scale,
                                   rtol=TOL, atol=TOL, err_msg=k)


def test_pipelined_deep_stack_matches_reference():
    """A plain first layer keeps the PrimaryCaps pipeline eligible; a
    residual first half silently falls back to the per-op pair."""
    cfg = CapsNetConfig(**BASE, caps_layers=(CapsLayerSpec(14, 6),
                                             ResCapsBlock()))
    params, imgs, _ = _data(cfg, batch=2)
    pplan = compile_plan(cfg, batch=2, pipeline=True)
    assert pplan.ops[1].name == execplan.PIPE_NAME
    want = capsnet.forward(params, imgs, cfg)
    got = capsnet.forward(params, imgs, cfg, backend="pallas", plan=pplan)
    np.testing.assert_allclose(np.asarray(got["lengths"]),
                               np.asarray(want["lengths"]),
                               rtol=TOL, atol=TOL)

    res_first = CapsNetConfig(**BASE, caps_layers=(ResCapsBlock(),))
    rplan = compile_plan(res_first, batch=2, pipeline=True)
    assert [op.name for op in rplan.ops][:2] == ["Conv1", "PrimaryCaps"]


def test_routing_stack_ref_reduces_to_plain_routing():
    cfg = CapsNetConfig(**BASE)
    params = capsnet.init_params(KEY, cfg)
    u = jax.random.normal(KEY, (2, cfg.num_primary, cfg.primary_dim))
    want = capsnet.routing_by_agreement(
        capsnet.compute_votes(u, params["cc_w"]), cfg.routing_iters)
    np.testing.assert_allclose(np.asarray(routing_stack_ref(params, u, cfg)),
                               np.asarray(want), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Property: per-layer PlanError naming + largest feasible batch
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(width=st.integers(min_value=500, max_value=900))
def test_plan_error_names_failing_layer_and_feasible_batch(width):
    """A stack whose INTERMEDIATE layer blows the budget fails with that
    layer's instance name (not the final ClassCaps layer's) and reports
    the largest batch its streamed floor could serve."""
    cfg = CapsNetConfig(**BASE, caps_layers=(CapsLayerSpec(width, 8),))
    budget = 300_000
    with pytest.raises(PlanError) as exc:
        compile_plan(cfg, batch=256, vmem_budget=budget)
    msg = str(exc.value)
    # the failing instance is the layer FED BY the wide one: [0] consumes
    # 48 capsules cheaply; the final layer consumes `width` capsules --
    # whichever raised must name itself and the feasible batch.
    assert msg.startswith(f"{FUSED_NAME}") and "batch=256" in msg
    assert "largest feasible batch is" in msg
    n = int(msg.rsplit("largest feasible batch is", 1)[1].split()[0])
    assert 0 <= n < 256
    if n > 0:
        compile_plan(cfg, batch=n, vmem_budget=budget)   # boundary plans


def test_plan_error_names_intermediate_instance():
    """Force the INTERMEDIATE instance itself to be the infeasible one:
    its huge fan-in makes layer [0] the first to blow the budget."""
    cfg = CapsNetConfig(**{**BASE, "num_primary_groups": 64},
                        caps_layers=(CapsLayerSpec(8, 4),))
    with pytest.raises(PlanError, match=rf"{FUSED_NAME}\[0\]"):
        compile_plan(cfg, batch=512, vmem_budget=250_000)


# ---------------------------------------------------------------------------
# Per-instance phases: pmu / dse gate repeated layers separately
# ---------------------------------------------------------------------------

def test_phase_groups_suffix_repeated_layers():
    cfg = CapsNetConfig(**BASE, caps_layers=(ResCapsBlock(),))
    plan = compile_plan(cfg, batch=1, train=True)
    names = [g[0] for g in plan.phase_groups()]
    assert len(set(names)) == len(names)
    assert f"{FUSED_NAME}[0]" in names and f"{FUSED_NAME}[1]" in names
    assert f"{FUSED_NAME}[1]{BWD_SUFFIX}" in names
    covered = [p for _, ps in plan.phase_groups() for p in ps]
    assert len(set(covered)) == len(covered)     # no collapsed profiles
    # the PMU schedule carries one gating phase per layer instance
    mem = pmu.SRAMConfig(name="accum", capacity_bytes=1 << 20, ports=1,
                         sectors_per_bank=8)
    sched = pmu.schedule_from_plan(mem, plan)
    assert [ph.name for ph in sched.phases] == names


def test_dse_rejects_colliding_profile_names():
    profiles = analysis.capsnet_profiles()
    org = dse.design_organizations(profiles)["PG-SMP"]
    with pytest.raises(ValueError, match="duplicate operation profile"):
        dse.evaluate(org, [profiles[2], profiles[2]])


def test_dse_scores_deep_stack_plan():
    cfg = CapsNetConfig(**BASE, caps_layers=(ResCapsBlock(),))
    plan = compile_plan(cfg, batch=1)
    best = dse.best_design(plan=plan)
    assert best.total_mj > 0


# ---------------------------------------------------------------------------
# Reversible activation residency: flat in depth
# ---------------------------------------------------------------------------

def test_activation_residency_flat_in_depth():
    base = CapsNetConfig(**BASE, caps_layers=(ResCapsBlock(),))
    rev1 = activation_residency_bytes(base, batch=4)
    for n in (2, 4, 8):
        cfg = CapsNetConfig(**BASE, caps_layers=(ResCapsBlock(),) * n)
        assert activation_residency_bytes(cfg, batch=4) == rev1
        saved = activation_residency_bytes(cfg, batch=4, reversible=False)
        assert saved > rev1            # linear-in-depth baseline grows
    plan = compile_plan(base, batch=4, train=True)
    assert plan.activation_residency_bytes() == rev1
