"""benchmarks/run.py --trend: the monotonic-slowdown detector that
catches perf drift the per-commit --baseline gate (1.5x factor) never
fires on, unit-tested on synthetic artifact histories."""

import os

from benchmarks.run import BASELINE_NAME, _trend_paths, detect_trend


def _artifact(**named_us):
    return dict(rows=[dict(name=n, us_per_call=us, derived="")
                      for n, us in named_us.items()])


def _history(*per_run):
    return [_artifact(**run) for run in per_run]


def test_flat_history_clean():
    hist = _history(*[dict(a=100.0, b=200.0, c=400.0)] * 5)
    assert detect_trend(hist) == []


def test_creeping_row_below_gate_threshold_flagged():
    """+15% per run: every adjacent step is far below the gate's 1.5x,
    but the cumulative 1.52x drift is exactly what --trend exists for."""
    hist = _history(
        dict(a=100.0, b=200.0, c=400.0),
        dict(a=100.0, b=200.0, c=460.0),
        dict(a=100.0, b=200.0, c=529.0),
        dict(a=100.0, b=200.0, c=608.0),
    )
    flagged = detect_trend(hist)
    assert [t["name"] for t in flagged] == ["c"]
    assert flagged[0]["ratio"] > 1.5
    assert flagged[0]["points"] == 4


def test_uniform_machine_slowdown_not_flagged():
    """Every run 2x slower than the last (a slower runner, not a
    regression): the median normalization absorbs it entirely."""
    hist = _history(
        dict(a=100.0, b=200.0, c=400.0),
        dict(a=200.0, b=400.0, c=800.0),
        dict(a=400.0, b=800.0, c=1600.0),
    )
    assert detect_trend(hist) == []


def test_creep_survives_machine_speed_noise():
    """Machine speed swings run to run AND one row drifts on top: only
    the drifting row is flagged."""
    hist = _history(
        dict(a=100.0, b=200.0, c=400.0),
        dict(a=210.0, b=420.0, c=1008.0),     # 2.1x machine, c +20%
        dict(a=90.0, b=180.0, c=518.0),       # 0.9x machine, c +20% more
    )
    flagged = detect_trend(hist)
    assert [t["name"] for t in flagged] == ["c"]


def test_non_monotonic_noise_not_flagged():
    """A row that spikes and recovers is the gate's business (if it ever
    exceeds the factor), not a trend."""
    hist = _history(
        dict(a=100.0, b=200.0, c=400.0),
        dict(a=100.0, b=200.0, c=560.0),
        dict(a=100.0, b=200.0, c=410.0),
        dict(a=100.0, b=200.0, c=570.0),
    )
    assert detect_trend(hist) == []


def test_small_total_drift_not_flagged():
    """Monotone but tiny (+3% total): below min_total, stays quiet."""
    hist = _history(
        dict(a=100.0, b=200.0, c=400.0),
        dict(a=100.0, b=200.0, c=406.0),
        dict(a=100.0, b=200.0, c=412.0),
    )
    assert detect_trend(hist) == []


def test_needs_min_points():
    hist = _history(dict(a=100.0), dict(a=900.0))
    assert detect_trend(hist) == []


def test_trend_paths_exclude_committed_baseline(tmp_path):
    """A directory --trend argument must NOT pick up BENCH_baseline.json:
    a freshly refreshed baseline has the newest mtime and would land as
    the 'newest' trend point, corrupting the chronology."""
    names = ["BENCH_run1.json", "BENCH_run2.json", BASELINE_NAME]
    for k, name in enumerate(names):
        p = tmp_path / name
        p.write_text("{}")
        os.utime(p, (1_000_000 + k, 1_000_000 + k))   # baseline newest
    paths = _trend_paths([str(tmp_path)], window=5)
    assert [p.name for p in paths] == ["BENCH_run1.json", "BENCH_run2.json"]
    # naming the baseline explicitly still works (the user asked for it)
    explicit = _trend_paths([str(tmp_path / BASELINE_NAME)], window=5)
    assert [p.name for p in explicit] == [BASELINE_NAME]
    # window still trims the oldest points after the exclusion
    assert [p.name for p in _trend_paths([str(tmp_path)], window=1)] == [
        "BENCH_run2.json"]


def test_untimed_and_missing_rows_ignored():
    """plan/* rows (0.0 us) and rows absent from any artifact carry no
    trend signal; rows present everywhere still gate."""
    hist = _history(
        dict(a=100.0, b=200.0, c=400.0, plan=0.0, old=50.0),
        dict(a=130.0, b=200.0, c=400.0, plan=0.0),
        dict(a=169.0, b=200.0, c=400.0, plan=0.0, new=70.0),
    )
    flagged = detect_trend(hist)
    assert [t["name"] for t in flagged] == ["a"]
