"""Tests for the static plan auditor (``verify.lowering``) and the
shared runtime-invariant checker (``verify.invariants``).

The positive direction (every registered arch audits clean) is what
``python -m repro.verify`` sweeps in CI; here we pin a representative
slice plus the NEGATIVE direction: seeded wrong plan/kernel pairs that
the auditor must catch, and seeded inconsistent stats dicts the
invariant checker must flag.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import CAPSNET_ARCHS, get_config
from repro.core import execplan
from repro.verify import audit_config, audit_op, check_engine_stats
from repro.verify import lowering


def _checks_by_name(audit):
    return {c.name: c for c in audit.checks}


# ---------------------------------------------------------------------------
# Clean audits: every registered arch, plus train/pipeline coverage
# ---------------------------------------------------------------------------

class TestCleanAudit:

    @pytest.mark.parametrize("arch", CAPSNET_ARCHS)
    def test_full_budget_pipelined(self, arch):
        rep = audit_config(get_config(arch), batch=1, pipeline=True)
        assert rep.ok, [f"{op}: {c.name} {c.detail}"
                        for op, c in rep.failures()]

    def test_train_plan_covers_backward_tracers(self):
        rep = audit_config(get_config("capsnet-mnist"), batch=2,
                           train=True)
        assert rep.ok, [f"{op}: {c.name} {c.detail}"
                        for op, c in rep.failures()]
        kernels = {o.kernel for o in rep.ops}
        assert "conv_im2col_bwd" in kernels
        assert "votes_routing_bwd" in kernels

    def test_degraded_budget_audits_clean(self):
        # The quarter-budget rung forces blocked im2col extraction
        # (patch_rows) and streamed routing -- the lowering must still
        # match the degraded model.
        plan, _rep = execplan.degrade_plan(
            get_config("capsnet-mnist"), execplan.VMEM_BYTES // 4,
            batch=4, pipeline=True)
        rep = lowering.audit_plan(plan, label="mnist-25%")
        assert rep.ok, [f"{op}: {c.name} {c.detail}"
                        for op, c in rep.failures()]


# ---------------------------------------------------------------------------
# Seeded regressions: a wrong model/kernel pair MUST be caught
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def svhn_plan():
    return execplan.compile_plan(get_config("capsnet-svhn"), batch=4,
                                 pipeline=True)


@pytest.fixture(scope="module")
def svhn_routing_op(svhn_plan):
    # Multi-pass streamed op: its W stream crosses HBM n_passes times,
    # so every seeded lie below is observable in the lowering.
    (op,) = [o for o in svhn_plan.ops
             if o.kernel == "primary_routing"]
    assert op.n_passes and op.n_passes > 1
    return op


class TestSeededDrift:

    def test_understated_vmem_is_caught(self, svhn_plan, svhn_routing_op):
        lie = dataclasses.replace(svhn_routing_op,
                                  vmem_bytes=svhn_routing_op.vmem_bytes // 2)
        audit = audit_op(svhn_plan, lie)
        assert not audit.ok
        assert not _checks_by_name(audit)["vmem-under-model"].ok

    def test_overstated_vmem_is_caught(self, svhn_plan, svhn_routing_op):
        lie = dataclasses.replace(svhn_routing_op,
                                  vmem_bytes=svhn_routing_op.vmem_bytes * 4)
        audit = audit_op(svhn_plan, lie)
        assert not _checks_by_name(audit)["vmem-over-model"].ok

    def test_wrong_hbm_traffic_is_caught(self, svhn_plan, svhn_routing_op):
        lie = dataclasses.replace(svhn_routing_op,
                                  hbm_bytes=svhn_routing_op.hbm_bytes * 10)
        audit = audit_op(svhn_plan, lie)
        assert not _checks_by_name(audit)["hbm-traffic"].ok

    def test_wrong_pass_count_is_caught(self, svhn_plan, svhn_routing_op):
        lie = dataclasses.replace(svhn_routing_op,
                                  n_passes=svhn_routing_op.n_passes + 3)
        audit = audit_op(svhn_plan, lie)
        assert not _checks_by_name(audit)["w-pass-count"].ok

    def test_honest_op_passes_the_same_checks(self, svhn_plan,
                                              svhn_routing_op):
        audit = audit_op(svhn_plan, svhn_routing_op)
        assert audit.ok, [f"{c.name}: {c.detail}"
                          for c in audit.failures()]


# ---------------------------------------------------------------------------
# Zero-intermediate proof: the jaxpr shape scan itself
# ---------------------------------------------------------------------------

class TestShapeCheck:

    B, I, J, D = 2, 8, 4, 4

    def _outer_eqns(self, fn, *avals):
        jaxpr = jax.make_jaxpr(fn)(*avals)
        calls, outer = [], []
        lowering._walk(jaxpr.jaxpr, calls, outer)
        return outer

    def test_materialized_uhat_fails_the_claim(self):
        B, I, J, D = self.B, self.I, self.J, self.D

        def leaky(u, w):
            uhat = jnp.einsum("bid,idj->bij", u, w)   # (B, I, J) in HBM
            return uhat.sum()

        outer = self._outer_eqns(
            leaky,
            jax.ShapeDtypeStruct((B, I, D), jnp.float32),
            jax.ShapeDtypeStruct((I, D, J), jnp.float32))
        chk = lowering._shape_check(outer, {(B, I, J)}, set(),
                                    "uhat-never-in-hbm")
        assert not chk.ok
        assert str((B, I, J)) in chk.detail

    def test_clean_function_passes_the_claim(self):
        B, I, J, D = self.B, self.I, self.J, self.D

        def tight(u, w):
            return jnp.einsum("bid,idj->bj", u, w)    # (B, J) only

        outer = self._outer_eqns(
            tight,
            jax.ShapeDtypeStruct((B, I, D), jnp.float32),
            jax.ShapeDtypeStruct((I, D, J), jnp.float32))
        chk = lowering._shape_check(outer, {(B, I, J)}, set(),
                                    "uhat-never-in-hbm")
        assert chk.ok

    def test_allowed_shapes_are_exempt(self):
        B, I, J, D = self.B, self.I, self.J, self.D

        def leaky(u, w):
            return jnp.einsum("bid,idj->bij", u, w).sum()

        outer = self._outer_eqns(
            leaky,
            jax.ShapeDtypeStruct((B, I, D), jnp.float32),
            jax.ShapeDtypeStruct((I, D, J), jnp.float32))
        chk = lowering._shape_check(outer, {(B, I, J)}, {(B, I, J)},
                                    "uhat-never-in-hbm")
        assert chk.ok


# ---------------------------------------------------------------------------
# Runtime-counter invariants (verify.invariants)
# ---------------------------------------------------------------------------

def _healthy_stats():
    return {
        "submitted": 5, "ok": 3, "timeout": 1, "error": 0, "shed": 1,
        "quarantined": 1, "n_shards": 2,
        "per_shard": [
            {"ok": 2, "timeout": 0, "error": 0, "shed": 0,
             "quarantined": 1},
            {"ok": 1, "timeout": 1, "error": 0, "shed": 0,
             "quarantined": 0},
        ],
        "queue_bucket": {"ok": 0, "timeout": 0, "error": 0, "shed": 1},
    }


class TestEngineStatsChecker:

    def test_terminal_statuses_pinned_to_serving(self):
        # verify.invariants mirrors the tuple instead of importing the
        # serving stack; this is the pin that keeps the mirror honest.
        from repro.serve.capsule import TERMINAL_STATUSES as serve_ts
        from repro.verify.invariants import TERMINAL_STATUSES as verify_ts
        assert set(serve_ts) == set(verify_ts)

    def test_healthy_stats_pass(self):
        assert check_engine_stats(_healthy_stats()) == []

    def test_lost_request_is_flagged(self):
        s = _healthy_stats()
        s["submitted"] += 1              # one submission never terminated
        problems = check_engine_stats(s)
        assert any("submitted" in p for p in problems)

    def test_missing_shard_row_is_flagged(self):
        s = _healthy_stats()
        s["per_shard"] = s["per_shard"][:1]
        problems = check_engine_stats(s)
        assert any("per-shard" in p for p in problems)

    def test_shard_counter_drift_is_flagged(self):
        s = _healthy_stats()
        s["per_shard"][0]["ok"] += 1     # shard claims a request twice
        problems = check_engine_stats(s)
        assert any(p.startswith("ok:") for p in problems)

    def test_quarantine_drift_is_flagged(self):
        s = _healthy_stats()
        s["quarantined"] = 7
        problems = check_engine_stats(s)
        assert any("quarantined" in p for p in problems)
