"""Sharding rules + a real small-mesh integration test (8 forced host
devices in a subprocess so the main test process keeps 1 device)."""

import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.launch.shapes import SHAPES, cell_supported, param_specs
from repro.parallel.sharding import (_spec_for_path, make_rules,
                                     param_pspecs)
import jax.numpy as jnp


def test_param_rules_tp_layout():
    assert _spec_for_path("embed", 2) == P("model", None)
    assert _spec_for_path("blocks/s0/q_proj", 3) == P(None, None, "model")
    assert _spec_for_path("blocks/s0/o_proj", 3) == P(None, "model", None)
    assert _spec_for_path("prefix/0/down_proj", 2) == P("model", None)
    assert _spec_for_path("blocks/s0/experts_gate", 4) == P(
        None, "model", None, None)
    assert _spec_for_path("blocks/s0/input_norm", 2) == P()
    assert _spec_for_path("shared/kv_down", 2) == P(None, None)


def test_param_pspecs_cover_all_leaves():
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    specs = param_specs(cfg, jnp.float32)
    ps = param_pspecs(specs)
    flat_p = jax.tree_util.tree_leaves(specs)
    flat_s = jax.tree_util.tree_leaves(
        ps, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)


def test_rules_sp_toggle():
    r_sp = make_rules(sp=True)
    r_nosp = make_rules(sp=False)
    assert r_sp.act("btd")[1] == "model"
    assert r_nosp.act("btd")[1] is None


def test_skip_policy():
    expect_long = {"gemma2-9b": True, "gemma3-12b": True,
                   "granite-3-2b": False, "gemma-7b": False,
                   "mamba2-370m": True, "phi3.5-moe-42b-a6.6b": False,
                   "deepseek-v2-lite-16b": True, "chameleon-34b": False,
                   "zamba2-1.2b": True}
    from repro.configs import get_config
    for arch, want in expect_long.items():
        cfg = get_config(arch)
        ok, _ = cell_supported(cfg, SHAPES["long_500k"])
        assert ok == want, arch
    hub = get_config("hubert-xlarge")
    assert not cell_supported(hub, SHAPES["decode_32k"])[0]
    assert not cell_supported(hub, SHAPES["long_500k"])[0]
    assert cell_supported(hub, SHAPES["train_4k"])[0]
    assert cell_supported(hub, SHAPES["prefill_32k"])[0]


def test_runnable_cell_count():
    """40 assigned cells minus the 6 documented skips = 34 runnable."""
    from repro.configs import LM_ARCHS, get_config
    runnable = sum(
        1 for a in LM_ARCHS for s in SHAPES
        if cell_supported(get_config(a), SHAPES[s])[0])
    assert runnable == 34


SUBPROCESS_SRC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.launch.steps import make_train_step
    from repro.models import init_model
    from repro.parallel.sharding import (ShardingCtx, make_rules,
                                         param_pspecs)
    from repro.train.optimizer import OptConfig, init_opt_state

    cfg = get_smoke_config("{arch}")
    from repro.parallel.compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = make_rules(False)
    shd = ShardingCtx(mesh, rules)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    batch = {{"inputs": jnp.zeros((4, 32), jnp.int32) + 3,
              "targets": jnp.ones((4, 32), jnp.int32)}}

    # sharded step
    pshard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_pspecs(params),
        is_leaf=lambda x: isinstance(x, P))
    params_sh = jax.device_put(params, pshard)
    step = jax.jit(make_train_step(cfg, OptConfig(warmup_steps=0),
                                   shd, compute_dtype=jnp.float32),
                   in_shardings=(pshard, None, None))
    _, _, m_sh = step(params_sh, opt, batch)

    # single-device reference
    step1 = jax.jit(make_train_step(cfg, OptConfig(warmup_steps=0),
                                    None, compute_dtype=jnp.float32))
    _, _, m1 = step1(params, opt, batch)
    print(json.dumps({{"sharded": float(m_sh["loss"]),
                       "single": float(m1["loss"])}}))
""")


@pytest.mark.parametrize("arch", ["granite-3-2b", "zamba2-1.2b",
                                  "deepseek-v2-lite-16b"])
def test_sharded_step_matches_single_device(arch, tmp_path):
    """Numerical equivalence: 2x4-mesh sharded train step == 1 device."""
    src = SUBPROCESS_SRC.format(arch=arch)
    out = subprocess.run([sys.executable, "-c", src], capture_output=True,
                         text=True, timeout=900,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root",
                              "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    np.testing.assert_allclose(res["sharded"], res["single"], rtol=2e-4)
