"""Training substrate: optimizer, data determinism, checkpoint round-trip
+ atomicity, fault-tolerant loop (NaN skip, preemption, resume), gradient
compression numerics."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.parallel.compress import (compress_grads_tree, ef_dequantize,
                                     ef_quantize)
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, DataIterator, batch_for_step
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.optimizer import (OptConfig, adamw_update, init_opt_state,
                                   lr_at)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_update():
    params = {"w": jnp.ones((4,)), "b": jnp.zeros((2,))}
    grads = {"w": jnp.full((4,), 0.5), "b": jnp.full((2,), -1.0)}
    cfg = OptConfig(peak_lr=0.1, warmup_steps=0, decay_steps=100,
                    weight_decay=0.0, clip_norm=1e9)
    state = init_opt_state(params)
    new_p, new_s, m = adamw_update(params, grads, state, cfg)
    # step 1: m_hat = g, v_hat = g^2 -> update = g/|g| = sign(g)
    lr = float(lr_at(jnp.asarray(1), cfg))
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               1.0 - lr * np.sign(0.5), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(new_p["b"]),
                               0.0 - lr * np.sign(-1.0), rtol=1e-4)


def test_lr_schedule_shape():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                    min_lr_ratio=0.1)
    lrs = [float(lr_at(jnp.asarray(s), cfg)) for s in range(0, 101, 5)]
    assert lrs[1] == pytest.approx(0.5)              # mid warmup
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[-1] == pytest.approx(0.1, abs=1e-3)   # floor


def test_grad_clipping():
    params = {"w": jnp.zeros((3,))}
    grads = {"w": jnp.full((3,), 1e6)}
    cfg = OptConfig(clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    _, _, m = adamw_update(params, grads, init_opt_state(params), cfg)
    assert float(m["grad_norm"]) > 1e5               # reported pre-clip


# ---------------------------------------------------------------------------
# Data determinism
# ---------------------------------------------------------------------------

def test_data_deterministic_per_step():
    dc = DataConfig(kind="lm", vocab_size=97, seq_len=16, global_batch=4)
    a = batch_for_step(dc, 7)
    b = batch_for_step(dc, 7)
    np.testing.assert_array_equal(np.asarray(a["inputs"]),
                                  np.asarray(b["inputs"]))
    c = batch_for_step(dc, 8)
    assert not np.array_equal(np.asarray(a["inputs"]),
                              np.asarray(c["inputs"]))


def test_data_skip_ahead_equals_sequential():
    dc = DataConfig(kind="lm", vocab_size=97, seq_len=8, global_batch=2)
    it1 = DataIterator(dc)
    for _ in range(5):
        next(it1)
    b5 = next(it1)
    it2 = DataIterator(dc)
    it2.skip_to(5)
    np.testing.assert_array_equal(next(it2)["inputs"], b5["inputs"])


def test_data_targets_shifted():
    dc = DataConfig(kind="lm", vocab_size=97, seq_len=16, global_batch=2)
    b = batch_for_step(dc, 0)
    np.testing.assert_array_equal(np.asarray(b["inputs"][:, 1:]),
                                  np.asarray(b["targets"][:, :-1]))


@given(hosts=st.sampled_from([1, 2, 4]))
@settings(max_examples=6, deadline=None)
def test_data_host_sharding_disjoint(hosts):
    dc = DataConfig(kind="lm", vocab_size=997, seq_len=8, global_batch=8)
    rows = [np.asarray(batch_for_step(dc, 3, host=h, num_hosts=hosts)
                       ["inputs"]) for h in range(hosts)]
    assert all(r.shape[0] == 8 // hosts for r in rows)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(tree, tmp_path, 3)
    restored, manifest = ckpt.restore(tree, tmp_path)
    assert manifest["step"] == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["nested"]["b"]),
                                  np.asarray(tree["nested"]["b"]))


def test_checkpoint_atomicity_no_commit_marker(tmp_path):
    tree = _tree()
    ckpt.save(tree, tmp_path, 1)
    # simulate a torn write: directory exists but no COMMIT marker
    (tmp_path / "step_00000002").mkdir()
    (tmp_path / "step_00000002" / "arrays.npz").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 1     # torn ckpt ignored
    restored, m = ckpt.restore(tree, tmp_path)
    assert m["step"] == 1


def test_checkpoint_gc_keeps_latest(tmp_path):
    cp = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        cp.save_async(tree, s)
    cp.wait()
    assert ckpt.committed_steps(tmp_path) == [3, 4]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(_tree(), tmp_path, 1)
    bad = {"a": jnp.zeros((3, 3)), "nested": {"b": jnp.ones((4,),
                                                            jnp.int32)}}
    with pytest.raises(ValueError):
        ckpt.restore(bad, tmp_path)


# ---------------------------------------------------------------------------
# Fault-tolerant loop
# ---------------------------------------------------------------------------

def _loop(tmp_path, total=8, ckpt_every=4):
    cfg = get_smoke_config("granite-3-2b")
    dc = DataConfig(kind="lm", vocab_size=cfg.vocab_size, seq_len=16,
                    global_batch=4)
    lc = LoopConfig(total_steps=total, ckpt_every=ckpt_every,
                    ckpt_dir=str(tmp_path / "ck"), log_every=1000,
                    heartbeat_path=str(tmp_path / "hb.json"))
    return TrainLoop(cfg, OptConfig(peak_lr=1e-3, warmup_steps=2), dc, lc)


def test_loop_runs_and_checkpoints(tmp_path):
    loop = _loop(tmp_path)
    hist = loop.run()
    assert len(hist) == 8
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert 8 in ckpt.committed_steps(tmp_path / "ck")
    hb = json.loads((tmp_path / "hb.json").read_text())
    assert hb["step"] == 8


def test_loop_resume_after_kill(tmp_path):
    loop1 = _loop(tmp_path, total=4, ckpt_every=4)
    loop1.run()
    # "restart the job" with a longer horizon: resumes from step 4
    loop2 = _loop(tmp_path, total=8, ckpt_every=4)
    hist = loop2.run(resume=True)
    assert hist[0]["step"] == 5
    assert loop2.step == 8


def test_loop_resume_loss_continuity(tmp_path):
    full = _loop(tmp_path / "x", total=8, ckpt_every=100)
    h_full = full.run()
    a = _loop(tmp_path / "y", total=4, ckpt_every=4)
    a.run()
    b = _loop(tmp_path / "y", total=8, ckpt_every=4)
    h_b = b.run(resume=True)
    # same data stream + same state => identical losses after resume
    np.testing.assert_allclose(h_full[-1]["loss"], h_b[-1]["loss"],
                               rtol=1e-4)


def test_loop_preemption(tmp_path):
    loop = _loop(tmp_path, total=100, ckpt_every=50)
    orig = loop._heartbeat

    def hb_and_stop(step, metrics):
        orig(step, metrics)
        if step >= 3:
            loop.request_stop()

    loop._heartbeat = hb_and_stop
    loop.run()
    assert loop.step < 100
    assert loop.step in ckpt.committed_steps(tmp_path / "ck")  # final save


def test_loop_nan_guard_ignores_stale_checkpoints(tmp_path):
    """Non-finite loss rolls back to THIS run's last committed step, not
    the directory's globally-latest: a stale later-step checkpoint from
    an abandoned run (here with an incompatible tree, so restoring it
    would raise a shape mismatch) must not be resurrected."""
    ckpt.save({"bogus": np.zeros((2, 2))}, tmp_path / "ck", 40)
    loop = _loop(tmp_path, total=6, ckpt_every=4)
    inner = loop._step_fn
    calls = {"n": 0}

    def poisoned(params, opt, batch):
        calls["n"] += 1
        params, opt, metrics = inner(params, opt, batch)
        if calls["n"] == 3:
            metrics = dict(metrics)
            metrics["loss"] = jnp.float32(np.nan)
        return params, opt, metrics

    loop._step_fn = poisoned
    hist = loop.run(resume=False)
    assert loop.nan_skips == 1
    assert loop.step == 6
    assert 3 not in [h["step"] for h in hist]   # poisoned batch skipped
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_loop_straggler_hook(tmp_path):
    seen = []
    loop = _loop(tmp_path, total=6)
    loop.on_straggler = lambda step, t: seen.append(step)
    # wrap step fn with an artificial stall on step 5
    inner = loop._step_fn
    calls = {"n": 0}

    def slow(params, opt, batch):
        calls["n"] += 1
        out = inner(params, opt, batch)
        jax.block_until_ready(out[0])
        if calls["n"] == 6:
            import time
            time.sleep(1.0)
        return out

    loop._step_fn = slow
    loop.run()
    assert seen, "straggler hook never fired"


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_ef_quantize_roundtrip_small_error():
    g = jax.random.normal(KEY, (128,)) * 0.01
    q, s, r = ef_quantize(g, None)
    deq = ef_dequantize(q, s)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(deq), np.asarray(g),
                               atol=float(s) + 1e-9)
    # residual == exact quantization error
    np.testing.assert_allclose(np.asarray(r),
                               np.asarray(g) - np.asarray(deq), atol=1e-9)


def test_error_feedback_unbiased_over_time():
    """Accumulated compressed updates converge to accumulated true grads."""
    g = 0.003 * jnp.ones((64,))
    res = jnp.zeros((64,))
    total = jnp.zeros((64,))
    for _ in range(50):
        (cg,), (res,) = (lambda t: (t[0], t[1]))(
            compress_grads_tree((g,), (res,)))
        total = total + cg
    np.testing.assert_allclose(np.asarray(total), 50 * 0.003,
                               rtol=0.02)


def test_compressed_psum_multidevice_semantics():
    """compressed_psum inside shard_map == plain mean-psum (within quant
    error), on a 1-device mesh with world=1."""
    from repro.parallel.compat import shard_map
    from repro.parallel.compress import compressed_psum
    mesh = jax.make_mesh((1,), ("d",))
    g = jax.random.normal(KEY, (32,)) * 0.01

    def f(x):
        out, _ = compressed_psum(x, "d", world=1)
        return out

    out = shard_map(f, mesh=mesh,
                    in_specs=jax.sharding.PartitionSpec(None),
                    out_specs=jax.sharding.PartitionSpec(None))(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=2e-4)


def test_elastic_restore_onto_resharded_mesh(tmp_path):
    """A checkpoint written by one topology restores onto another: the
    restore path reshards every leaf via the provided shardings
    (single-device CPU stands in for the new mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.parallel.compat import make_mesh

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.ones((4,))}
    ckpt.save(tree, tmp_path, 7)
    mesh = make_mesh((1,), ("model",))
    shardings = {"w": NamedSharding(mesh, P("model", None)),
                 "b": NamedSharding(mesh, P())}
    restored, manifest = ckpt.restore(tree, tmp_path, shardings=shardings)
    assert manifest["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding.spec == P("model", None)
