"""Minimal deterministic stand-in for the ``hypothesis`` package.

Activated by ``tests/conftest.py`` ONLY when the real hypothesis is not
installed, so the suite collects and runs everywhere.  It implements just
what this repo's tests use -- ``@given(**kwargs)`` with keyword strategies,
``@settings(max_examples=..., deadline=...)``, and the ``strategies``
submodule -- by enumerating boundary values first and then seeded
pseudo-random draws (every run sees the same examples).

If the real hypothesis IS installed it always wins: this directory is
appended to ``sys.path`` only on ImportError.
"""

from __future__ import annotations

import random

from . import strategies  # noqa: F401

__version__ = "0.0.fallback"
_SEED = 0xCA95


class settings:
    """Records max_examples; deadline and other knobs are accepted+ignored."""

    def __init__(self, max_examples: int = 20, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._fallback_settings = self
        return fn


def given(**strats):
    if not strats:
        raise TypeError("fallback @given supports keyword strategies only")

    def deco(fn):
        cfg = getattr(fn, "_fallback_settings", settings())

        def wrapper(*args, **kwargs):
            rng = random.Random(_SEED)
            streams = {name: s.example_stream(rng, cfg.max_examples)
                       for name, s in strats.items()}
            for idx in range(cfg.max_examples):
                drawn = {name: streams[name][idx] for name in strats}
                fn(*args, **drawn, **kwargs)
        # NOT functools.wraps: copying __wrapped__ would expose the strategy
        # parameters to pytest's fixture resolution.
        for attr in ("__name__", "__qualname__", "__module__", "__doc__"):
            setattr(wrapper, attr, getattr(fn, attr))
        return wrapper
    return deco
