"""Strategies for the fallback hypothesis shim: floats / integers /
sampled_from, each yielding boundary values first, then seeded draws."""

from __future__ import annotations


class SearchStrategy:
    def __init__(self, draw, edges=()):
        self._draw = draw
        self._edges = tuple(edges)

    def example_stream(self, rng, n: int) -> list:
        out = list(self._edges[:n])
        while len(out) < n:
            out.append(self._draw(rng))
        return out


def floats(min_value: float, max_value: float, **_ignored) -> SearchStrategy:
    mid = 0.5 * (min_value + max_value)
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value),
                          (min_value, max_value, mid))


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                          (min_value, max_value))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: rng.choice(elements), elements)
