"""Per-assigned-architecture smoke tests: reduced config of the same
family, one forward + one train step on CPU, asserting shapes + no NaNs
(deliverable f).  The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LM_ARCHS, get_config, get_smoke_config
from repro.launch.steps import make_train_step
from repro.models import init_model, lm_loss
from repro.models.config import count_params
from repro.train.optimizer import OptConfig, init_opt_state

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, t=16):
    if cfg.frontend == "audio_frames":
        inputs = jax.random.normal(KEY, (b, t, cfg.frontend_dim))
    else:
        inputs = jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)
    targets = jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)
    return {"inputs": inputs, "targets": targets}


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_model(KEY, cfg)
    batch = _batch(cfg)
    loss, metrics = lm_loss(params, batch, cfg)
    assert np.isfinite(float(loss)), f"{arch} forward loss not finite"

    step = jax.jit(make_train_step(cfg, OptConfig(warmup_steps=1), shd=None,
                                   compute_dtype=jnp.float32))
    opt = init_opt_state(params)
    new_params, new_opt, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    assert int(new_opt["step"]) == 1
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0] - x[1]))),
        jax.tree_util.tree_map(lambda a, b: (a, b), new_params, params), 0.0)
    assert delta > 0.0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "mamba2-370m": (48, 1024, None, None, 0, 50280),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "deepseek-v2-lite-16b": (27, 2048, 16, None, 1408, 102400),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "zamba2-1.2b": (None, 2048, 32, 32, 8192, 32000),
    }[arch]
    layers, d, h, kv, dff, vocab = expected
    if arch == "zamba2-1.2b":
        kinds = cfg.layer_kinds()
        assert sum(1 for k in kinds if k == "mamba") == 38
        assert cfg.ssm.d_state == 64
    elif layers is not None:
        assert cfg.num_layers == layers
    assert cfg.d_model == d
    if h is not None:
        assert cfg.num_heads == h
    if kv is not None:
        assert cfg.num_kv_heads == kv
    if arch == "deepseek-v2-lite-16b":
        assert cfg.moe.d_ff_expert == 1408
        assert cfg.moe.num_experts == 64 and cfg.moe.top_k == 6
        assert cfg.mla.kv_lora_rank == 512
    elif arch == "phi3.5-moe-42b-a6.6b":
        assert cfg.moe.num_experts == 16 and cfg.moe.top_k == 2
        assert cfg.moe.d_ff_expert == 6400
    elif arch == "mamba2-370m":
        assert cfg.ssm.d_state == 128
    elif dff:
        assert cfg.d_ff == dff
    assert cfg.vocab_size == vocab


def test_param_counts_plausible():
    """Analytic param counts land near the advertised model sizes."""
    expect = {
        "gemma2-9b": (8.5e9, 10.5e9),
        "gemma3-12b": (10.5e9, 13.5e9),
        "granite-3-2b": (2.0e9, 3.0e9),
        "gemma-7b": (7.5e9, 9.5e9),
        "mamba2-370m": (0.3e9, 0.45e9),
        "phi3.5-moe-42b-a6.6b": (39e9, 45e9),
        "deepseek-v2-lite-16b": (13e9, 17e9),
        "chameleon-34b": (32e9, 37e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        # our MLP is gated (3 matrices); HF hubert uses 2 -> slightly above 1B
        "hubert-xlarge": (0.9e9, 1.35e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_active_params_moe():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    active = count_params(cfg, active_only=True)
    total = count_params(cfg)
    assert active < 0.25 * total            # 6.6B active of 42B
    assert 5.5e9 < active < 8.5e9
