"""Loop-aware HLO analyzer: trip-count propagation, dot-flops counting,
collective accounting -- validated against hand-computable jitted graphs."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as ha
from repro.launch.roofline import Roofline


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_matmul_flops():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    text = _compiled_text(lambda x, y: x @ y, a, b)
    out = ha.analyze_hlo(text)
    assert out.flops == pytest.approx(2 * 64 * 128 * 32)
    assert out.dot_count == 1


def test_scan_multiplies_flops_by_trip_count():
    a = jnp.zeros((32, 32), jnp.float32)

    def fn(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    out = ha.analyze_hlo(_compiled_text(fn, a))
    assert out.flops == pytest.approx(7 * 2 * 32 * 32 * 32, rel=0.01)


def test_nested_scan_trip_counts_compose():
    a = jnp.zeros((16, 16), jnp.float32)

    def fn(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    out = ha.analyze_hlo(_compiled_text(fn, a))
    assert out.flops == pytest.approx(15 * 2 * 16 ** 3, rel=0.01)


def test_batched_dot_flops():
    a = jnp.zeros((4, 8, 16), jnp.float32)
    b = jnp.zeros((4, 16, 8), jnp.float32)
    out = ha.analyze_hlo(_compiled_text(
        lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b))
    assert out.flops == pytest.approx(2 * 4 * 8 * 16 * 8)


def test_memory_counts_argument_traffic():
    a = jnp.zeros((1024, 1024), jnp.float32)
    out = ha.analyze_hlo(_compiled_text(lambda x: x + 1.0, a))
    # one fusion: reads 4 MiB, writes 4 MiB
    assert out.memory_bytes == pytest.approx(2 * 4 * 2**20, rel=0.2)


def test_shape_bytes_parsing():
    assert ha._shape_bytes("f32[8,4]{1,0}") == 128
    assert ha._shape_bytes("bf16[10]") == 20
    assert ha._shape_bytes("(f32[4], s8[8])") == 24
    assert ha._shape_bytes("pred[]") == 1


def test_collective_accounting_ring_model():
    op = ha.Op(name="%x", opcode="all-reduce", type_str="f32[100]",
               line="", operands=[])
    assert ha._collective_moved(op, 4) == pytest.approx(2 * 400 * 3 / 4)
    op2 = ha.Op(name="%x", opcode="all-gather", type_str="f32[100]",
                line="", operands=[])
    assert ha._collective_moved(op2, 4) == pytest.approx(400 * 3 / 4)
    op3 = ha.Op(name="%x", opcode="reduce-scatter", type_str="f32[100]",
                line="", operands=[])
    assert ha._collective_moved(op3, 4) == pytest.approx(400 * 3)


def test_group_size_parsing():
    assert ha._group_size("replica_groups={{0,1,2,3}}") == 4
    assert ha._group_size("replica_groups=[16,16]<=[256]") == 16
    assert ha._group_size("no groups here", default=1) == 1


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops_per_device=197e12, hbm_bytes_per_device=819e9,
                 collective_bytes_per_device=0.0, chips=4,
                 model_flops=4 * 197e12 * 0.5)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.bottleneck in ("compute", "memory")
    assert r.mfu == pytest.approx(0.5)
    r2 = Roofline(flops_per_device=1.0, hbm_bytes_per_device=1.0,
                  collective_bytes_per_device=50e9 * 3, chips=1,
                  model_flops=1.0)
    assert r2.bottleneck == "collective"
    assert r2.collective_s == pytest.approx(3.0)


def test_real_scanned_model_flops_sane():
    """End-to-end: a smoke transformer's HLO flops within 2x of analytic."""
    from repro.configs import get_smoke_config
    from repro.models import init_model, lm_loss

    cfg = get_smoke_config("granite-3-2b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = {"inputs": jnp.zeros((2, 32), jnp.int32),
             "targets": jnp.zeros((2, 32), jnp.int32)}
    text = jax.jit(lambda p, b: lm_loss(p, b, cfg)[0]).lower(
        params, batch).compile().as_text()
    out = ha.analyze_hlo(text)
    # analytic forward flops: 2*N*D (matmul params only, no embed)
    from repro.models.config import count_params
    n_mat = count_params(cfg) - cfg.padded_vocab_size * cfg.d_model
    analytic = 2 * (n_mat * 64 + cfg.padded_vocab_size * cfg.d_model * 64)
    assert 0.5 * analytic < out.flops < 3.0 * analytic
