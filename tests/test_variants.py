"""Hillclimb-variant correctness: every perf knob must preserve numerics.

- bf16/low-precision RMSNorm (custom VJP) == fp32 autodiff within tolerance
- manual Megatron-SP (shard_map AG+RS) == auto-partitioned step (subprocess
  with 8 forced host devices)
- bf16 grad_dtype training still converges
"""

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import rmsnorm

KEY = jax.random.PRNGKey(0)


def test_lowp_rmsnorm_grads_match_fp32():
    x = jax.random.normal(KEY, (4, 8, 64), jnp.float32)
    w = 0.1 * jax.random.normal(KEY, (64,), jnp.float32)
    def f_hi(x, w):
        return jnp.sum(jnp.sin(rmsnorm(x, w, fp32=True)))

    def f_lo(x, w):
        return jnp.sum(jnp.sin(rmsnorm(x, w, fp32=False)))
    gx1, gw1 = jax.grad(f_hi, (0, 1))(x, w)
    gx2, gw2 = jax.grad(f_lo, (0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2), atol=2e-6)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2), atol=2e-6)
    np.testing.assert_allclose(
        np.asarray(rmsnorm(x, w, fp32=True)),
        np.asarray(rmsnorm(x, w, fp32=False)), atol=1e-6)


def test_lowp_rmsnorm_bf16_cotangent_dtype():
    """The whole point: bf16 input -> bf16 dx (no f32 promotion)."""
    x = jax.random.normal(KEY, (4, 32), jnp.bfloat16)
    w = jnp.zeros((32,), jnp.bfloat16)
    dx = jax.grad(lambda x: jnp.sum(rmsnorm(x, w, fp32=False)
                                    .astype(jnp.float32)))(x)
    assert dx.dtype == jnp.bfloat16


def test_grad_dtype_bf16_training_converges():
    from repro.configs import get_smoke_config
    from repro.launch.steps import make_train_step
    from repro.models import init_model
    from repro.train.data import DataConfig, batch_for_step
    from repro.train.optimizer import OptConfig, init_opt_state

    cfg = get_smoke_config("granite-3-2b")
    params = init_model(KEY, cfg)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, OptConfig(peak_lr=3e-3,
                                                  warmup_steps=2),
                                   grad_dtype="bf16"))
    dc = DataConfig(kind="lm", vocab_size=cfg.vocab_size, seq_len=32,
                    global_batch=8)
    losses = []
    for s in range(20):
        params, opt, m = step(params, opt, batch_for_step(dc, s))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


MANUAL_TP_SRC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.models import init_model, lm_loss
    from repro.parallel.sharding import (ShardingCtx, make_rules,
                                         param_pspecs)

    from repro.parallel.compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = make_rules(False)
    shd = ShardingCtx(mesh, rules)
    base = get_smoke_config("granite-3-2b")
    params = init_model(jax.random.PRNGKey(0), base)
    batch = {"inputs": jnp.zeros((4, 32), jnp.int32) + 5,
             "targets": jnp.ones((4, 32), jnp.int32)}
    pshard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_pspecs(params),
        is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, pshard)

    out = {}
    for name, cfg in [("auto", base),
                      ("manual", dataclasses.replace(base, manual_tp=True))]:
        loss, _ = jax.jit(lambda p, b: lm_loss(p, b, cfg, shd))(params,
                                                                batch)
        out[name] = float(loss)
    print(json.dumps(out))
""")


def test_manual_tp_matches_auto_partitioning():
    out = subprocess.run([sys.executable, "-c", MANUAL_TP_SRC],
                         capture_output=True, text=True, timeout=900,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    np.testing.assert_allclose(res["manual"], res["auto"], rtol=2e-4)


def test_moe_grouped_dispatch_respects_row_capacity():
    """Tokens never exceed per-row capacity with grouped dispatch."""
    from repro.models.config import ModelConfig, MoEConfig
    from repro.models.moe import capacity_for, init_moe_params, moe_forward

    cfg = ModelConfig(
        name="t", family="moe", d_model=16, num_heads=2, num_kv_heads=2,
        head_dim=8, d_ff=32, vocab_size=64, pattern=("global",), repeats=1,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=24,
                      capacity_factor=1.0))
    p = init_moe_params(KEY, cfg, jnp.float32)
    # Adversarial: every token routes identically within a row.
    x = jnp.broadcast_to(jax.random.normal(KEY, (1, 1, 16)), (2, 64, 16))
    out, _ = moe_forward(p, x, cfg=cfg)
    assert np.isfinite(np.asarray(out)).all()
    # capacity is per ROW (64 tokens), not global (128)
    assert capacity_for(64, cfg.moe) == 32
