"""Golden concession-ordering tests for ``degrade_plan``.

For every registered CapsNet arch we pin the EXACT concession sequence
at a ladder of reduced VMEM budgets.  The ordering is part of the
serving contract: batch reduction is always reported first, then the
pipelined pair dissolving, then per-op mode/tile concessions in plan
order -- a degraded replica's log line must stay stable and readable
across planner refactors.  Budgets are fractions of the full
``VMEM_BYTES`` (16 MiB), matching the ``python -m repro.verify``
degrade ladder.
"""

import pytest

from repro.configs.registry import CAPSNET_ARCHS, get_config
from repro.core.execplan import (PlanError, VMEM_BYTES, compile_plan,
                                 degrade_plan)

# (arch, requested batch, budget fraction) -> exact concession tuple.
GOLDEN = {
    ("capsnet-mnist", 4, 1.0): (),
    ("capsnet-mnist", 4, 0.5): (),
    ("capsnet-mnist", 4, 0.25): (
        "PrimaryCaps-Routing: block_i 128 -> 4",
    ),
    ("capsnet-mnist", 4, 0.125): (
        "Conv1: conv tiles (1024,128,256) -> (256,128,256)",
        "PrimaryCaps-Routing: resident -> streamed",
        "PrimaryCaps-Routing: block_i 128 -> 64",
    ),
    ("capsnet-cifar10", 2, 1.0): (),
    ("capsnet-cifar10", 2, 0.5): (
        "batch 2 -> 1",
        "PrimaryCaps: conv tiles (128,256,256) -> (64,256,256)",
        "ClassCaps-Routing[0]: block_i 8 -> 4",
        "ClassCaps-Routing[1]: block_i 8 -> 4",
        "ClassCaps-Routing[2]: block_i 8 -> 4",
        "ClassCaps-Routing[3]: block_i 8 -> 4",
        "ClassCaps-Routing[4]: block_i 8 -> 4",
        "ClassCaps-Routing[5]: block_i 8 -> 4",
        "ClassCaps-Routing: block_i 2048 -> 512",
    ),
    ("capsnet-svhn", 4, 1.0): (),
    ("capsnet-svhn", 4, 0.5): (
        "PrimaryCaps-Routing: block_i 256 -> 64",
    ),
    ("capsnet-svhn", 4, 0.25): (
        "PrimaryCaps-Routing: block_i 256 -> 16",
    ),
    ("capsnet-svhn", 4, 0.125): (
        "batch 4 -> 2",
        "Conv1: conv tiles (512,256,256) -> (256,256,256)",
        "PrimaryCaps-Routing: block_i 256 -> 2",
        "PrimaryCaps-Routing: conv tiles (256,256,256) -> (128,256,256)",
    ),
}


@pytest.mark.parametrize(("arch", "batch", "frac"), sorted(GOLDEN),
                         ids=lambda v: str(v))
def test_concession_sequence_golden(arch, batch, frac):
    cfg = get_config(arch)
    plan, rep = degrade_plan(cfg, int(VMEM_BYTES * frac), batch=batch,
                             pipeline=True)
    assert rep.concessions == GOLDEN[(arch, batch, frac)]
    assert rep.requested_batch == batch
    assert rep.degraded == bool(rep.concessions)
    # The returned plan honors whatever batch the report claims.
    assert plan.batch == rep.batch


@pytest.mark.parametrize("arch", CAPSNET_ARCHS)
def test_full_budget_is_concession_free_and_memoized(arch):
    batch = 2 if arch == "capsnet-cifar10" else 4
    cfg = get_config(arch)
    plan, rep = degrade_plan(cfg, VMEM_BYTES, batch=batch, pipeline=True)
    assert rep.concessions == ()
    # Bit-identical to the full-budget plan: a no-fault replica has zero
    # behavior change.
    assert plan == compile_plan(cfg, batch=batch, pipeline=True)


def test_batch_concession_is_reported_first():
    # Whenever batch is conceded it must lead the sequence -- operators
    # grep degradation logs for the throughput hit first.
    for (arch, batch, frac), gold in GOLDEN.items():
        batch_notes = [c for c in gold if c.startswith("batch ")]
        if batch_notes:
            assert gold[0] == batch_notes[0], (arch, frac)
            assert len(batch_notes) == 1


def test_exhausted_ladder_raises_named_planerror():
    with pytest.raises(PlanError, match="batch >= 1"):
        degrade_plan(get_config("capsnet-cifar10"), VMEM_BYTES // 4,
                     batch=2, pipeline=True)
