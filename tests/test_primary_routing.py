"""Pipelined PrimaryCaps->ClassCaps megakernel: fused-vs-unfused parity
(ragged / non-power-of-two capsule counts, batch>1, both consumer
schedules), jax.grad parity, the plan's pipelined-vs-per-op selection
(budget-forced fallback, PlanError boundary), and the modeled
inter-layer HBM savings."""

import jax
import numpy as np
import pytest

from repro.core import analysis, capsnet, execplan
from repro.core.capsnet import CapsNetConfig
from repro.core.execplan import (FUSED_NAME, PIPE_NAME, BWD_SUFFIX,
                                 PlanError, compile_plan,
                                 plan_primary_routing,
                                 primary_intermediate_hbm_bytes,
                                 primary_routing_hbm_bytes)
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)

SMOKE = CapsNetConfig(image_hw=14, conv1_channels=16, conv1_kernel=5,
                      pc_kernel=3, num_primary_groups=4, primary_dim=4,
                      class_dim=8, decoder_hidden=(32, 64))
# Odd image + 24 capsule groups: num_primary = 600, every dimension
# non-power-of-two (the NONPOW2 config of test_execplan).
NONPOW2 = CapsNetConfig(image_hw=15, conv1_channels=24, conv1_kernel=5,
                        pc_kernel=3, pc_stride=2, num_primary_groups=24,
                        primary_dim=4, class_dim=8, use_decoder=False)


def _net(b, h, cin, kh, stride, n_ch, caps_dim, j, d, seed=0):
    """Random producer input + both layers' weights for one pair shape."""
    oh = (h - kh) // stride + 1
    i_dim = oh * oh * (n_ch // caps_dim)
    k = jax.random.split(jax.random.fold_in(KEY, seed), 4)
    x = jax.random.uniform(k[0], (b, h, h, cin))
    w_pc = 0.2 * jax.random.normal(k[1], (kh, kh, cin, n_ch))
    b_pc = 0.1 * jax.random.normal(k[2], (n_ch,))
    w_cc = 0.3 * jax.random.normal(k[3], (i_dim, j * d, caps_dim))
    return x, w_pc, b_pc, w_cc


def _unfused(x, w_pc, b_pc, w_cc, *, stride, iters, j, caps_dim):
    """The per-op oracle: conv_im2col with fused squash -> reshape ->
    votes_routing -- exactly the fallback path a per-op plan runs."""
    pc = ops.conv2d(x, w_pc, b_pc, stride=stride, epilogue="squash",
                    squash_dim=caps_dim)
    u = pc.reshape(x.shape[0], w_cc.shape[0], caps_dim)
    return ops.votes_routing(u, w_cc, iters=iters, num_classes=j)


# ---------------------------------------------------------------------------
# Kernel parity: pipelined megakernel == per-op pair, both schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["resident", "streamed"])
@pytest.mark.parametrize("b,h,cin,kh,stride,n_ch,c,j,d,bi,bk", [
    (2, 10, 16, 3, 2, 16, 4, 10, 8, 32, 64),   # divisible blocks (I=64)
    (2, 10, 8, 6, 2, 12, 4, 4, 8, 8, 13),      # I=27: odd, ragged i + k
    (3, 7, 8, 3, 2, 60, 4, 5, 8, 64, 1024),    # I=135, batch>1, bi > I
    (1, 9, 6, 3, 2, 20, 4, 3, 16, 7, 29),      # I=80, prime-ish tiles
])
def test_pipelined_matches_unfused_pair(mode, b, h, cin, kh, stride, n_ch,
                                        c, j, d, bi, bk):
    x, w_pc, b_pc, w_cc = _net(b, h, cin, kh, stride, n_ch, c, j, d,
                               seed=h + n_ch)
    got = ops.primary_routing(x, w_pc, b_pc, w_cc, stride=stride, iters=3,
                              num_classes=j, mode=mode, block_i=bi,
                              block_k=bk)
    want = _unfused(x, w_pc, b_pc, w_cc, stride=stride, iters=3, j=j,
                    caps_dim=c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("iters", [1, 2, 5])
def test_pipelined_iteration_sweep(iters):
    x, w_pc, b_pc, w_cc = _net(2, 10, 16, 3, 2, 16, 4, 5, 8, seed=iters)
    for mode in ("resident", "streamed"):
        got = ops.primary_routing(x, w_pc, b_pc, w_cc, stride=2,
                                  iters=iters, num_classes=5, mode=mode,
                                  block_i=16, block_k=32)
        want = _unfused(x, w_pc, b_pc, w_cc, stride=2, iters=iters, j=5,
                        caps_dim=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_pipelined_planless_wrapper_picks_schedule():
    """Without a plan the wrapper resolves (mode, block_i, block_k, conv
    tiles) through the memoized plan decision and still matches."""
    x, w_pc, b_pc, w_cc = _net(2, 10, 16, 3, 2, 16, 4, 10, 8, seed=9)
    got = ops.primary_routing(x, w_pc, b_pc, w_cc, stride=2, iters=3,
                              num_classes=10)
    want = _unfused(x, w_pc, b_pc, w_cc, stride=2, iters=3, j=10,
                    caps_dim=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    mode, bi, bk, cb = ops.planned_primary_routing(16, 144, 16, 64, 4, 80,
                                                   10, 3, 2)
    assert mode == "resident"            # smoke-scale votes fit VMEM
    assert 1 <= bi <= 64 and 1 <= bk <= 144 and len(cb) == 3


def test_pipelined_rejects_bad_args():
    x, w_pc, b_pc, w_cc = _net(1, 10, 8, 3, 2, 12, 4, 4, 8)
    with pytest.raises(ValueError, match="unknown mode"):
        ops.primary_routing(x, w_pc, b_pc, w_cc, stride=2, num_classes=4,
                            mode="hybrid", block_i=8, block_k=16)
    with pytest.raises(ValueError, match="not divisible"):
        ops.primary_routing(x, w_pc, b_pc, w_cc, stride=2, num_classes=3,
                            mode="resident", block_i=8, block_k=16)


# ---------------------------------------------------------------------------
# Gradients: the recompute-from-patches VJP matches the per-op pair
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["resident", "streamed"])
def test_grad_parity_vs_unfused(mode):
    x, w_pc, b_pc, w_cc = _net(2, 10, 8, 6, 2, 12, 4, 4, 8, seed=3)

    def loss_fused(x, w_pc, b_pc, w_cc):
        v = ops.primary_routing(x, w_pc, b_pc, w_cc, stride=2, iters=3,
                                num_classes=4, mode=mode, block_i=8,
                                block_k=32)
        return jax.numpy.sum(jax.numpy.sin(v))

    def loss_split(x, w_pc, b_pc, w_cc):
        v = _unfused(x, w_pc, b_pc, w_cc, stride=2, iters=3, j=4,
                     caps_dim=4)
        return jax.numpy.sum(jax.numpy.sin(v))

    got = jax.grad(loss_fused, argnums=(0, 1, 2, 3))(x, w_pc, b_pc, w_cc)
    want = jax.grad(loss_split, argnums=(0, 1, 2, 3))(x, w_pc, b_pc, w_cc)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


def test_grad_through_total_loss_matches_jnp():
    """End to end: jax.grad through the pipelined train plan equals the
    jnp backend's gradients on every parameter."""
    b = 3
    params = capsnet.init_params(KEY, SMOKE)
    imgs = jax.random.uniform(KEY, (b, 14, 14, 1))
    labels = jax.numpy.array([1, 7, 3])
    plan = compile_plan(SMOKE, batch=b, train=True, pipeline=True)
    assert any(op.name == PIPE_NAME for op in plan.ops)

    gp = jax.grad(lambda p: capsnet.total_loss(
        p, imgs, labels, SMOKE, backend="pallas", plan=plan)[0])(params)
    gr = jax.grad(lambda p: capsnet.total_loss(
        p, imgs, labels, SMOKE, backend="jnp")[0])(params)
    for k in gp:
        np.testing.assert_allclose(np.asarray(gp[k]), np.asarray(gr[k]),
                                   rtol=1e-4, atol=1e-5, err_msg=k)


# ---------------------------------------------------------------------------
# Plan selection: pipelined when it fits, per-op fallback under pressure,
# PlanError only when neither fits
# ---------------------------------------------------------------------------

def _pipe_args(cfg, batch):
    dims = analysis.dims_from_config(cfg)
    return dict(p_pos=dims.pc_out ** 2, k_in=dims.pc_k ** 2 * dims.pc_cin,
                n_ch=dims.pc_cout, num_caps=dims.num_primary,
                caps_dim=dims.primary_dim,
                jd=dims.num_classes * dims.class_dim,
                j=dims.num_classes, batch=batch)


def test_budget_forces_perop_fallback():
    """One byte under the pipelined streamed floor: compile_plan silently
    falls back to the per-op pair (which still fits -- its phases never
    coexist), and the unfused path keeps executing."""
    a = _pipe_args(SMOKE, 64)
    floor = execplan._pipe_streamed_vmem(
        a["batch"], a["p_pos"], a["n_ch"], 1, a["num_caps"], 1,
        a["caps_dim"], a["jd"], a["j"])
    budget = floor - 1
    with pytest.raises(PlanError, match="streamed block_i=1, block_k=1"):
        plan_primary_routing(
            a["p_pos"], a["k_in"], a["n_ch"], a["num_caps"], a["caps_dim"],
            a["jd"], a["j"], batch=a["batch"], vmem_budget=budget)
    plan = compile_plan(SMOKE, batch=64, vmem_budget=budget, pipeline=True)
    names = [op.name for op in plan.ops]
    assert PIPE_NAME not in names
    assert "PrimaryCaps" in names and FUSED_NAME in names


def test_pipelined_plan_selected_when_it_fits():
    plan = compile_plan(SMOKE, batch=8, pipeline=True)
    names = [op.name for op in plan.ops]
    assert names == ["Conv1", PIPE_NAME]
    op = plan.op(PIPE_NAME)
    assert op.mode in ("resident", "streamed")
    assert op.block_i >= 1 and op.block_k >= 1
    # pipeline=False (the default) never emits the pair
    perop = compile_plan(SMOKE, batch=8)
    assert PIPE_NAME not in [o.name for o in perop.ops]


def test_pipelined_forward_matches_perop_plan_end_to_end():
    params = capsnet.init_params(KEY, NONPOW2)
    imgs = jax.random.uniform(KEY, (2, 15, 15, 1))
    pipe = compile_plan(NONPOW2, batch=2, pipeline=True)
    perop = compile_plan(NONPOW2, batch=2)
    assert any(op.name == PIPE_NAME for op in pipe.ops)
    want = capsnet.forward(params, imgs, NONPOW2)
    got = capsnet.forward(params, imgs, NONPOW2, backend="pallas",
                          plan=pipe)
    split = capsnet.forward(params, imgs, NONPOW2, backend="pallas",
                            plan=perop)
    np.testing.assert_allclose(np.asarray(got["lengths"]),
                               np.asarray(want["lengths"]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got["lengths"]),
                               np.asarray(split["lengths"]),
                               rtol=1e-5, atol=1e-6)


def test_wrapper_rejects_batch_over_plan():
    plan = compile_plan(SMOKE, batch=2, pipeline=True)
    x, w_pc, b_pc, w_cc = _net(4, 10, 16, 3, 2, 16, 4, 10, 8)
    with pytest.raises(ValueError, match="exceeds the plan's batch"):
        ops.primary_routing(x, w_pc, b_pc, w_cc, plan=plan)
    out = ops.primary_routing(x[:1], w_pc, b_pc, w_cc, plan=plan)
    assert out.shape == (1, 80)


def test_train_plan_keeps_perop_backward():
    """The pipelined VJP replays the producer from patches and composes
    the per-op backward kernels, so a pipelined TRAIN plan's backward
    OpPlans are the per-op ones -- with the PrimaryCaps backward always
    paying the 3-matmul squash-recompute."""
    plan = compile_plan(CapsNetConfig(), batch=8, train=True, pipeline=True)
    names = [op.name for op in plan.ops]
    assert names == ["Conv1", PIPE_NAME, FUSED_NAME + BWD_SUFFIX,
                     "PrimaryCaps" + BWD_SUFFIX, "Conv1" + BWD_SUFFIX]
    pc_bwd = plan.op("PrimaryCaps" + BWD_SUFFIX)
    patches = pc_bwd.workload.m * pc_bwd.workload.k * execplan.ELEM_BYTES
    assert pc_bwd.hbm_bytes == 3 * pc_bwd.block.hbm_bytes + 2 * patches


# ---------------------------------------------------------------------------
# Modeled HBM traffic: the inter-layer u round-trip is gone
# ---------------------------------------------------------------------------

def test_pipelined_plan_zero_intermediate_and_lower_total():
    """The acceptance criterion: on the MNIST config the pipelined plan
    reports the PrimaryCaps->ClassCaps intermediate at 0 bytes AND a
    lower total forward HBM traffic than the per-op plan."""
    cfg = CapsNetConfig()
    pipe = compile_plan(cfg, batch=8, pipeline=True)
    perop = compile_plan(cfg, batch=8)
    op = pipe.op(PIPE_NAME)
    assert op.intermediate_hbm_bytes == 0.0
    assert op.uhat_hbm_bytes == 0.0
    inter = perop.op("PrimaryCaps").intermediate_hbm_bytes
    assert inter == primary_intermediate_hbm_bytes(8, cfg.num_primary,
                                                   cfg.primary_dim)
    assert inter == 2 * 8 * 1152 * 8 * execplan.ELEM_BYTES
    assert pipe.forward_hbm_bytes() < perop.forward_hbm_bytes()
    # the modeled pipelined traffic is the plan's own number
    a = _pipe_args(cfg, 8)
    dims = analysis.dims_from_config(cfg)
    extract = execplan.conv_extract_hbm_bytes(
        dims.conv1_out, dims.pc_cin, dims.pc_k, dims.pc_out, batch=8)
    assert op.hbm_bytes == primary_routing_hbm_bytes(
        8, a["p_pos"], a["k_in"], a["n_ch"], a["num_caps"], a["caps_dim"],
        a["jd"], pipe.op(PIPE_NAME).mode == "streamed"
        and cfg.routing_iters + 1 or 1) + extract


def test_summary_and_pmu_cover_pipelined_phase():
    """The pipelined op appears in the plan summary with its intermediate
    column; the PMU gates the pair as ONE phase (one wakeup window, no
    spurious transition at the fused-away producer/consumer boundary),
    and ``phase_groups`` reports every covered profile for the DSE."""
    from repro.core.energy import SRAMConfig
    from repro.core.pmu import schedule_from_plan
    plan = compile_plan(CapsNetConfig(), batch=8, pipeline=True)
    rows = {r["name"]: r for r in plan.summary()}
    assert rows[PIPE_NAME]["intermediate_hbm_bytes"] == 0.0
    groups = dict(plan.phase_groups())
    assert groups[PIPE_NAME] == execplan.PIPE_COVERS
    mem = SRAMConfig("m", 1 << 20, power_gated=True, banks=16,
                     sectors_per_bank=8)
    sched = schedule_from_plan(mem, plan)
    assert [p.name for p in sched.phases] == ["Conv1", PIPE_NAME]


def test_plan_cache_bounded():
    assert ops.planned_primary_routing.cache_info().maxsize == 64
