"""Chaos suite: deterministic fault injection (``core/faults.py``) driving
the graceful-degradation paths for real -- engine retry/quarantine/breaker/
degraded-VMEM replanning, ``degrade_plan``'s fallback ladder, and the
training harness's NaN-streak / straggler / preemption machinery.

CI runs this file as the ``chaos-smoke`` job; locally:

    PYTHONPATH=src python -m pytest tests/test_faults.py -q
"""

import json

import jax
import numpy as np
import pytest

from repro.core import capsnet, faults
from repro.core.capsnet import CapsNetConfig
from repro.core.execplan import PlanError, compile_plan, degrade_plan
from repro.core.faults import FaultSpec, InjectionError
from repro.serve import CapsRequest, CapsuleEngine, EngineStalled
from repro.train import checkpoint as ckpt
from repro.verify import assert_engine_stats
from repro.train.capsnet_loop import SMOKE, CapsLoopConfig, CapsTrainLoop

KEY = jax.random.PRNGKey(0)
CFG = CapsNetConfig(image_hw=14, conv1_channels=16, conv1_kernel=5,
                    pc_kernel=3, num_primary_groups=4, primary_dim=4,
                    class_dim=8, use_decoder=False)
PARAMS = capsnet.init_params(KEY, CFG)


def _images(n):
    return np.asarray(jax.random.uniform(
        KEY, (n, CFG.image_hw, CFG.image_hw, 1)))


def _reference_lengths(image):
    return np.asarray(capsnet.forward(PARAMS, image[None], CFG)["lengths"][0])


def _assert_terminal(engine):
    """Every submitted request reached exactly one terminal status and the
    counters account for all of them -- the ISSUE acceptance invariant.
    The accounting itself lives in the shared checker
    (``repro.verify.assert_engine_stats``) so this suite and
    ``test_sharded_serving.py`` cannot drift apart."""
    return assert_engine_stats(engine)


# -- registry mechanics ------------------------------------------------------

def test_spec_validation():
    with pytest.raises(InjectionError, match="unknown fault kind"):
        FaultSpec(site="engine.tick", kind="meteor_strike")
    with pytest.raises(InjectionError, match="times"):
        FaultSpec(site="engine.tick", kind="nan_output", times=-1)
    with pytest.raises(InjectionError, match="factor"):
        FaultSpec(site="engine.tick", kind="vmem_shrink", factor=0.0)
    with pytest.raises(InjectionError, match="factor"):
        FaultSpec(site="engine.tick", kind="vmem_shrink", factor=1.5)
    FaultSpec(site="engine.tick", kind="vmem_shrink", factor=1.0)  # boundary


def test_fires_at_window():
    spec = FaultSpec(site="s", kind="nan_output", at=2, times=3)
    assert [spec.fires_at(i) for i in range(7)] == \
        [False, False, True, True, True, False, False]
    never = FaultSpec(site="s", kind="nan_output", at=2, times=0)
    assert not any(never.fires_at(i) for i in range(7))


def test_poll_indexes_and_fired_log():
    a = FaultSpec(site="s", kind="nan_output", at=1, times=2)
    b = FaultSpec(site="t", kind="stall", at=0, times=1)
    with faults.inject(a, b) as reg:
        assert faults.poll("s", index=0) == ()
        assert faults.poll("s", index=1) == (a,)
        # no explicit index: the site's own counter advances per poll
        assert faults.poll("t") == (b,)      # counter 0
        assert faults.poll("t") == ()        # counter 1
        # kind filter
        assert faults.poll("s", index=2, kinds=("stall",)) == ()
        assert faults.poll("s", index=2, kinds=("nan_output",)) == (a,)
        assert reg.fired == [("s", "nan_output", 1), ("t", "stall", 0),
                             ("s", "nan_output", 2)]
        assert reg.count() == 3
        assert reg.count(site="s") == 2
        assert reg.count(kind="stall") == 1
    assert not faults.enabled()


def test_nested_inject_refused():
    with faults.inject():
        with pytest.raises(InjectionError, match="already active"):
            with faults.inject():
                pass
    assert not faults.enabled()              # outer context tore down


def test_disabled_is_inert():
    assert not faults.enabled()
    assert faults.registry() is None
    assert faults.poll("engine.tick", index=0) == ()
    x = np.ones(3)
    assert faults.corrupt_array("ops.conv2d", x) is x   # same object, no copy


# -- ops.* kernel-wrapper sites (eager calls) --------------------------------

def test_ops_site_poisons_eager_forward():
    img = _images(1)
    clean = np.asarray(capsnet.forward(PARAMS, img, CFG, backend="pallas",
                                       interpret=True)["lengths"])
    assert np.all(np.isfinite(clean))
    with faults.inject(FaultSpec(site=faults.SITE_CONV2D,
                                 kind="nan_output")) as reg:
        out = capsnet.forward(PARAMS, img, CFG, backend="pallas",
                              interpret=True)
        assert not np.all(np.isfinite(np.asarray(out["lengths"])))
        assert reg.count(site=faults.SITE_CONV2D, kind="nan_output") == 1
    # injection torn down: the same call is clean (and bit-identical) again
    again = np.asarray(capsnet.forward(PARAMS, img, CFG, backend="pallas",
                                       interpret=True)["lengths"])
    np.testing.assert_array_equal(again, clean)


def test_ops_site_plan_error_raises():
    with faults.inject(FaultSpec(site=faults.SITE_CONV2D,
                                 kind="plan_error")):
        with pytest.raises(PlanError, match="injected plan_error"):
            capsnet.forward(PARAMS, _images(1), CFG, backend="pallas",
                            interpret=True)


def test_ops_inf_output_corrupts_array():
    with faults.inject(FaultSpec(site=faults.SITE_VOTES_ROUTING,
                                 kind="inf_output")):
        out = faults.corrupt_array(faults.SITE_VOTES_ROUTING,
                                   np.zeros((2, 2), np.float32))
        assert np.all(np.isposinf(np.asarray(out)))


# -- degrade_plan fallback ladder --------------------------------------------

def test_degrade_plan_full_budget_is_golden():
    """At 100% budget the degraded plan IS the normal plan (bit-identical
    frozen dataclasses) and the report concedes nothing."""
    for pipeline in (False, True):
        plan, rep = degrade_plan(CFG, batch=4, pipeline=pipeline)
        assert plan == compile_plan(CFG, batch=4, pipeline=pipeline)
        assert rep.concessions == ()
        assert not rep.degraded
        assert rep.batch == rep.requested_batch == 4


def test_degrade_plan_forces_streamed_schedule():
    plan, rep = degrade_plan(CFG, batch=16, vmem_budget=200_000,
                             pipeline=True)
    assert rep.degraded and rep.batch == 16
    assert any("resident -> streamed" in c for c in rep.concessions)
    modes = {op.name: op.mode for op in plan.ops}
    assert modes["PrimaryCaps-Routing"] == "streamed"
    assert all(op.vmem_bytes <= 200_000 for op in plan.ops)


def test_degrade_plan_reduces_batch():
    """On the full MNIST config the pipelined pair's resident ``u`` scales
    with batch, so a tight budget walks down to a smaller feasible batch
    (the last rung before the breaker) and says so."""
    plan, rep = degrade_plan(CapsNetConfig(), batch=8, vmem_budget=600_000,
                             pipeline=True)
    assert rep.requested_batch == 8
    assert rep.batch < 8
    assert plan.batch == rep.batch
    assert any(f"batch 8 -> {rep.batch}" in c for c in rep.concessions)


def test_degrade_plan_exhaustion_raises_planerror():
    with pytest.raises(PlanError, match="no feasible plan"):
        degrade_plan(CFG, batch=4, vmem_budget=60_000)
    # min_batch floors the walk-down even when smaller batches would fit
    with pytest.raises(PlanError, match="batch >= 8"):
        degrade_plan(CapsNetConfig(), batch=8, vmem_budget=600_000,
                     pipeline=True, min_batch=8)


def test_degraded_plan_output_parity():
    """A degraded plan changes the schedule, never the math."""
    imgs = _images(2)
    plan, rep = degrade_plan(CFG, batch=2, vmem_budget=200_000,
                             pipeline=True)
    assert rep.degraded
    got = np.asarray(capsnet.forward(PARAMS, imgs, CFG, backend="pallas",
                                     plan=plan, interpret=True)["lengths"])
    want = np.asarray(capsnet.forward(PARAMS, imgs, CFG)["lengths"])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# -- engine chaos ------------------------------------------------------------

def test_engine_nan_storm_terminates_with_terminal_statuses():
    imgs = _images(5)
    engine = CapsuleEngine(PARAMS, CFG, slots=2)
    for i in range(5):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_FORWARD,
                                 kind="nan_output", at=0, times=2)) as reg:
        engine.run()
        assert reg.count(kind="nan_output") >= 1
    s = _assert_terminal(engine)
    assert s["poisoned"] >= 1
    assert s["retries"] >= 1
    # retried requests recovered once the storm passed
    assert s["ok"] == 5 and s["error"] == 0
    for r in engine.finished:
        np.testing.assert_allclose(r.lengths, _reference_lengths(imgs[r.rid]),
                                   rtol=1e-5, atol=1e-5)


def test_engine_errors_after_max_retries():
    engine = CapsuleEngine(PARAMS, CFG, slots=1, max_retries=1,
                           quarantine_after=10)
    engine.submit(CapsRequest(rid=0, image=_images(1)[0]))
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_FORWARD,
                                 kind="inf_output", at=0, times=50)):
        engine.run()
    s = _assert_terminal(engine)
    assert engine.finished[0].status == "error"
    assert engine.finished[0].retries == 1
    assert s["error"] == 1 and s["ok"] == 0


def test_engine_quarantines_poisoned_slot_and_sheds_backlog():
    imgs = _images(3)
    engine = CapsuleEngine(PARAMS, CFG, slots=1, max_retries=5,
                           quarantine_after=2)
    for i in range(3):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_FORWARD,
                                 kind="nan_output", at=0, times=100)):
        engine.run()
    s = _assert_terminal(engine)
    assert engine.quarantined == {0}
    assert s["quarantined"] == 1
    assert s["error"] == 1          # the request that poisoned the lane
    assert s["shed"] == 2           # the unservable backlog, not a hang


def test_engine_quarantine_probation_restores_capacity():
    """Regression: quarantine used to be permanent, so a transient NaN
    storm shrank capacity forever.  After the ``FaultSpec`` window
    closes, ``probation_ticks`` consecutive clean ticks lift the
    quarantine and the lane serves again."""
    imgs = _images(5)
    engine = CapsuleEngine(PARAMS, CFG, slots=2, max_retries=5,
                           retry_backoff_ticks=0, quarantine_after=2,
                           probation_ticks=3)
    # Phase 1: one request -> only slot 0 is active; two poisoned ticks
    # quarantine the lane and error the request.
    engine.submit(CapsRequest(rid=0, image=imgs[0]))
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_FORWARD,
                                 kind="nan_output", at=0, times=2)):
        engine.run()
    assert engine.quarantined == {0}
    assert engine.stats()["error"] == 1
    # Phase 2: the fault window is over.  Slot 1 keeps serving; after
    # three clean ticks slot 0 comes off probation and capacity returns.
    for i in range(1, 5):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    engine.run()
    s = _assert_terminal(engine)
    assert engine.quarantined == set()
    assert s["unquarantined"] == 1
    assert s["quarantined"] == 0
    assert s["ok"] == 4 and s["error"] == 1
    for r in engine.finished:
        if r.status == "ok":
            np.testing.assert_allclose(
                r.lengths, _reference_lengths(imgs[r.rid]),
                rtol=1e-5, atol=1e-5)


def test_engine_plan_swap_clears_quarantine():
    """A degrade-replan swaps the serving path, so standing quarantine
    verdicts are stale: the swap returns the lanes to the pool even with
    probation disabled."""
    imgs = _images(4)
    engine = CapsuleEngine(PARAMS, CFG, slots=2, backend="pallas",
                           quarantine_after=1, probation_ticks=None)
    for i in range(4):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    with faults.inject(
            FaultSpec(site=faults.SITE_ENGINE_FORWARD, kind="nan_output",
                      at=0, times=1),
            FaultSpec(site=faults.SITE_ENGINE_TICK, kind="vmem_shrink",
                      at=1, times=1, factor=0.012)):
        engine.run()
    s = _assert_terminal(engine)
    assert s["error"] == 2           # quarantine_after=1: both lanes, tick 0
    assert s["replans"] == 1 and s["unquarantined"] == 2
    assert engine.quarantined == set()
    assert s["ok"] == 2              # served AFTER the swap lifted quarantine
    assert engine._forward_traces == 2


def test_engine_breaker_trip_clears_quarantine():
    """The circuit breaker re-traces onto the reference backend -- a
    fresh serving path, so quarantined lanes get a fresh chance too."""
    engine = CapsuleEngine(PARAMS, CFG, slots=2, backend="pallas")
    engine.quarantined = {0, 1}
    engine._poison_streak = [3, 3]
    engine._trip_breaker()
    assert engine.quarantined == set()
    assert engine._poison_streak == [0, 0]
    assert engine.stats()["unquarantined"] == 2


def test_engine_retry_past_deadline_times_out():
    """Regression: the deadline sweep only ran at tick start, so a
    request poisoned by a slow tick was re-dispatched past its
    ``deadline_s``.  The retry path must check the deadline first and
    terminate as ``timeout`` -- never burn another dispatch on a dead
    request."""
    engine = CapsuleEngine(PARAMS, CFG, slots=1, max_retries=5,
                           retry_backoff_ticks=0, quarantine_after=10)
    clock = {"t": 0.0}
    engine._now = lambda: clock["t"]
    orig_forward = engine._forward

    def slow_forward(*a):               # each dispatch costs 0.6s of clock
        out = orig_forward(*a)
        clock["t"] += 0.6
        return out

    engine._forward = slow_forward
    engine.submit(CapsRequest(rid=0, image=_images(1)[0], deadline_s=1.0))
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_FORWARD,
                                 kind="nan_output", at=0, times=2)):
        engine.run()
    s = _assert_terminal(engine)
    # Tick 0 poisons at t=0.6 (inside deadline: one retry is scheduled);
    # tick 1 poisons at t=1.2 -- past the deadline, so the request must
    # time out THERE instead of being re-dispatched a second time.
    assert engine.finished[0].status == "timeout"
    assert s["timeout"] == 1 and s["ok"] == 0 and s["error"] == 0
    assert s["retries"] == 1 and s["poisoned"] == 2


def test_engine_sharded_nan_storm_terminal_and_per_shard_sums():
    """Chaos under the mesh path (1-shard mesh runs on a single device):
    a NaN storm still leaves every request terminal, and the per-shard
    counters + queue bucket sum to the aggregate."""
    imgs = _images(6)
    engine = CapsuleEngine(PARAMS, CFG, slots=2, n_shards=1,
                           retry_backoff_ticks=0)
    for i in range(6):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_FORWARD,
                                 kind="nan_output", at=0, times=2)):
        engine.run()
    s = _assert_terminal(engine)
    assert s["n_shards"] == 1 and s["poisoned"] >= 2
    assert engine._forward_traces == 1
    for r in engine.finished:
        if r.status == "ok":
            np.testing.assert_allclose(
                r.lengths, _reference_lengths(imgs[r.rid]),
                rtol=1e-5, atol=1e-5)


def test_engine_sharded_vmem_shrink_one_retrace():
    """A vmem_shrink under the mesh path swaps the degraded PER-SHARD
    plan with ONE re-trace across the whole mesh."""
    imgs = _images(6)
    engine = CapsuleEngine(PARAMS, CFG, slots=2, backend="pallas",
                           n_shards=1)
    for i in range(6):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_TICK,
                                 kind="vmem_shrink", at=1, times=2,
                                 factor=0.012)):
        engine.run()
    s = _assert_terminal(engine)
    assert s["ok"] == 6 and s["replans"] == 1
    assert engine._forward_traces == 2       # healthy trace + degraded trace
    for r in engine.finished:
        np.testing.assert_allclose(r.lengths, _reference_lengths(imgs[r.rid]),
                                   rtol=1e-4, atol=1e-4)


def test_engine_slot_corrupt_healed_by_retry():
    """Device-row corruption (the host copy stays clean) is healed by the
    retry path's re-upload -- the request still finishes ``ok``."""
    engine = CapsuleEngine(PARAMS, CFG, slots=1)
    img = _images(1)[0]
    engine.submit(CapsRequest(rid=0, image=img))
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_TICK,
                                 kind="slot_corrupt", at=0, times=1,
                                 seed=7)) as reg:
        engine.run()
        assert reg.count(kind="slot_corrupt") == 1
    s = _assert_terminal(engine)
    assert s["ok"] == 1 and s["poisoned"] == 1 and s["retries"] == 1
    np.testing.assert_allclose(engine.finished[0].lengths,
                               _reference_lengths(img), rtol=1e-5, atol=1e-5)


def test_engine_vmem_shrink_swaps_degraded_plan():
    """Mid-run shrink: ONE replan at a tick boundary, ONE new trace, the
    surviving requests bit-match the reference forward."""
    imgs = _images(6)
    engine = CapsuleEngine(PARAMS, CFG, slots=2, backend="pallas")
    for i in range(6):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    assert engine._forward_traces == 0
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_TICK,
                                 kind="vmem_shrink", at=1, times=2,
                                 factor=0.012)):
        engine.run()
    s = _assert_terminal(engine)
    assert s["ok"] == 6
    assert s["replans"] == 1                 # idempotent across the window
    assert s["breaker_trips"] == 0
    assert s["degraded"] and engine.degrade_report.degraded
    assert engine.plan.vmem_budget == engine.degrade_report.vmem_budget
    assert engine._forward_traces == 2       # healthy trace + degraded trace
    for r in engine.finished:
        np.testing.assert_allclose(r.lengths, _reference_lengths(imgs[r.rid]),
                                   rtol=1e-4, atol=1e-4)


def test_engine_vmem_shrink_noop_factor_keeps_plan():
    """factor=1.0 is the identity shrink: the budget is unchanged, so the
    engine must not replan or re-trace -- the reaction path is a no-op."""
    imgs = _images(4)
    engine = CapsuleEngine(PARAMS, CFG, slots=2, backend="pallas")
    for i in range(4):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_TICK,
                                 kind="vmem_shrink", at=1, times=1,
                                 factor=1.0)):
        engine.run()
    s = _assert_terminal(engine)
    assert s["ok"] == 4 and s["replans"] == 0 and not s["degraded"]
    assert engine._forward_traces == 1
    assert s["vmem_budget"] == engine._orig_budget


def test_engine_vmem_shrink_infeasible_trips_breaker():
    """A budget nothing fits under falls through degrade_plan to the
    breaker: the engine re-traces on the jnp reference backend and keeps
    serving, parity intact."""
    imgs = _images(6)
    engine = CapsuleEngine(PARAMS, CFG, slots=2, backend="pallas")
    for i in range(6):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_TICK,
                                 kind="vmem_shrink", at=1, times=1,
                                 factor=0.0005)):
        engine.run()
    s = _assert_terminal(engine)
    assert s["ok"] == 6
    assert s["breaker_trips"] == 1 and s["replans"] == 0
    assert s["degraded"] and engine.plan is None
    assert engine._backend == "jnp"
    assert engine._forward_traces == 2
    for r in engine.finished:
        np.testing.assert_allclose(r.lengths, _reference_lengths(imgs[r.rid]),
                                   rtol=1e-4, atol=1e-4)


def test_engine_plan_error_storm_trips_breaker():
    imgs = _images(4)
    engine = CapsuleEngine(PARAMS, CFG, slots=2, backend="pallas",
                           breaker_after=2)
    for i in range(4):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_FORWARD,
                                 kind="plan_error", at=0, times=2)):
        engine.run()
    s = _assert_terminal(engine)
    assert s["forward_failures"] == 2
    assert s["breaker_trips"] == 1 and s["degraded"]
    assert s["ok"] == 4                      # the reference path served them
    assert engine._backend == "jnp"
    # the pallas forward raised before its first dispatch, so the only
    # trace ever taken is the breaker's jnp one
    assert engine._forward_traces == 1


def test_engine_stall_detection_raises_named_error():
    engine = CapsuleEngine(PARAMS, CFG, slots=1, stall_ticks=5)
    engine.submit(CapsRequest(rid=0, image=_images(1)[0]))
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_TICK,
                                 kind="stall", at=0, times=1000)):
        with pytest.raises(EngineStalled, match="stalled"):
            engine.run()


def test_engine_run_max_ticks_bounds_the_loop():
    imgs = _images(3)
    engine = CapsuleEngine(PARAMS, CFG, slots=1)
    for i in range(3):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    with pytest.raises(EngineStalled, match="max_ticks=1"):
        engine.run(max_ticks=1)


def test_engine_bounded_queue_reject_and_shed_oldest():
    imgs = _images(3)
    rej = CapsuleEngine(PARAMS, CFG, slots=1, max_queue=2,
                        admission="reject")
    for i in range(3):
        rej.submit(CapsRequest(rid=i, image=imgs[i]))
    assert [r.rid for r in rej.finished] == [2]      # the newcomer paid
    assert rej.finished[0].status == "shed"
    rej.run()
    s = _assert_terminal(rej)
    assert s["ok"] == 2 and s["shed"] == 1

    old = CapsuleEngine(PARAMS, CFG, slots=1, max_queue=2,
                        admission="shed-oldest")
    for i in range(3):
        old.submit(CapsRequest(rid=i, image=imgs[i]))
    assert [r.rid for r in old.finished] == [0]      # the oldest paid
    old.run()
    s = _assert_terminal(old)
    assert s["ok"] == 2 and s["shed"] == 1
    assert sorted(r.rid for r in old.finished if r.status == "ok") == [1, 2]

    with pytest.raises(ValueError, match="admission"):
        CapsuleEngine(PARAMS, CFG, admission="coin-flip")


def test_engine_deadline_expires_to_timeout():
    imgs = _images(2)
    engine = CapsuleEngine(PARAMS, CFG, slots=1)
    engine.submit(CapsRequest(rid=0, image=imgs[0], deadline_s=0.0))
    engine.submit(CapsRequest(rid=1, image=imgs[1]))
    engine.run()
    s = _assert_terminal(engine)
    assert s["timeout"] == 1 and s["ok"] == 1
    by_rid = {r.rid: r for r in engine.finished}
    assert by_rid[0].status == "timeout" and by_rid[0].lengths is None
    assert by_rid[1].status == "ok"


def test_engine_acceptance_nan_storm_plus_half_vmem():
    """The ISSUE acceptance scenario: a NaN storm AND a 50% VMEM shrink
    mid-run; the engine terminates, every request is terminal, the
    counters sum, and surviving outputs match the reference."""
    imgs = _images(6)
    engine = CapsuleEngine(PARAMS, CFG, slots=2, backend="pallas")
    for i in range(6):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    with faults.inject(
            FaultSpec(site=faults.SITE_ENGINE_FORWARD, kind="nan_output",
                      at=0, times=2),
            FaultSpec(site=faults.SITE_ENGINE_TICK, kind="vmem_shrink",
                      at=2, times=1, factor=0.5)):
        engine.run()
    s = _assert_terminal(engine)
    assert s["poisoned"] >= 1
    assert s["vmem_budget"] == engine._orig_budget // 2
    for r in engine.finished:
        if r.status == "ok":
            np.testing.assert_allclose(
                r.lengths, _reference_lengths(imgs[r.rid]),
                rtol=1e-4, atol=1e-4)


def test_engine_no_faults_single_trace_regression():
    """With injection disabled the hardened engine behaves exactly like
    the seed: one forward trace across all occupancies, everything ok."""
    imgs = _images(5)
    engine = CapsuleEngine(PARAMS, CFG, slots=2)
    for i in range(5):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    engine.run()
    s = _assert_terminal(engine)
    assert s["ok"] == 5 and engine._forward_traces == 1
    assert not s["degraded"] and s["replans"] == 0


# -- training harness --------------------------------------------------------

def _loop(tmp_path, total=8, **kw):
    return CapsTrainLoop(SMOKE, CapsLoopConfig(
        total_steps=total, batch=8, ckpt_every=4,
        ckpt_dir=str(tmp_path / "ck"), log_every=1000, backend="jnp", **kw))


def test_nan_streak_bounds_consecutive_not_lifetime(tmp_path):
    """Regression for the satellite fix: three NON-consecutive NaN steps
    must survive max_nan_skips=2 (the bound is the streak), while three
    CONSECUTIVE ones must abort."""
    loop = _loop(tmp_path, total=8, max_nan_skips=2)
    with faults.inject(
            FaultSpec(site=faults.SITE_TRAIN_STEP, kind="nan_output", at=1),
            FaultSpec(site=faults.SITE_TRAIN_STEP, kind="nan_output", at=3),
            FaultSpec(site=faults.SITE_TRAIN_STEP, kind="inf_output", at=5)):
        hist = loop.run()
    assert loop.nan_skips == 3               # lifetime count still reported
    assert loop.step == 8
    steps = [h["step"] for h in hist]
    assert 2 not in steps and 4 not in steps and 6 not in steps

    dead = _loop(tmp_path / "dead", total=8, max_nan_skips=2)
    with faults.inject(FaultSpec(site=faults.SITE_TRAIN_STEP,
                                 kind="nan_output", at=1, times=3)):
        with pytest.raises(RuntimeError, match="diverged: 3 consecutive"):
            dead.run()


def test_stall_fault_fires_straggler_hook(tmp_path):
    calls = []
    loop = CapsTrainLoop(SMOKE, CapsLoopConfig(
        total_steps=10, batch=8, ckpt_every=100,
        ckpt_dir=str(tmp_path / "ck"), log_every=1000, backend="jnp",
        straggler_factor=3.0),
        on_straggler=lambda step, dt: calls.append((step, dt)))
    with faults.inject(FaultSpec(site=faults.SITE_TRAIN_STEP, kind="stall",
                                 at=8, seconds=30.0)):
        loop.run()
    assert len(calls) == 1
    step, dt = calls[0]
    assert step == 8 and dt >= 30.0          # virtual time, no real sleep


def test_preemption_save_commits_checkpoint(tmp_path):
    """``request_stop`` mid-run (here: from the straggler hook, the SIGTERM
    stand-in) commits a ``preempted`` checkpoint at the stopped step."""
    loop = CapsTrainLoop(SMOKE, CapsLoopConfig(
        total_steps=50, batch=8, ckpt_every=100,
        ckpt_dir=str(tmp_path / "ck"), log_every=1000, backend="jnp",
        straggler_factor=3.0),
        on_straggler=lambda step, dt: loop.request_stop())
    with faults.inject(FaultSpec(site=faults.SITE_TRAIN_STEP, kind="stall",
                                 at=7, seconds=30.0)):
        loop.run()
    assert loop.step < 50                    # preempted, not completed
    assert ckpt.latest_step(tmp_path / "ck") == loop.step
    manifest = json.loads(
        (tmp_path / "ck" / f"step_{loop.step:08d}" / "manifest.json")
        .read_text())
    assert manifest["extra"]["preempted"] is True
    # and the preempted state resumes cleanly
    resumed = _loop(tmp_path, total=loop.step + 2)
    hist = resumed.run(resume=True)
    assert hist and hist[0]["step"] == loop.step + 1


def test_heartbeat_tmp_does_not_collide_on_stem(tmp_path):
    """Satellite regression: the heartbeat staging file is ``a.json.tmp``
    (full name + suffix), so a sibling ``a.tmp`` is never clobbered and
    two heartbeats sharing a stem cannot race through one staging path."""
    sentinel = tmp_path / "hb.tmp"
    sentinel.write_text("do not touch")
    loop = _loop(tmp_path, total=1,
                 heartbeat_path=str(tmp_path / "hb.json"))
    loop._heartbeat(3, {"loss": 1.25})
    assert sentinel.read_text() == "do not touch"
    assert json.loads((tmp_path / "hb.json").read_text())["step"] == 3
    assert not (tmp_path / "hb.json.tmp").exists()   # staging file replaced
