"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def rand(shape, dtype=jnp.float32, k=KEY, scale=1.0):
    return (scale * jax.random.normal(k, shape)).astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# caps_votes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,i,c,n", [(1, 64, 8, 160), (2, 256, 8, 160),
                                     (3, 128, 16, 80), (1, 1152, 8, 160)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_caps_votes(b, i, c, n, dtype):
    u = rand((b, i, c), dtype)
    w = rand((i, n, c), dtype)
    got = ops.caps_votes(u, w)
    want = ref.caps_votes(u.astype(jnp.float32), w.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=TOL[dtype], atol=TOL[dtype] * 8)


def test_caps_votes_block_sweep():
    u = rand((2, 256, 8))
    w = rand((256, 160, 8))
    want = ref.caps_votes(u, w)
    for bi in (32, 64, 128, 256):
        got = ops.caps_votes(u, w, block_i=bi)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("i,bi", [(300, 128), (135, 32), (27, 8), (100, 256)])
def test_caps_votes_ragged_tail(i, bi):
    """I need not divide block_i (grid = cdiv, masked/clamped tail)."""
    u = rand((2, i, 8))
    w = rand((i, 40, 8))
    got = ops.caps_votes(u, w, block_i=bi)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.caps_votes(u, w)),
                               rtol=1e-5, atol=1e-5)


def test_caps_votes_planned_block_not_degenerate():
    """Regression: non-power-of-two capsule counts used to collapse the
    planner pick to block_i=1 via the old ``while i % bi: bi //= 2``."""
    for i in (27, 300, 1100):
        bi = ops.planned_block_i(i, 8, 160)
        assert 8 <= bi <= i
    u = rand((1, 1100, 8))
    w = rand((1100, 160, 8))
    got = ops.caps_votes(u, w)                    # default = planner pick
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.caps_votes(u, w)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# routing (fused) -- the paper's on-chip-resident loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("iters", [1, 2, 3, 5])
@pytest.mark.parametrize("b,i,j,d", [(1, 64, 10, 16), (2, 1152, 10, 16),
                                     (3, 96, 4, 8)])
def test_routing_fused(iters, b, i, j, d):
    uh = 0.1 * rand((b, i, j * d))
    got = ops.routing(uh, iters=iters, num_classes=j)
    want = ref.routing(uh.reshape(b, i, j, d), iters).reshape(b, j * d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_routing_matches_capsnet_module():
    from repro.core.capsnet import routing_by_agreement
    uh = 0.1 * rand((2, 128, 160))
    got = ops.routing(uh, iters=3, num_classes=10)
    want = routing_by_agreement(uh.reshape(2, 128, 10, 16), 3)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want.reshape(2, 160)),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# squash / rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 16), (4, 100, 16), (1, 1152, 8),
                                   (2, 3, 5, 8)])
def test_squash(shape):
    x = rand(shape)
    np.testing.assert_allclose(np.asarray(ops.squash(x)),
                               np.asarray(ref.squash(x)), rtol=1e-5,
                               atol=1e-6)


def test_squash_norm_bound():
    x = 100.0 * rand((16, 32))
    v = ops.squash(x)
    norms = np.linalg.norm(np.asarray(v), axis=-1)
    assert (norms <= 1.0 + 1e-5).all()


def test_squash_ragged_rows():
    x = rand((7, 5, 8))                      # 35 rows, not a block multiple
    got = ops.squash(x, block_rows=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.squash(x)),
                               rtol=1e-5, atol=1e-6)


def test_squash_single_canonical_implementation():
    """The kernel and the fused routing kernel share core.capsnet.squash
    (ref.squash stays an independent oracle)."""
    from repro.core.capsnet import squash as canonical
    from repro.kernels import routing as routing_mod
    from repro.kernels import squash as squash_mod
    assert squash_mod.squash_reference is canonical
    assert routing_mod.squash is canonical
    x = rand((13, 16), scale=5.0)
    np.testing.assert_allclose(np.asarray(canonical(x)),
                               np.asarray(ref.squash(x)),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(ops.squash(x)),
                               np.asarray(canonical(x)),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("rows,d", [(8, 64), (1024, 512), (7, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(rows, d, dtype):
    x = rand((rows, d), dtype)
    w = rand((d,), scale=0.1)
    got = ops.rmsnorm(x, w)
    want = ref.rmsnorm(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=TOL[dtype], atol=TOL[dtype])


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tq,tk,win,cap,causal", [
    (128, 128, None, None, True),
    (256, 256, 64, None, True),
    (128, 128, None, 50.0, True),
    (1, 256, None, None, True),          # decode
    (8, 264, 32, 30.0, True),            # non-pow2 kv + window + softcap
    (64, 64, None, None, False),         # bidirectional
    (96, 96, 16, None, True),
])
def test_flash_attention(tq, tk, win, cap, causal):
    ks = jax.random.split(KEY, 3)
    q = rand((2, 4, tq, 64), k=ks[0])
    k = rand((2, 4, tk, 64), k=ks[1])
    v = rand((2, 4, tk, 64), k=ks[2])
    got = ops.flash_attention(q, k, v, causal=causal, window=win,
                              softcap=cap)
    want = ref.attention(q, k, v, causal=causal, window=win, softcap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("d", [64, 128, 256])
def test_flash_attention_head_dims(d):
    ks = jax.random.split(KEY, 3)
    q = rand((1, 2, 128, d), k=ks[0])
    k = rand((1, 2, 128, d), k=ks[1])
    v = rand((1, 2, 128, d), k=ks[2])
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_block_sweep():
    ks = jax.random.split(KEY, 3)
    q, k, v = (rand((1, 2, 256, 64), k=kk) for kk in ks)
    want = ref.attention(q, k, v, causal=True)
    for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]:
        got = ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                  block_k=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_flash_vs_model_attention():
    """Flash kernel == the model's grouped_attention on expanded heads."""
    from repro.models.attention import grouped_attention
    ks = jax.random.split(KEY, 3)
    b, h, t, d = 2, 4, 64, 32
    q = rand((b, t, h, d), k=ks[0])
    k = rand((b, t, h, d), k=ks[1])
    v = rand((b, t, h, d), k=ks[2])
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    want = grouped_attention(q, k, v, pos, pos, causal=True, window=None,
                             softcap=None, scale=d ** -0.5)
    got = ops.flash_attention(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(np.asarray(got.transpose(0, 2, 1, 3)),
                               np.asarray(want), rtol=2e-5, atol=2e-5)
