"""Plan-driven im2col conv kernels vs the XLA convolution oracle.

Covers ragged M/N grid tiles, K zero-padding, strided patch extraction,
the bias/ReLU/squash epilogues, and the plan-aware ``ops.conv2d`` wrapper.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.capsnet import squash
from repro.kernels import ops
from repro.kernels.conv_im2col import (conv2d_im2col, im2col_patches,
                                       matmul_bias_act)

KEY = jax.random.PRNGKey(0)


def _conv_ref(x, w, b, stride):
    out = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


@pytest.mark.parametrize(
    "batch,hw,k,cin,cout,stride",
    [
        (2, 11, 3, 3, 24, 1),      # ragged M and N vs 8/128-ish tiles
        (3, 14, 5, 7, 20, 2),      # strided, K=175 forces zero-padding
        (1, 9, 4, 2, 12, 3),       # stride > kernel overlap, tiny channels
        (2, 28, 9, 1, 32, 1),      # MNIST Conv1 shape (narrow)
    ])
def test_conv_im2col_matches_lax(batch, hw, k, cin, cout, stride):
    x = jax.random.uniform(KEY, (batch, hw, hw, cin))
    w = 0.1 * jax.random.normal(KEY, (k, k, cin, cout))
    b = 0.1 * jax.random.normal(KEY, (cout,))
    want = _conv_ref(x, w, b, stride)
    got = conv2d_im2col(x, w, b, stride=stride,
                        block_m=8, block_k=16, block_n=8)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_conv_relu_epilogue():
    x = jax.random.uniform(KEY, (2, 10, 10, 3))
    w = 0.5 * jax.random.normal(KEY, (3, 3, 3, 16))
    b = jnp.linspace(-0.5, 0.5, 16)
    want = jnp.maximum(_conv_ref(x, w, b, 1), 0.0)
    got = conv2d_im2col(x, w, b, stride=1, epilogue="relu",
                        block_m=16, block_k=8, block_n=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_conv_squash_epilogue_matches_unfused():
    """Fused per-capsule squash == conv + bias, then squash over dim-4
    channel groups (the PrimaryCaps activation)."""
    pd = 4
    x = jax.random.uniform(KEY, (2, 12, 12, 5))
    w = 0.3 * jax.random.normal(KEY, (3, 3, 5, 24))
    b = 0.1 * jax.random.normal(KEY, (24,))
    pre = _conv_ref(x, w, b, 2)
    want = squash(pre.reshape(*pre.shape[:-1], 24 // pd, pd)).reshape(pre.shape)
    got = conv2d_im2col(x, w, b, stride=2, epilogue="squash", squash_dim=pd,
                        block_m=8, block_k=16, block_n=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_squash_epilogue_rejects_misaligned_tile():
    x = jax.random.uniform(KEY, (1, 8, 8, 2))
    w = jax.random.normal(KEY, (3, 3, 2, 12))
    b = jnp.zeros((12,))
    with pytest.raises(ValueError):
        conv2d_im2col(x, w, b, epilogue="squash", squash_dim=5, block_n=8)
    with pytest.raises(ValueError):            # default squash_dim=0: clear
        conv2d_im2col(x, w, b, epilogue="squash")  # error, not ZeroDivision


def test_unknown_epilogue_rejected():
    with pytest.raises(ValueError):
        matmul_bias_act(jnp.ones((4, 4)), jnp.ones((4, 4)), jnp.ones((4,)),
                        epilogue="gelu")


def test_patches_match_manual_extraction():
    """Patch column order is (kh, kw, c)-major -- what w.reshape expects."""
    b, hw, k, c, stride = 2, 7, 3, 2, 2
    x = np.asarray(jax.random.uniform(KEY, (b, hw, hw, c)))
    oh = (hw - k) // stride + 1
    got = np.asarray(im2col_patches(jnp.asarray(x), kh=k, kw=k, stride=stride))
    assert got.shape == (b, oh * oh, k * k * c)
    for bi in range(b):
        for i in range(oh):
            for j in range(oh):
                patch = x[bi, i * stride:i * stride + k,
                          j * stride:j * stride + k, :]
                np.testing.assert_array_equal(got[bi, i * oh + j],
                                              patch.reshape(-1))


def test_ops_conv2d_uses_planned_blocks_without_plan():
    """The memoized planner pick drives the wrapper when no plan is given."""
    x = jax.random.uniform(KEY, (2, 14, 14, 1))
    w = 0.1 * jax.random.normal(KEY, (5, 5, 1, 16))
    b = 0.1 * jax.random.normal(KEY, (16,))
    want = _conv_ref(x, w, b, 1)
    got = ops.conv2d(x, w, b, stride=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    bm, bk, bn = ops.planned_conv_blocks(2 * 10 * 10, 25, 16)
    assert bm >= 8 and bk >= 25 and bn >= 16     # aligned planner tiles


def test_ops_conv2d_uses_plan_op_blocks():
    from repro.core.capsnet import CapsNetConfig
    from repro.core.execplan import compile_plan
    cfg = CapsNetConfig(image_hw=14, conv1_channels=16, conv1_kernel=5,
                        pc_kernel=3, num_primary_groups=4, primary_dim=4,
                        class_dim=8, use_decoder=False)
    plan = compile_plan(cfg, batch=2)
    params_w = 0.1 * jax.random.normal(KEY, (5, 5, 1, 16))
    params_b = jnp.zeros((16,))
    x = jax.random.uniform(KEY, (2, 14, 14, 1))
    want = jnp.maximum(_conv_ref(x, params_w, params_b, 1), 0.0)
    got = ops.conv2d(x, params_w, params_b, stride=1,
                     plan_op=plan.op("Conv1"), epilogue="relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
