import os
import sys

# Tests see ONE device (the dry-run is the only place that forces 512).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
