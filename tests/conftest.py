import importlib.util
import os
import sys

# Tests see ONE device (the dry-run is the only place that forces 512).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis when available; otherwise fall back to the
# minimal deterministic shim in tests/_fallback so the suite still collects
# and runs (the real package always wins when installed).
if importlib.util.find_spec("hypothesis") is None:
    sys.path.append(os.path.join(os.path.dirname(__file__), "_fallback"))
