"""Multi-device CapsuleEngine serving: CPU-mesh parity and chaos at
2/4/8 virtual devices (8 forced host devices in a subprocess so the main
test process keeps 1 device -- same idiom as ``test_sharding.py``).

The acceptance claims checked here:
  * the sharded engine serves ``n_shards * slots_per_shard`` concurrent
    requests with ONE forward trace (``_forward_traces``);
  * outputs are bit-identical to the single-device engine for the same
    request stream, at every shard count, on both backends;
  * fault injection (vmem_shrink replan, NaN storm) keeps working per
    shard: ONE re-trace across the whole mesh, terminal statuses, and
    per-shard counters that sum to ``submitted``.
"""

import json
import subprocess
import sys
import textwrap

import pytest

SUBPROCESS_SRC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import numpy as np
    from repro.core import capsnet, faults
    from repro.core.capsnet import CapsNetConfig
    from repro.core.faults import FaultSpec
    from repro.serve import CapsRequest, CapsuleEngine

    CFG = CapsNetConfig(image_hw=14, conv1_channels=16, conv1_kernel=5,
                        pc_kernel=3, num_primary_groups=4, primary_dim=4,
                        class_dim=8, use_decoder=False)
    PARAMS = capsnet.init_params(jax.random.PRNGKey(0), CFG)
    IMGS = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(1), (16, CFG.image_hw, CFG.image_hw, 1)),
        np.float32)

    def serve(engine, n=16):
        for i in range(n):
            engine.submit(CapsRequest(rid=i, image=IMGS[i % len(IMGS)]))
        engine.run()
        return {r.rid: (np.asarray(r.lengths), r.pred)
                for r in engine.finished}

    from repro.verify import check_engine_stats

    def shard_sums_ok(s):
        # Shared counter-sum checker (also used by tests/test_faults.py
        # and documented by ``python -m repro.verify``).
        return not check_engine_stats(s)

    out = {"device_count": jax.device_count()}

    # -- jnp parity at every shard count vs the single-device engine ----
    ref = serve(CapsuleEngine(PARAMS, CFG, slots=16))
    for n in (1, 2, 4, 8):
        eng = CapsuleEngine(PARAMS, CFG, slots=16, n_shards=n)
        got = serve(eng)
        out[f"jnp_x{n}"] = dict(
            bit_identical=all(np.array_equal(ref[k][0], got[k][0])
                              and ref[k][1] == got[k][1] for k in ref),
            traces=eng._forward_traces,
            ticks=eng.ticks,
            shard_sums=shard_sums_ok(eng.stats()))

    # -- 8 * slots_per_shard concurrent requests, one tick, one trace ---
    eng = CapsuleEngine(PARAMS, CFG, slots=16, n_shards=8)
    for i in range(16):
        eng.submit(CapsRequest(rid=i, image=IMGS[i]))
    eng.step()
    s = eng.stats()
    out["concurrent"] = dict(slots_per_shard=eng.slots_per_shard,
                             ok_first_tick=s["ok"],
                             occupancy=s["occupancy"],
                             traces=eng._forward_traces)

    # -- pallas: per-shard plan, bit-identical to single-device pallas --
    pref = serve(CapsuleEngine(PARAMS, CFG, slots=16, backend="pallas"))
    eng = CapsuleEngine(PARAMS, CFG, slots=16, backend="pallas",
                        n_shards=8)
    got = serve(eng)
    out["pallas_x8"] = dict(
        bit_identical=all(np.array_equal(pref[k][0], got[k][0])
                          for k in pref),
        plan_batch=eng.plan.batch, traces=eng._forward_traces)

    # -- vmem_shrink under sharding: one replan, ONE mesh-wide re-trace -
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_TICK,
                                 kind="vmem_shrink", at=1, times=2,
                                 factor=0.012)):
        eng = CapsuleEngine(PARAMS, CFG, slots=8, backend="pallas",
                            n_shards=2)
        serve(eng)
    s = eng.stats()
    out["vmem_shrink_x2"] = dict(ok=s["ok"], replans=s["replans"],
                                 degraded=s["degraded"],
                                 traces=eng._forward_traces,
                                 shard_sums=shard_sums_ok(s))

    # -- NaN storm under sharding: terminal + per-shard sums ------------
    with faults.inject(FaultSpec(site=faults.SITE_ENGINE_FORWARD,
                                 kind="nan_output", at=0, times=2)):
        eng = CapsuleEngine(PARAMS, CFG, slots=8, n_shards=4,
                            retry_backoff_ticks=0)
        serve(eng)
    s = eng.stats()
    out["nan_storm_x4"] = dict(
        submitted=s["submitted"], poisoned=s["poisoned"],
        terminal=s["ok"] + s["timeout"] + s["error"] + s["shed"],
        shard_sums=shard_sums_ok(s))

    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def mesh_results():
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_SRC],
                         capture_output=True, text=True, timeout=900,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["device_count"] == 8
    return res


@pytest.mark.parametrize("n", [1, 2, 4, 8])
def test_sharded_parity_bit_identical(mesh_results, n):
    r = mesh_results[f"jnp_x{n}"]
    assert r["bit_identical"]
    assert r["traces"] == 1
    assert r["shard_sums"]


def test_full_mesh_serves_concurrently_one_trace(mesh_results):
    r = mesh_results["concurrent"]
    assert r["ok_first_tick"] == 8 * r["slots_per_shard"] == 16
    assert r["occupancy"] == 1.0
    assert r["traces"] == 1


def test_pallas_sharded_parity_and_per_shard_plan(mesh_results):
    r = mesh_results["pallas_x8"]
    assert r["bit_identical"]
    assert r["plan_batch"] == 2          # slots=16 over 8 shards
    assert r["traces"] == 1


def test_vmem_shrink_under_sharding_one_mesh_retrace(mesh_results):
    r = mesh_results["vmem_shrink_x2"]
    assert r["ok"] == 16 and r["replans"] == 1 and r["degraded"]
    assert r["traces"] == 2              # healthy trace + degraded trace
    assert r["shard_sums"]


def test_nan_storm_under_sharding_terminal(mesh_results):
    r = mesh_results["nan_storm_x4"]
    assert r["terminal"] == r["submitted"] == 16
    assert r["poisoned"] >= 2
    assert r["shard_sums"]
