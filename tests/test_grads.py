"""Differentiable Pallas path: gradient parity vs the jnp reference.

``jax.grad`` through ``backend="pallas"`` runs the kernels' custom VJPs
(backward im2col: col2im scatter + patches^T dy matmul; fused
votes+routing backward: routing replay in VMEM scratch honoring the
reference's ``stop_gradient(u_hat)`` convention).  Property-based tests
sweep ragged i-blocks, non-power-of-two capsule counts (groups=24),
batch>1, and both routing modes -- including a VMEM budget that flips the
mode -- asserting parity with ``jax.grad`` of the jnp reference to <= 1e-5
relative error, plus the backward-plan invariants (``uhat_hbm_bytes=0``,
the forward-plans/backward-raises PlanError boundary).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import capsnet, execplan
from repro.core.capsnet import CapsNetConfig
from repro.core.execplan import (BWD_SUFFIX, FUSED_NAME, PlanError,
                                 compile_plan, plan_votes_routing_bwd,
                                 spilled_votes_routing_bwd_hbm_bytes,
                                 votes_routing_bwd_hbm_bytes)
from repro.kernels import ops
from repro.kernels.conv_im2col import (col2im_patches, conv2d_im2col,
                                       im2col_patches, matmul_at_b)

KEY = jax.random.PRNGKey(0)
TOL = 1e-5

SMOKE = CapsNetConfig(image_hw=14, conv1_channels=16, conv1_kernel=5,
                      pc_kernel=3, num_primary_groups=4, primary_dim=4,
                      class_dim=8, decoder_hidden=(32, 64))
# Odd image + 24 capsule groups: num_primary = 600, every dimension
# non-power-of-two (the NONPOW2 config of test_execplan).
NONPOW2 = CapsNetConfig(image_hw=15, conv1_channels=24, conv1_kernel=5,
                        pc_kernel=3, pc_stride=2, num_primary_groups=24,
                        primary_dim=4, class_dim=8, use_decoder=False)


def _rel(got, want):
    got, want = np.asarray(got), np.asarray(want)
    return np.abs(got - want).max() / (np.abs(want).max() + 1e-12)


def _uv(b, i, c, jd, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, seed), 3)
    u = 0.5 * jax.random.normal(k1, (b, i, c))
    w = 0.3 * jax.random.normal(k2, (i, jd, c))
    return u, w, k3


# ---------------------------------------------------------------------------
# Backward building blocks
# ---------------------------------------------------------------------------

def test_matmul_at_b_matches_einsum_with_ragged_reduction():
    k1, k2 = jax.random.split(KEY)
    a = jax.random.normal(k1, (45, 13))          # M=45 ragged vs block_m=16
    b = jax.random.normal(k2, (45, 21))
    got = matmul_at_b(a, b, block_m=16, block_k=8, block_n=8)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(a.T @ b), rtol=1e-5, atol=1e-6)


def test_col2im_is_adjoint_of_im2col():
    """<col2im(dp), x> == <dp, im2col(x)>: the scatter kernel is the
    exact transpose of the strided patch extraction."""
    k1, k2 = jax.random.split(KEY)
    for stride in (1, 2):
        x = jax.random.normal(k1, (2, 9, 9, 3))
        oh = (9 - 3) // stride + 1
        dp = jax.random.normal(k2, (2, oh * oh, 3 * 3 * 3))
        patches = im2col_patches(x, kh=3, kw=3, stride=stride)
        dx = col2im_patches(dp, kh=3, kw=3, stride=stride, h=9, w=9)
        lhs = float(jnp.sum(dx * x))
        rhs = float(jnp.sum(dp * patches))
        assert lhs == pytest.approx(rhs, rel=1e-5)


@pytest.mark.parametrize("epilogue,squash_dim,stride", [
    ("none", 0, 1), ("relu", 0, 1), ("relu", 0, 2), ("squash", 4, 2)])
def test_conv_grad_matches_lax_conv_reference(epilogue, squash_dim, stride):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    x = jax.random.normal(k1, (2, 11, 11, 3))
    w = 0.2 * jax.random.normal(k2, (3, 3, 3, 8))
    bias = 0.1 * jax.random.normal(k3, (8,))
    oh = (11 - 3) // stride + 1
    dy = jax.random.normal(k4, (2, oh, oh, 8))

    def f_pal(x, w, bias):
        out = conv2d_im2col(x, w, bias, stride=stride, block_m=16,
                            block_k=8, block_n=8, epilogue=epilogue,
                            squash_dim=squash_dim)
        return jnp.sum(out * dy)

    def f_ref(x, w, bias):
        out = jax.lax.conv_general_dilated(
            x, w, (stride, stride), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + bias
        if epilogue == "relu":
            out = jax.nn.relu(out)
        elif epilogue == "squash":
            s = out.shape
            out = capsnet.squash(out.reshape(*s[:3], s[3] // squash_dim,
                                             squash_dim)).reshape(s)
        return jnp.sum(out * dy)

    g_pal = jax.grad(f_pal, argnums=(0, 1, 2))(x, w, bias)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, bias)
    for got, want in zip(g_pal, g_ref):
        assert _rel(got, want) <= TOL


def test_squash_kernel_grad_matches_reference():
    x = jax.random.normal(KEY, (3, 37, 6))       # ragged rows vs block 16
    dy = jax.random.normal(jax.random.fold_in(KEY, 1), x.shape)
    g_pal = jax.grad(lambda x: jnp.sum(
        ops.squash(x, block_rows=16) * dy))(x)
    g_ref = jax.grad(lambda x: jnp.sum(capsnet.squash(x) * dy))(x)
    assert _rel(g_pal, g_ref) <= TOL


# ---------------------------------------------------------------------------
# Fused votes+routing backward: the full (mode x bwd_mode x shape) matrix
# ---------------------------------------------------------------------------

def _vr_grad_pair(u, w, dv, *, iters, j, d, mode, bwd_mode, bi, bwd_bi):
    b, i, c = u.shape

    def loss_pal(u, w):
        v = ops.votes_routing(u, w, iters=iters, num_classes=j, mode=mode,
                              block_i=bi, bwd_mode=bwd_mode,
                              bwd_block_i=bwd_bi)
        return jnp.sum(v.reshape(b, j, d) * dv)

    def loss_ref(u, w):
        uh = capsnet.compute_votes(u, w.reshape(i, j, d, c))
        return jnp.sum(capsnet.routing_by_agreement(uh, iters) * dv)

    return (jax.grad(loss_pal, argnums=(0, 1))(u, w),
            jax.grad(loss_ref, argnums=(0, 1))(u, w))


@pytest.mark.parametrize("mode", ["resident", "streamed"])
@pytest.mark.parametrize("bwd_mode", ["resident", "streamed"])
@pytest.mark.parametrize("b,i,c,j,d,bi,iters", [
    (1, 64, 8, 10, 16, 32, 3),       # divisible blocks
    (2, 100, 8, 10, 16, 32, 3),      # ragged final i-block + batch>1
    (2, 27, 4, 4, 8, 8, 1),          # odd non-power-of-two capsule count
], ids=["even", "ragged", "nonpow2"])
def test_votes_routing_grad_parity(mode, bwd_mode, b, i, c, j, d, bi, iters):
    u, w, k3 = _uv(b, i, c, j * d, seed=i + iters)
    dv = jax.random.normal(k3, (b, j, d))
    got, want = _vr_grad_pair(u, w, dv, iters=iters, j=j, d=d, mode=mode,
                              bwd_mode=bwd_mode, bi=bi,
                              bwd_bi=max(bi // 2, 1))
    for g, r in zip(got, want):
        assert _rel(g, r) <= TOL


@given(i=st.integers(9, 80), bi=st.integers(1, 48),
       bwd_mode=st.sampled_from(["resident", "streamed"]))
@settings(max_examples=8, deadline=None)
def test_votes_routing_grad_property(i, bi, bwd_mode):
    """Property sweep: ANY capsule count / i-tile pair stays at parity
    (ragged tails, block_i > I clamping, degenerate block_i=1)."""
    b, c, j, d = 2, 4, 4, 4
    u, w, k3 = _uv(b, i, c, j * d, seed=1000 + i + bi)
    dv = jax.random.normal(k3, (b, j, d))
    got, want = _vr_grad_pair(u, w, dv, iters=2, j=j, d=d, mode="streamed",
                              bwd_mode=bwd_mode, bi=min(bi, i),
                              bwd_bi=min(bi, i))
    for g, r in zip(got, want):
        assert _rel(g, r) <= TOL


@pytest.mark.parametrize("b,i,c,j,d,bi,iters", [
    (1, 64, 8, 10, 16, 32, 3),       # divisible blocks
    (2, 100, 8, 10, 16, 32, 3),      # ragged final i-block + batch>1
    (2, 27, 4, 4, 8, 8, 1),          # odd non-power-of-two capsule count
], ids=["even", "ragged", "nonpow2"])
def test_streamed_fused_bwd_matches_2pass_oracle(b, i, c, j, d, bi, iters):
    """The fused replay (iters+4 W passes) produces the SAME gradients as
    the unfused 2-pass replay oracle (2*iters+4 passes) -- and both match
    the jnp reference."""
    u, w, k3 = _uv(b, i, c, j * d, seed=50 + i + iters)
    dv = jax.random.normal(k3, (b, j, d))
    fused, want = _vr_grad_pair(u, w, dv, iters=iters, j=j, d=d,
                                mode="streamed", bwd_mode="streamed",
                                bi=bi, bwd_bi=max(bi // 2, 1))
    oracle, _ = _vr_grad_pair(u, w, dv, iters=iters, j=j, d=d,
                              mode="streamed-2pass",
                              bwd_mode="streamed-2pass",
                              bi=bi, bwd_bi=max(bi // 2, 1))
    for g_f, g_o, g_r in zip(fused, oracle, want):
        np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_o),
                                   rtol=1e-5, atol=1e-7)
        assert _rel(g_f, g_r) <= TOL


def test_grad_through_planless_wrapper():
    """Without a plan the wrapper resolves the backward schedule through
    the memoized backward plan decision and still matches the reference."""
    u, w, k3 = _uv(2, 150, 8, 80, seed=7)
    dv = jax.random.normal(k3, (2, 10, 8))

    def loss(u, w):
        return jnp.sum(ops.votes_routing(u, w, iters=3, num_classes=10
                                         ).reshape(2, 10, 8) * dv)

    def loss_ref(u, w):
        uh = capsnet.compute_votes(u, w.reshape(150, 10, 8, 8))
        return jnp.sum(capsnet.routing_by_agreement(uh, 3) * dv)

    got = jax.grad(loss, argnums=(0, 1))(u, w)
    want = jax.grad(loss_ref, argnums=(0, 1))(u, w)
    for g, r in zip(got, want):
        assert _rel(g, r) <= TOL
    mode, bi = ops.planned_votes_routing_bwd(150, 8, 80, 10, 3, 2)
    assert mode in ("resident", "streamed") and 1 <= bi <= 150


# ---------------------------------------------------------------------------
# End-to-end: margin loss + reconstruction through the whole network
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg,batch", [(SMOKE, 3), (NONPOW2, 2)],
                         ids=["smoke", "nonpow2"])
def test_total_loss_grad_parity(cfg, batch):
    params = capsnet.init_params(KEY, cfg)
    imgs = jax.random.uniform(KEY, (batch, cfg.image_hw, cfg.image_hw, 1))
    labels = jnp.arange(batch) % cfg.num_classes

    def loss(backend):
        return lambda p: capsnet.total_loss(p, imgs, labels, cfg,
                                            backend=backend)[0]

    g_jnp = jax.grad(loss("jnp"))(params)
    g_pal = jax.grad(loss("pallas"))(params)
    for k in g_jnp:
        assert _rel(g_pal[k], g_jnp[k]) <= TOL, k


def test_budget_flip_to_streamed_keeps_grad_parity():
    """A VMEM budget under the resident floors flips BOTH the forward and
    the backward to streamed -- and the gradients still match the jnp
    reference (the mode-flip case of the parity matrix)."""
    budget = 300_000
    dims_i, c = NONPOW2.num_primary, NONPOW2.primary_dim
    jd = NONPOW2.num_classes * NONPOW2.class_dim
    assert execplan._fused_resident_vmem(2, dims_i, 1, c, jd, 10) > budget
    assert execplan._fused_resident_bwd_vmem(
        2, dims_i, 1, c, jd, 10, NONPOW2.routing_iters) > budget
    plan = compile_plan(NONPOW2, batch=2, vmem_budget=budget, train=True)
    assert plan.op(FUSED_NAME).mode == "streamed"
    assert plan.op(FUSED_NAME + BWD_SUFFIX).mode == "streamed"

    params = capsnet.init_params(KEY, NONPOW2)
    imgs = jax.random.uniform(KEY, (2, 15, 15, 1))
    labels = jnp.array([2, 8])
    g_pal = jax.grad(lambda p: capsnet.total_loss(
        p, imgs, labels, NONPOW2, backend="pallas", plan=plan)[0])(params)
    g_jnp = jax.grad(lambda p: capsnet.total_loss(
        p, imgs, labels, NONPOW2)[0])(params)
    for k in g_jnp:
        assert _rel(g_pal[k], g_jnp[k]) <= TOL, k


def test_train_step_improves_loss_on_pallas_backend():
    params = capsnet.init_params(KEY, SMOKE)
    from repro.train.data import DataConfig, mnist_batch
    dc = DataConfig(kind="mnist", global_batch=16)
    losses = []
    for step in range(14):
        b = mnist_batch(dc, step, image_hw=14)
        params, m = capsnet.train_step(params, b["images"], b["labels"],
                                       SMOKE, lr=3e-2, backend="pallas")
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    # per-batch losses are noisy; compare window means like the jnp test
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


# ---------------------------------------------------------------------------
# Backward plan: uhat_hbm_bytes=0, traffic model, PlanError boundary
# ---------------------------------------------------------------------------

def test_backward_plan_reports_zero_uhat_traffic():
    plan = compile_plan(CapsNetConfig(), batch=8, train=True)
    bwd = plan.op(FUSED_NAME + BWD_SUFFIX)
    assert bwd.uhat_hbm_bytes == 0
    assert bwd.kernel == "votes_routing_bwd"
    cfg = CapsNetConfig()
    jd = cfg.num_classes * cfg.class_dim
    fused = votes_routing_bwd_hbm_bytes(8, cfg.num_primary, cfg.primary_dim,
                                        jd, mode=bwd.mode,
                                        iters=cfg.routing_iters)
    assert bwd.hbm_bytes == fused
    spilled, uhat = spilled_votes_routing_bwd_hbm_bytes(
        8, cfg.num_primary, cfg.primary_dim, jd)
    # u_hat is written+read and its cotangent round-trips the same way
    assert uhat == 4 * 8 * cfg.num_primary * jd * execplan.ELEM_BYTES
    assert fused < spilled                # the fused backward moves less
    # the backward phases are gated like the forward's
    groups = dict(plan.phase_groups())
    assert groups[FUSED_NAME + BWD_SUFFIX] == (
        "Update+Sum-bwd", "Sum+Squash-bwd", "ClassCaps-FC-bwd")
    assert "Conv1-bwd" in groups and "PrimaryCaps-bwd" in groups


def test_forward_only_backward_fallback_warns_once():
    """A forward-only caller whose backward cannot plan gets a ONE-TIME
    RuntimeWarning naming the exceeded budget (the old silent fallback
    left a later jax.grad running an unvalidated footprint with no
    trace), and the forward still executes and matches the reference."""
    import warnings as _warnings
    from repro.core import analysis
    from repro.kernels.ops import _warn_bwd_fallback_once
    dims = analysis.dims_from_config(NONPOW2)
    jd = dims.num_classes * dims.class_dim
    floor = execplan._fused_streamed_bwd_vmem(
        2, dims.num_primary, 1, dims.primary_dim, jd, dims.num_classes,
        dims.routing_iters)
    plan = compile_plan(NONPOW2, batch=2, vmem_budget=floor - 1)
    u, w, _ = _uv(2, dims.num_primary, dims.primary_dim, jd, seed=77)
    _warn_bwd_fallback_once.cache_clear()
    with pytest.warns(RuntimeWarning, match="no feasible backward") as rec:
        got = ops.votes_routing(u, w, plan=plan)
    assert f"{floor - 1} B" in str(rec[0].message)      # names the budget
    assert FUSED_NAME + BWD_SUFFIX in str(rec[0].message)  # names the op
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")                 # second call: silent
        ops.votes_routing(u, w, plan=plan)
    want = capsnet.routing_by_agreement(
        capsnet.compute_votes(u, w.reshape(dims.num_primary,
                                           dims.num_classes, dims.class_dim,
                                           dims.primary_dim)),
        dims.routing_iters).reshape(2, jd)
    assert _rel(got, want) <= 1e-4


def test_backward_traffic_model_counts_fused_passes():
    """votes_routing_bwd_hbm_bytes streams W iters+4 times in streamed
    mode (the fused replay), not the old 2*iters+4."""
    cfg = CapsNetConfig()
    jd = cfg.num_classes * cfg.class_dim
    stre = votes_routing_bwd_hbm_bytes(2, cfg.num_primary, cfg.primary_dim,
                                       jd, mode="streamed", iters=3)
    res = votes_routing_bwd_hbm_bytes(2, cfg.num_primary, cfg.primary_dim,
                                      jd, mode="resident", iters=3)
    w_sweep = cfg.num_primary * jd * cfg.primary_dim * execplan.ELEM_BYTES
    u_bytes = 2 * cfg.num_primary * cfg.primary_dim * execplan.ELEM_BYTES
    # streamed - resident = (iters+4-2) W sweeps minus one fewer u pass
    assert stre - res == (3 + 4 - 2) * w_sweep - u_bytes


def test_smallest_backward_infeasible_budget_raises_at_source():
    """The smallest budget that plans the forward but not the backward
    raises a PlanError naming the backward op and the largest feasible
    batch -- not an opaque validate() footprint complaint."""
    from repro.core import analysis
    dims = analysis.dims_from_config(NONPOW2)
    jd = dims.num_classes * dims.class_dim
    floor = execplan._fused_streamed_bwd_vmem(
        2, dims.num_primary, 1, dims.primary_dim, jd, dims.num_classes,
        dims.routing_iters)
    # one byte under the backward floor: the forward still plans...
    fwd_plan = compile_plan(NONPOW2, batch=2, vmem_budget=floor - 1)
    assert fwd_plan.op(FUSED_NAME).mode == "streamed"
    # ...but the training plan fails with the named boundary
    with pytest.raises(PlanError) as exc:
        compile_plan(NONPOW2, batch=2, vmem_budget=floor - 1, train=True)
    msg = str(exc.value)
    assert FUSED_NAME + BWD_SUFFIX in msg
    assert "batch=2" in msg
    assert "largest feasible batch is 1" in msg
    # at the floor itself the backward plans (streamed block_i=1)
    at_floor = compile_plan(NONPOW2, batch=2, vmem_budget=floor, train=True)
    bwd = at_floor.op(FUSED_NAME + BWD_SUFFIX)
    assert bwd.mode == "streamed" and bwd.block_i == 1


def test_plan_votes_routing_bwd_prefers_resident_when_roomy():
    sched = plan_votes_routing_bwd(600, 4, 80, 10, batch=2, iters=3)
    assert sched.mode == "resident" and sched.n_passes == 2
    tight = plan_votes_routing_bwd(600, 4, 80, 10, batch=2, iters=3,
                                   vmem_budget=400_000)
    # fused replay: one W stream per replayed iteration + readout, then
    # seed / reverse / emit -- NOT the old 2-pass replay's 2*iters+4
    assert tight.mode == "streamed" and tight.n_passes == 3 + 4
    assert tight.vmem_bytes <= 400_000


def test_train_false_plan_unchanged():
    """Inference plans are untouched: no backward ops, train=False."""
    plan = compile_plan(CapsNetConfig(), batch=2)
    assert not plan.train
    assert [op.name for op in plan.ops] == [
        "Conv1", "PrimaryCaps", FUSED_NAME]
    with pytest.raises(KeyError):
        plan.op(FUSED_NAME + BWD_SUFFIX)
