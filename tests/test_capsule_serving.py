"""CapsuleEngine: slot-batched classification vs the direct forward oracle,
queue refill, latency/throughput reporting, pallas-backend parity, the
sharded (mesh) layout, and the asyncio host loop.

The in-process tests exercise the mesh path with ``n_shards=1`` (one
CpuDevice); multi-device parity at 2/4/8 virtual devices lives in
``tests/test_sharded_serving.py`` (subprocess with forced host devices).
"""

import asyncio

import jax
import numpy as np
import pytest

from repro.core import capsnet
from repro.core.capsnet import CapsNetConfig
from repro.core.execplan import PlanError, compile_plan
from repro.serve import AsyncCapsuleServer, CapsRequest, CapsuleEngine

KEY = jax.random.PRNGKey(0)
CFG = CapsNetConfig(image_hw=14, conv1_channels=16, conv1_kernel=5,
                    pc_kernel=3, num_primary_groups=4, primary_dim=4,
                    class_dim=8, use_decoder=False)
PARAMS = capsnet.init_params(KEY, CFG)


def _images(n):
    return np.asarray(jax.random.uniform(
        KEY, (n, CFG.image_hw, CFG.image_hw, 1)))


def test_engine_matches_direct_forward():
    imgs = _images(5)
    engine = CapsuleEngine(PARAMS, CFG, slots=2)
    for i in range(5):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    done = engine.run()
    assert sorted(r.rid for r in done) == list(range(5))
    for r in done:
        want = np.asarray(capsnet.forward(
            PARAMS, imgs[r.rid][None], CFG)["lengths"][0])
        np.testing.assert_allclose(r.lengths, want, rtol=1e-5, atol=1e-5)
        assert r.pred == int(np.argmax(want))


def test_engine_refills_slots_from_queue():
    imgs = _images(7)
    engine = CapsuleEngine(PARAMS, CFG, slots=3)
    for i in range(7):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    done = engine.run()
    assert len(done) == 7
    assert engine.ticks >= 3                      # ceil(7 / 3)
    assert all(a is None for a in engine.active)
    assert not engine.queue
    # later requests waited in the queue while slots were busy
    assert max(r.queue_ticks for r in done) >= 1


def test_engine_reports_latency_and_throughput():
    imgs = _images(4)
    engine = CapsuleEngine(PARAMS, CFG, slots=2)
    for i in range(4):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    engine.run()
    s = engine.stats()
    assert s["requests"] == 4
    assert s["elapsed_s"] > 0
    assert s["requests_per_s"] > 0
    assert s["mean_latency_ms"] > 0
    assert s["max_latency_ms"] >= s["mean_latency_ms"]
    assert 0 < s["occupancy"] <= 1.0
    for r in engine.finished:
        assert r.latency_s is not None and r.latency_s >= 0


def test_engine_shares_one_plan():
    plan = compile_plan(CFG, batch=2)
    engine = CapsuleEngine(PARAMS, CFG, slots=2, plan=plan)
    assert engine.plan is plan                    # amortized, not recompiled


def test_engine_rejects_plan_batch_below_slots():
    """A plan compiled for batch < slots would blow the validated VMEM
    footprint (or raise the opaque kernel batch error) on the first
    step(); the constructor rejects it naming both numbers."""
    plan = compile_plan(CFG, batch=2)
    with pytest.raises(PlanError, match=r"batch 2.*4 slots"):
        CapsuleEngine(PARAMS, CFG, slots=4, plan=plan)
    # batch == slots and batch > slots are both within the validated bound
    for slots in (2, 1):
        engine = CapsuleEngine(PARAMS, CFG, slots=slots, plan=plan)
        assert engine.plan is plan


def test_engine_traces_forward_once_across_occupancies():
    """Varying occupancy (full slots, partial refill, single straggler)
    must reuse ONE compiled forward: the active-slot gather runs inside
    the jit over a fixed-size padded index.  The old eager jnp.take
    compiled a fresh gather program per distinct occupancy count."""
    imgs = _images(7)
    engine = CapsuleEngine(PARAMS, CFG, slots=3)
    for i in range(7):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    done = engine.run()                   # occupancies 3, 3, 1
    assert len(done) == 7 and engine.ticks == 3
    assert engine._forward_traces == 1
    for r in done:                        # and results stay correct
        want = np.asarray(capsnet.forward(
            PARAMS, imgs[r.rid][None], CFG)["lengths"][0])
        np.testing.assert_allclose(r.lengths, want, rtol=1e-5, atol=1e-5)


def test_engine_reuses_slot_with_fresh_image():
    """The dirty-slot upload path must refresh a reused slot's device row
    -- stale device state would silently classify the PREVIOUS image."""
    imgs = _images(4)
    engine = CapsuleEngine(PARAMS, CFG, slots=1)
    for i in range(4):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    done = engine.run()                   # slot 0 reused for every request
    assert [r.rid for r in done] == list(range(4))
    preds = set()
    for r in done:
        want = np.asarray(capsnet.forward(
            PARAMS, imgs[r.rid][None], CFG)["lengths"][0])
        np.testing.assert_allclose(r.lengths, want, rtol=1e-5, atol=1e-5)
        preds.add(tuple(np.round(r.lengths, 6)))
    assert len(preds) == 4                # four distinct images, not one


def test_engine_pallas_backend_matches_jnp_engine():
    imgs = _images(3)
    results = {}
    for backend in ("jnp", "pallas"):
        engine = CapsuleEngine(PARAMS, CFG, slots=2, backend=backend)
        for i in range(3):
            engine.submit(CapsRequest(rid=i, image=imgs[i]))
        done = engine.run()
        results[backend] = {r.rid: r.lengths for r in done}
    for rid in range(3):
        np.testing.assert_allclose(results["pallas"][rid],
                                   results["jnp"][rid],
                                   rtol=1e-4, atol=1e-4)


def test_engine_rejects_mismatched_image_layout():
    """A same-size CHW image must be rejected, not silently reinterpreted
    as HWC garbage (the old reshape accepted any same-size layout)."""
    engine = CapsuleEngine(PARAMS, CFG, slots=2)
    good = _images(1)[0]                                   # [14, 14, 1] HWC
    chw = np.transpose(good, (2, 0, 1))                    # [1, 14, 14] CHW
    with pytest.raises(ValueError, match="does not match"):
        engine.submit(CapsRequest(rid=0, image=chw))
    with pytest.raises(ValueError, match="does not match"):
        engine.submit(CapsRequest(rid=1, image=good.reshape(-1)))  # flat
    with pytest.raises(ValueError, match="does not match"):
        engine.submit(CapsRequest(rid=2, image=good[..., 0]))      # [14, 14]
    assert not engine.queue                                # nothing admitted
    engine.submit(CapsRequest(rid=3, image=good))          # correct layout
    assert len(engine.queue) == 1
    done = engine.run()
    want = np.asarray(capsnet.forward(PARAMS, good[None], CFG)["lengths"][0])
    np.testing.assert_allclose(done[0].lengths, want, rtol=1e-5, atol=1e-5)


def test_engine_empty_step_is_noop():
    engine = CapsuleEngine(PARAMS, CFG, slots=2)
    assert engine.step() == 0
    assert engine.stats()["requests"] == 0


def test_engine_preserves_fifo_admission():
    imgs = _images(6)
    engine = CapsuleEngine(PARAMS, CFG, slots=1)
    for i in range(6):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    done = engine.run()
    assert [r.rid for r in done] == list(range(6))  # one slot => strict FIFO


# -- sharded layout (n_shards=1 exercises the mesh path on one device) -------

def test_sharded_engine_bit_identical_to_plain():
    """The mesh path (shard_map, per-shard index, sharded device batch)
    must not perturb a single bit: the head is per-sample."""
    imgs = _images(7)
    results = {}
    for n_shards in (None, 1):
        engine = CapsuleEngine(PARAMS, CFG, slots=2, n_shards=n_shards)
        for i in range(7):
            engine.submit(CapsRequest(rid=i, image=imgs[i]))
        engine.run()
        assert engine._forward_traces == 1
        results[n_shards] = {r.rid: (r.lengths, r.pred)
                             for r in engine.finished}
    for rid in range(7):
        np.testing.assert_array_equal(results[None][rid][0],
                                      results[1][rid][0])
        assert results[None][rid][1] == results[1][rid][1]


def test_sharded_engine_pallas_per_shard_plan():
    """ONE compile_plan produces the per-shard plan: plan.batch equals
    slots_per_shard, and the pallas engine serves through it."""
    imgs = _images(4)
    engine = CapsuleEngine(PARAMS, CFG, slots=2, backend="pallas",
                           n_shards=1)
    assert engine.plan.batch == engine.slots_per_shard == 2
    for i in range(4):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    engine.run()
    assert engine._forward_traces == 1
    for r in engine.finished:
        want = np.asarray(capsnet.forward(
            PARAMS, imgs[r.rid][None], CFG)["lengths"][0])
        np.testing.assert_allclose(r.lengths, want, rtol=1e-4, atol=1e-4)


def test_sharded_engine_validates_layout():
    with pytest.raises(ValueError, match="does not divide"):
        CapsuleEngine(PARAMS, CFG, slots=3, n_shards=2)
    with pytest.raises(ValueError, match="n_shards"):
        CapsuleEngine(PARAMS, CFG, slots=4,
                      n_shards=len(jax.devices()) + 1)


def test_sharded_engine_plan_batch_contract():
    """slots = n_shards * plan.batch: a caller plan below the PER-SHARD
    batch is rejected up front, one at (or above) it is accepted even
    though plan.batch < slots."""
    if len(jax.devices()) < 2:
        plan = compile_plan(CFG, batch=1)
        with pytest.raises(PlanError, match="per shard"):
            CapsuleEngine(PARAMS, CFG, slots=2, n_shards=1, plan=plan)
        ok = compile_plan(CFG, batch=2)
        engine = CapsuleEngine(PARAMS, CFG, slots=2, n_shards=1, plan=ok)
        assert engine.plan is ok
    else:
        plan = compile_plan(CFG, batch=2)
        with pytest.raises(PlanError, match="per shard"):
            CapsuleEngine(PARAMS, CFG, slots=8, n_shards=2, plan=plan)
        engine = CapsuleEngine(PARAMS, CFG, slots=4, n_shards=2, plan=plan)
        assert engine.plan is plan               # 4 = 2 shards * batch 2


def test_sharded_engine_stats_sum_per_shard():
    imgs = _images(5)
    engine = CapsuleEngine(PARAMS, CFG, slots=2, n_shards=1, max_queue=2)
    for i in range(5):
        engine.submit(CapsRequest(rid=i, image=imgs[i]))
    engine.run()
    s = engine.stats()
    assert s["n_shards"] == 1 and s["slots_per_shard"] == 2
    for status in ("ok", "timeout", "error", "shed"):
        assert (sum(sh[status] for sh in s["per_shard"])
                + s["queue_bucket"][status] == s[status])
    assert s["ok"] + s["timeout"] + s["error"] + s["shed"] == s["submitted"]
    assert s["queue_bucket"]["shed"] == s["shed"] > 0   # admission sheds


# -- asyncio host loop -------------------------------------------------------

def test_async_server_serves_concurrent_submissions():
    imgs = _images(9)

    async def main():
        engine = CapsuleEngine(PARAMS, CFG, slots=3)
        async with AsyncCapsuleServer(engine) as server:
            reqs = await asyncio.gather(
                *(server.submit(imgs[i]) for i in range(9)))
        return engine, reqs

    engine, reqs = asyncio.run(main())
    assert all(r.status == "ok" for r in reqs)
    assert engine._forward_traces == 1          # the loop adds no traces
    for i, r in enumerate(reqs):
        want = np.asarray(capsnet.forward(
            PARAMS, imgs[i][None], CFG)["lengths"][0])
        np.testing.assert_allclose(r.lengths, want, rtol=1e-5, atol=1e-5)


def test_async_server_recycles_slots_continuously():
    """Work submitted while earlier requests are in flight is picked up
    by later ticks of the same driver -- no batch boundaries."""
    imgs = _images(6)

    async def main():
        engine = CapsuleEngine(PARAMS, CFG, slots=2)
        async with AsyncCapsuleServer(engine) as server:
            first = asyncio.ensure_future(
                asyncio.gather(*(server.submit(imgs[i]) for i in range(3))))
            await asyncio.sleep(0)              # let the first wave land
            second = asyncio.gather(
                *(server.submit(imgs[i]) for i in range(3, 6)))
            reqs = await first + await second
        return engine, reqs

    engine, reqs = asyncio.run(main())
    assert all(r.status == "ok" for r in reqs)
    assert len(engine.finished) == 6
    assert engine._forward_traces == 1


def test_async_server_admission_control_sheds():
    """The engine's bounded-queue admission applies unchanged: a shed
    request's future resolves immediately with status 'shed'."""
    imgs = _images(8)

    async def main():
        engine = CapsuleEngine(PARAMS, CFG, slots=1, max_queue=2,
                               admission="reject")
        async with AsyncCapsuleServer(engine) as server:
            reqs = await asyncio.gather(
                *(server.submit(imgs[i]) for i in range(8)))
        return engine, reqs

    engine, reqs = asyncio.run(main())
    statuses = [r.status for r in reqs]
    assert set(statuses) <= {"ok", "shed"} and "shed" in statuses
    s = engine.stats()
    assert s["ok"] + s["shed"] == s["submitted"] == 8


def test_async_server_over_sharded_engine():
    imgs = _images(6)

    async def main():
        engine = CapsuleEngine(PARAMS, CFG, slots=2, n_shards=1)
        async with AsyncCapsuleServer(engine) as server:
            reqs = await asyncio.gather(
                *(server.submit(imgs[i]) for i in range(6)))
        return engine, reqs

    engine, reqs = asyncio.run(main())
    assert all(r.status == "ok" for r in reqs)
    assert engine._forward_traces == 1
    s = engine.stats()
    assert sum(sh["ok"] for sh in s["per_shard"]) == 6
