"""CapsTrainLoop: margin+reconstruction training through the Pallas
backend with the repo's checkpoint / NaN-guard / heartbeat machinery."""

import json

import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint as ckpt
from repro.train.capsnet_loop import (SMOKE, CapsLoopConfig, CapsTrainLoop,
                                      main)


def _loop(tmp_path, total=8, backend="jnp", batch=8, **kw):
    return CapsTrainLoop(SMOKE, CapsLoopConfig(
        total_steps=total, batch=batch, ckpt_every=4,
        ckpt_dir=str(tmp_path / "ck"), log_every=1000, backend=backend,
        heartbeat_path=str(tmp_path / "hb.json"), **kw))


def test_loop_runs_checkpoints_and_heartbeat(tmp_path):
    loop = _loop(tmp_path, total=8)
    hist = loop.run()
    assert len(hist) == 8
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert 8 in ckpt.committed_steps(tmp_path / "ck")
    hb = json.loads((tmp_path / "hb.json").read_text())
    assert hb["step"] == 8


def test_loop_resume_after_kill(tmp_path):
    _loop(tmp_path, total=4).run()
    # "restart the job" with a longer horizon: resumes from step 4
    loop2 = _loop(tmp_path, total=8)
    hist = loop2.run(resume=True)
    assert hist[0]["step"] == 5
    assert loop2.step == 8


def test_nan_guard_rolls_back_and_skips_batch(tmp_path):
    loop = _loop(tmp_path, total=6)
    inner = loop._step_fn
    calls = {"n": 0}

    def poisoned(params, images, labels):
        calls["n"] += 1
        params, metrics = inner(params, images, labels)
        if calls["n"] == 3:
            metrics = dict(metrics)
            metrics["loss"] = jnp.float32(np.nan)
        return params, metrics

    loop._step_fn = poisoned
    hist = loop.run()
    assert loop.nan_skips == 1
    assert loop.step == 6                    # the poisoned batch is skipped,
    assert 3 not in [h["step"] for h in hist]  # not retried
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_nan_guard_ignores_stale_checkpoints_from_other_runs(tmp_path):
    """A shared ckpt_dir holding LATER steps from an abandoned run must
    not be resurrected by the NaN rollback: the guard restores THIS
    run's last committed step, not the directory's globally-latest."""
    # stale "other run" checkpoint at step 40 with an incompatible tree:
    # restoring it would raise a shape-mismatch ValueError
    ckpt.save({"params": {"bogus": np.zeros((3, 3))}},
              tmp_path / "ck", 40)
    loop = _loop(tmp_path, total=6)
    inner = loop._step_fn
    calls = {"n": 0}

    def poisoned(params, images, labels):
        calls["n"] += 1
        params, metrics = inner(params, images, labels)
        if calls["n"] == 3:
            metrics = dict(metrics)
            metrics["loss"] = jnp.float32(np.nan)
        return params, metrics

    loop._step_fn = poisoned
    hist = loop.run(resume=False)            # the --no-resume scenario
    assert loop.nan_skips == 1
    assert loop.step == 6
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_pallas_backend_20_steps_loss_decreases(tmp_path):
    """The CI training-smoke assertion as a test: 20 SGD steps through
    the differentiable Pallas path, loss falls, no NaN rollback fires."""
    loop = _loop(tmp_path, total=20, backend="pallas", batch=16)
    assert loop.plan is not None and loop.plan.train
    hist = loop.run()
    assert len(hist) == 20
    assert loop.nan_skips == 0
    losses = [h["loss"] for h in hist]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_cli_assert_improves(tmp_path):
    rc = main(["--steps", "12", "--batch", "16", "--backend", "jnp",
               "--ckpt-dir", str(tmp_path / "ck"), "--assert-improves",
               "--no-resume"])
    assert rc == 0
