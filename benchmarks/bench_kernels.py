"""Kernel microbenches: wall time of the Pallas kernels (interpret mode on
CPU -- correctness-path timing, NOT TPU perf) + allclose deltas vs the
pure-jnp oracles.  TPU perf is assessed structurally via the planner and
the roofline (see EXPERIMENTS.md)."""

import jax
import numpy as np

from benchmarks.common import row, timed
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def main() -> list[str]:
    rows = []
    # CapsuleNet-MNIST-shaped inputs (the paper's workload)
    u = jax.random.normal(KEY, (1, 1152, 8))
    w = jax.random.normal(KEY, (1152, 160, 8))
    (votes, us) = timed(lambda: np.asarray(ops.caps_votes(u, w)), repeats=2)
    err = np.abs(votes - np.asarray(ref.caps_votes(u, w))).max()
    rows.append(row("kernels.caps_votes_mnist", us, f"maxerr={err:.2e}"))

    uh = 0.1 * jax.random.normal(KEY, (1, 1152, 160))
    (v, us) = timed(lambda: np.asarray(ops.routing(uh, iters=3)), repeats=2)
    err = np.abs(v - np.asarray(
        ref.routing(uh.reshape(1, 1152, 10, 16), 3).reshape(1, 160))).max()
    rows.append(row("kernels.routing_fused_mnist", us, f"maxerr={err:.2e}"))

    x = jax.random.normal(KEY, (4096, 256))
    (s, us) = timed(lambda: np.asarray(ops.squash(x)), repeats=2)
    err = np.abs(s - np.asarray(ref.squash(x))).max()
    rows.append(row("kernels.squash_4kx256", us, f"maxerr={err:.2e}"))

    wgt = 0.1 * jax.random.normal(KEY, (1024,))
    xr = jax.random.normal(KEY, (2048, 1024))
    (y, us) = timed(lambda: np.asarray(ops.rmsnorm(xr, wgt)), repeats=2)
    err = np.abs(y - np.asarray(ref.rmsnorm(xr, wgt))).max()
    rows.append(row("kernels.rmsnorm_2kx1k", us, f"maxerr={err:.2e}"))

    q = jax.random.normal(KEY, (1, 4, 256, 64))
    k = jax.random.normal(KEY, (1, 4, 256, 64))
    v2 = jax.random.normal(KEY, (1, 4, 256, 64))
    (o, us) = timed(lambda: np.asarray(
        ops.flash_attention(q, k, v2, causal=True)), repeats=2)
    err = np.abs(o - np.asarray(ref.attention(q, k, v2, causal=True))).max()
    rows.append(row("kernels.flash_attn_256", us, f"maxerr={err:.2e}"))
    return rows


if __name__ == "__main__":
    main()
