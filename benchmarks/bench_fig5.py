"""Paper Fig. 5: energy breakdown of (a) the all-on-chip CapsAcc [11] vs
(b) the on-chip + off-chip hierarchy."""

from benchmarks.common import row, timed
from repro.core import analysis, dse


def main() -> list[str]:
    profiles = analysis.capsnet_profiles()
    orgs = dse.design_organizations(profiles)

    (a, us_a) = timed(dse.all_onchip_system, profiles)
    ev_smp = dse.evaluate(orgs["SMP"], profiles)
    (b, us_b) = timed(dse.hierarchy_system, profiles, ev_smp)

    print(f"\n# Fig5a all-on-chip[11]: accel {a.accelerator_mj:.3f} buf "
          f"{a.buffers_mj:.3f} onchip {a.onchip_mj:.3f} mJ "
          f"(mem {a.memory_fraction:.1%})")
    print(f"# Fig5b hierarchy/SMP : accel {b.accelerator_mj:.3f} buf "
          f"{b.buffers_mj:.3f} onchip {b.onchip_mj:.3f} offchip "
          f"{b.offchip_mj:.3f} mJ (mem {b.memory_fraction:.1%})")
    saving = 1 - b.total_mj / a.total_mj
    rows = [
        row("fig5.all_onchip_total_mj", us_a, f"{a.total_mj:.4f}"),
        row("fig5.hierarchy_total_mj", us_b, f"{b.total_mj:.4f}"),
        row("fig5.hierarchy_saving", us_b,
            f"{saving:.3f} (paper: 0.66)"),
        row("fig5.memory_fraction", us_b,
            f"{b.memory_fraction:.3f} (paper: 0.96)"),
    ]
    return rows


if __name__ == "__main__":
    main()
