"""Paper Fig. 10d: on-chip memory energy per CapsuleNet operation, for
every CapStore organization (shows PrimaryCaps dominating and power gating
helping everywhere BUT the high-utilization PC phase)."""

from benchmarks.common import row, timed
from repro.core import analysis, dse


def main() -> list[str]:
    profiles = analysis.capsnet_profiles()
    orgs = dse.design_organizations(profiles)
    rows = []
    print("\n# Fig10d: org x op energy (mJ)")
    hdr = "#   org     " + "".join(f"{p.name:>14s}" for p in profiles)
    print(hdr)
    for name, org in orgs.items():
        (ev, us) = timed(dse.evaluate, org, profiles, repeats=1)
        line = f"#   {name:7s} " + "".join(
            f"{ev.per_op_mj[p.name]:14.4f}" for p in profiles)
        print(line)
        pc_share = ev.per_op_mj["PrimaryCaps"] / ev.total_mj
        rows.append(row(f"fig10d.{name}.primarycaps_share", us,
                        f"{pc_share:.3f}"))
    return rows


if __name__ == "__main__":
    main()
