"""Shared benchmark helpers: timing + CSV row emission.

Every ``row()`` is also recorded in ``RECORDS`` so ``benchmarks.run`` can
emit a machine-readable artifact (``--json``) for CI perf tracking.
"""

from __future__ import annotations

import time

# (name, us_per_call, derived) for every row emitted this process.
RECORDS: list[dict] = []


def timed(fn, *args, repeats: int = 20, **kw):
    """Returns (result, microseconds_per_call).

    Reports the MINIMUM over ``repeats`` individually-timed calls: OS/
    container contention only ever adds time, so the min is the stable
    statistic -- the mean of a few calls swings 2-3x between processes on
    shared runners, which would false-flag the ``--baseline`` perf gate.
    """
    fn(*args, **kw)                      # warmup / trace
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    us = best * 1e6
    return out, us


def row(name: str, us: float, derived: str, gate: bool = True) -> str:
    """Emit one CSV row.  ``gate=False`` marks wall-clock observations
    (e.g. engine throughput) that the ``--baseline`` perf gate must not
    fail on -- they time a whole loop, not a repeatable call."""
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    RECORDS.append(dict(name=name, us_per_call=round(us, 1), derived=derived,
                        gate=gate))
    return line
