"""Shared benchmark helpers: timing + CSV row emission.

Every ``row()`` is also recorded in ``RECORDS`` so ``benchmarks.run`` can
emit a machine-readable artifact (``--json``) for CI perf tracking.
"""

from __future__ import annotations

import time

# (name, us_per_call, derived) for every row emitted this process.
RECORDS: list[dict] = []


def timed(fn, *args, repeats: int = 3, **kw):
    """Returns (result, microseconds_per_call)."""
    fn(*args, **kw)                      # warmup / trace
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def row(name: str, us: float, derived: str) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    RECORDS.append(dict(name=name, us_per_call=round(us, 1), derived=derived))
    return line
