"""Headline-claim validation table: our model vs the paper's published
numbers (EXPERIMENTS.md Sec. Paper-validation)."""

from benchmarks.common import row
from repro.core import analysis, dse


def main() -> list[str]:
    profiles = analysis.capsnet_profiles()
    orgs = dse.design_organizations(profiles)
    evs = {n: dse.evaluate(o, profiles) for n, o in orgs.items()}
    a = dse.all_onchip_system(profiles)
    b = dse.hierarchy_system(profiles, evs["SMP"])
    best = dse.best_design(profiles)
    c = dse.hierarchy_system(profiles, best.evaluation)

    claims = [
        ("memory_energy_fraction", b.memory_fraction, 0.96),
        ("hierarchy_saving_vs_all_onchip", 1 - b.total_mj / a.total_mj,
         0.66),
        ("pgsep_onchip_vs_smp", 1 - evs["PG-SEP"].total_mj
         / evs["SMP"].total_mj, 0.86),
        ("total_vs_all_onchip", 1 - c.total_mj / a.total_mj, 0.78),
        ("total_vs_hierarchy_b", 1 - c.total_mj / b.total_mj, 0.46),
        ("onchip_area_vs_smp", 1 - best.evaluation.area_mm2
         / evs["SMP"].area_mm2, 0.47),
        ("total_area_vs_all_onchip", 1 - c.total_area_mm2
         / a.total_area_mm2, 0.25),
        ("accel_energy_share", c.accelerator_mj / c.total_mj, 0.045),
        ("dse_selects_pg_sep", 1.0 if best.org_name == "PG-SEP" else 0.0,
         1.0),
        ("sep_larger_than_smp", orgs["SEP"].total_bytes
         / orgs["SMP"].total_bytes, 2.26),
    ]
    rows = []
    print("\n# paper-validation: claim, ours, paper, |delta|")
    for name, ours, paper in claims:
        print(f"#   {name:32s} {ours:7.3f} {paper:7.3f} "
              f"{abs(ours - paper):6.3f}")
        rows.append(row(f"validation.{name}", 0.0,
                        f"ours={ours:.3f};paper={paper:.3f}"))
    return rows


if __name__ == "__main__":
    main()
