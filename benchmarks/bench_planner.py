"""TPU adaptation: CapStore planner DSE over Pallas block shapes for the
paper's workloads AND representative LM matmuls (DESIGN.md Sec. 2)."""

from benchmarks.common import row, timed
from repro.core.planner import (CAPSNET_WORKLOADS, MatmulWorkload,
                                arithmetic_intensity, plan_matmul)

LM_WORKLOADS = [
    ("gemma2-qkv(4k)", MatmulWorkload(m=4096, k=3584, n=4096 + 2 * 2048)),
    ("gemma2-mlp(4k)", MatmulWorkload(m=4096, k=3584, n=14336)),
    ("granite-mlp(4k)", MatmulWorkload(m=4096, k=2048, n=8192)),
    ("vocab-head(4k)", MatmulWorkload(m=4096, k=3584, n=256128)),
]


def main() -> list[str]:
    rows = []
    print("\n# planner: workload, block(m,k,n), vmem_KiB, gated%, "
          "hbm_MiB, intensity(flops/byte)")
    for name, w in CAPSNET_WORKLOADS + LM_WORKLOADS:
        (p, us) = timed(plan_matmul, w, repeats=1)
        print(f"#   {name:18s} {p.block_m:5d}x{p.block_k:5d}x{p.block_n:5d}"
              f" {p.vmem_total/1024:9.1f} {p.gated_fraction:7.1%}"
              f" {p.hbm_bytes/2**20:9.1f} "
              f"{arithmetic_intensity(p, w):8.1f}")
        rows.append(row(f"planner.{name}.intensity", us,
                        f"{arithmetic_intensity(p, w):.1f}"))
    return rows


if __name__ == "__main__":
    main()
