"""Plan-driven CapsNet execution: jnp vs Pallas forward + batched serving.

Times the reference jnp forward against the ExecutionPlan-driven Pallas
forward (interpret mode on CPU -- the comparison is about the shared plan,
not raw speed off-TPU), prints the compiled plan, and drives the slot-based
``CapsuleEngine`` over a request stream to report requests/s.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import row, timed
from repro.core import capsnet
from repro.core.capsnet import CapsNetConfig
from repro.core.execplan import compile_plan
from repro.serve.capsule import CapsRequest, CapsuleEngine

CFG = CapsNetConfig(image_hw=14, conv1_channels=16, conv1_kernel=5,
                    pc_kernel=3, num_primary_groups=4, primary_dim=4,
                    class_dim=8, use_decoder=False)
BATCH = 4
REQUESTS = 16


def main() -> None:
    key = jax.random.PRNGKey(0)
    params = capsnet.init_params(key, CFG)
    imgs = jax.random.uniform(key, (BATCH, CFG.image_hw, CFG.image_hw, 1))
    plan = compile_plan(CFG, batch=BATCH)

    for r in plan.summary():
        row(f"plan/{r['name']}", 0.0,
            f"kernel={r['kernel']} block={r['block']} "
            f"vmem_kib={r['vmem_kib']:.1f}")

    f_jnp = jax.jit(lambda p, x: capsnet.forward(p, x, CFG)["lengths"])
    f_pal = jax.jit(lambda p, x: capsnet.forward(p, x, CFG, backend="pallas",
                                                 plan=plan)["lengths"])
    want, us = timed(lambda: np.asarray(f_jnp(params, imgs)))
    row("capsnet-forward-jnp", us, f"batch={BATCH}")
    got, us = timed(lambda: np.asarray(f_pal(params, imgs)))
    row("capsnet-forward-pallas", us,
        f"maxdiff={np.abs(got - want).max():.2e}")

    engine = CapsuleEngine(params, CFG, slots=BATCH, plan=plan)
    pool = np.asarray(imgs)
    for i in range(REQUESTS):
        engine.submit(CapsRequest(rid=i, image=pool[i % BATCH]))
    engine.run()
    s = engine.stats()
    row("capsule-serving", 1e6 * s["elapsed_s"] / max(s["requests"], 1),
        f"req/s={s['requests_per_s']:.1f} occupancy={s['occupancy']:.2f} "
        f"mean_lat_ms={s['mean_latency_ms']:.2f}")


if __name__ == "__main__":
    main()
