"""Plan-driven CapsNet execution: jnp vs Pallas forward + batched serving.

Times the reference jnp forward against the ExecutionPlan-driven Pallas
forward (interpret mode on CPU -- the comparison is about the shared plan,
not raw speed off-TPU), the PIPELINED plan (Conv1 -> one
``primary_routing`` megakernel) against the per-op plan with the modeled
inter-layer HBM bytes the pipelining eliminates, times the im2col conv
kernels and the fused votes+routing megakernel against the split
``caps_votes`` -> ``routing`` pair (with the modeled HBM bytes each moves
-- the u_hat round-trip the fusion kills), prints the compiled plan,
times the 3-block CIFAR-10 ResCaps stack (per-layer fused OpPlans,
modeled per-layer HBM bytes, reversible-backward grad vs jnp), and
drives the slot-based ``CapsuleEngine`` over a request stream reporting
its full ``stats()`` (the CI perf-trajectory rows in
``BENCH_capsule.json``).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import row, timed
from repro.configs import registry
from repro.core import capsnet, execplan
from repro.core.capsnet import CapsNetConfig
from repro.core.execplan import (BWD_SUFFIX, FUSED_NAME, PIPE_NAME,
                                 compile_plan, plan_votes_routing,
                                 primary_intermediate_hbm_bytes,
                                 spilled_votes_routing_bwd_hbm_bytes,
                                 split_votes_routing_hbm_bytes,
                                 votes_routing_bwd_hbm_bytes,
                                 votes_routing_hbm_bytes)
from repro.kernels import ops
from repro.serve.capsule import CapsRequest, CapsuleEngine

CFG = CapsNetConfig(image_hw=14, conv1_channels=16, conv1_kernel=5,
                    pc_kernel=3, num_primary_groups=4, primary_dim=4,
                    class_dim=8, use_decoder=False)
BATCH = 4
DEEP_BATCH = 2                 # the 3-block CIFAR-10 smoke stack rows
REQUESTS = 16


def main() -> None:
    key = jax.random.PRNGKey(0)
    params = capsnet.init_params(key, CFG)
    imgs = jax.random.uniform(key, (BATCH, CFG.image_hw, CFG.image_hw, 1))
    plan = compile_plan(CFG, batch=BATCH)

    for r in plan.summary():
        row(f"plan/{r['name']}", 0.0,
            f"kernel={r['kernel']} block={r['block']} mode={r['mode']} "
            f"vmem_kib={r['vmem_kib']:.1f} "
            f"uhat_hbm_bytes={r['uhat_hbm_bytes']}")

    f_jnp = jax.jit(lambda p, x: capsnet.forward(p, x, CFG)["lengths"])
    f_pal = jax.jit(lambda p, x: capsnet.forward(p, x, CFG, backend="pallas",
                                                 plan=plan)["lengths"])
    want, us = timed(lambda: np.asarray(f_jnp(params, imgs)))
    row("capsnet-forward-jnp", us, f"batch={BATCH}")
    got, us = timed(lambda: np.asarray(f_pal(params, imgs)))
    row("capsnet-forward-pallas", us,
        f"maxdiff={np.abs(got - want).max():.2e}")

    # PIPELINED plan: Conv1 -> ONE primary_routing megakernel (PrimaryCaps
    # conv + squash + votes + routing, the inter-layer u resident in VMEM)
    # vs the per-op plan above -- same forward, one fewer HBM round-trip.
    pipe_plan = compile_plan(CFG, batch=BATCH, pipeline=True)
    pipe_op = pipe_plan.op(PIPE_NAME)
    f_pipe = jax.jit(lambda p, x: capsnet.forward(
        p, x, CFG, backend="pallas", plan=pipe_plan)["lengths"])
    piped, us = timed(lambda: np.asarray(f_pipe(params, imgs)))
    row("capsnet-forward-pallas-pipelined", us,
        f"mode={pipe_op.mode} block_i={pipe_op.block_i} "
        f"block_k={pipe_op.block_k} maxdiff={np.abs(piped - got).max():.2e}")
    inter = primary_intermediate_hbm_bytes(BATCH, CFG.num_primary,
                                           CFG.primary_dim)
    row("primary-routing/fwd-hbm-bytes-pipelined", 0.0,
        f"{pipe_plan.forward_hbm_bytes():.0f}")
    row("primary-routing/fwd-hbm-bytes-perop", 0.0,
        f"{plan.forward_hbm_bytes():.0f}")
    row("primary-routing/hbm-bytes-intermediate-saved", 0.0,
        f"{inter:.0f} (u round-trip killed; pipelined "
        f"intermediate_hbm_bytes={pipe_op.intermediate_hbm_bytes:.0f})")

    # Individual plan-driven conv kernels (the PR-2 im2col path).
    c1 = plan.op("Conv1")
    x1, us = timed(lambda: np.asarray(ops.conv2d(
        imgs, params["conv1_w"], params["conv1_b"], stride=1, plan_op=c1,
        epilogue="relu")))
    row("conv1-im2col", us,
        f"block={c1.block.block_m}x{c1.block.block_k}x{c1.block.block_n}")
    pc = plan.op("PrimaryCaps")
    _, us = timed(lambda: np.asarray(ops.conv2d(
        x1, params["pc_w"], params["pc_b"], stride=CFG.pc_stride, plan_op=pc,
        squash_dim=CFG.primary_dim)))
    row("primarycaps-im2col", us,
        f"block={pc.block.block_m}x{pc.block.block_k}x{pc.block.block_n} "
        f"fused_squash={pc.fuses_squash}")

    # Fused votes+routing megakernel vs the split caps_votes -> routing
    # pair, plus the modeled HBM bytes each schedule moves per forward.
    fused_op = plan.op(FUSED_NAME)
    jd = CFG.num_classes * CFG.class_dim
    u = capsnet.squash(jax.random.normal(
        key, (BATCH, CFG.num_primary, CFG.primary_dim)))
    w = params["cc_w"].reshape(CFG.num_primary, jd, CFG.primary_dim)
    fused, us = timed(lambda: np.asarray(ops.votes_routing(
        u, w, plan=plan)))
    row("votes-routing-fused", us,
        f"mode={fused_op.mode} block_i={fused_op.block_i}")
    split, us = timed(lambda: np.asarray(ops.routing(
        ops.caps_votes(u, w, plan=plan), plan=plan)))
    row("votes-routing-split", us,
        f"maxdiff={np.abs(fused - split).max():.2e}")
    split_bytes, uhat_bytes = split_votes_routing_hbm_bytes(
        BATCH, CFG.num_primary, CFG.primary_dim, jd)
    row("votes-routing/hbm-bytes-fused", 0.0, f"{fused_op.hbm_bytes:.0f}")
    row("votes-routing/hbm-bytes-split", 0.0, f"{split_bytes:.0f}")
    row("votes-routing/hbm-bytes-uhat-saved", 0.0,
        f"{uhat_bytes:.0f} (u_hat round-trip killed; fused uhat_hbm_bytes="
        f"{fused_op.uhat_hbm_bytes:.0f})")

    # STREAMED schedule (forced by a budget under the resident floor):
    # the fused s+b pass streams W iters+1 times per forward where the
    # 2-pass oracle streamed it 2*iters+1 times -- both timed, plus the
    # modeled W traffic each moves and the fused backward's iters+4.
    iters = CFG.routing_iters
    floor = execplan._fused_resident_vmem(
        BATCH, CFG.num_primary, 1, CFG.primary_dim, jd, CFG.num_classes)
    tight = plan_votes_routing(CFG.num_primary, CFG.primary_dim, jd,
                               CFG.num_classes, batch=BATCH, iters=iters,
                               vmem_budget=floor - 1)
    stre, us = timed(lambda: np.asarray(ops.votes_routing(
        u, w, iters=iters, mode=tight.mode, block_i=tight.block_i,
        bwd_mode=tight.mode, bwd_block_i=tight.block_i)))
    row("votes-routing-streamed-fused", us,
        f"mode={tight.mode} block_i={tight.block_i} w_passes={tight.n_passes}")
    oracle, us = timed(lambda: np.asarray(ops.votes_routing(
        u, w, iters=iters, mode="streamed-2pass", block_i=tight.block_i,
        bwd_mode="streamed-2pass", bwd_block_i=tight.block_i)))
    row("votes-routing-streamed-2pass", us,
        f"w_passes={2 * iters + 1} maxdiff={np.abs(stre - oracle).max():.2e}")
    stre_bytes = votes_routing_hbm_bytes(BATCH, CFG.num_primary,
                                         CFG.primary_dim, jd, tight.n_passes)
    oracle_bytes = votes_routing_hbm_bytes(BATCH, CFG.num_primary,
                                           CFG.primary_dim, jd, 2 * iters + 1)
    row("votes-routing/hbm-bytes-streamed", 0.0,
        f"{stre_bytes:.0f} (W x {tight.n_passes} = iters+1 passes)")
    row("votes-routing/hbm-bytes-streamed-2pass", 0.0,
        f"{oracle_bytes:.0f} (W x {2 * iters + 1} passes; fused saves "
        f"{oracle_bytes - stre_bytes:.0f})")
    row("votes-routing-bwd/hbm-bytes-streamed", 0.0,
        f"{votes_routing_bwd_hbm_bytes(BATCH, CFG.num_primary, CFG.primary_dim, jd, mode='streamed', iters=iters):.0f} "
        f"(W x {iters + 4} = iters+4 passes)")

    # Backward: the custom-VJP training step through both backends, and
    # the fused backward's modeled HBM bytes vs a recompute-from-HBM
    # backward (u_hat spilled by the forward, d u_hat round-tripping the
    # same way -- the traffic the fused backward never moves).
    tplan = compile_plan(CFG, batch=BATCH, train=True)
    bwd_op = tplan.op(FUSED_NAME + BWD_SUFFIX)
    labels = jax.random.randint(key, (BATCH,), 0, CFG.num_classes)
    g_jnp = jax.jit(jax.grad(
        lambda p, x, y: capsnet.total_loss(p, x, y, CFG)[0]))
    g_pal = jax.jit(jax.grad(
        lambda p, x, y: capsnet.total_loss(p, x, y, CFG, backend="pallas",
                                           plan=tplan)[0]))
    _, us = timed(lambda: np.asarray(g_jnp(params, imgs, labels)["cc_w"]))
    row("capsnet-grad-jnp", us, f"batch={BATCH}")
    _, us = timed(lambda: np.asarray(g_pal(params, imgs, labels)["cc_w"]))
    row("capsnet-grad-pallas", us,
        f"bwd_mode={bwd_op.mode} bwd_block_i={bwd_op.block_i}")
    spilled_bytes, uhat_bwd = spilled_votes_routing_bwd_hbm_bytes(
        BATCH, CFG.num_primary, CFG.primary_dim, jd)
    row("votes-routing-bwd/hbm-bytes-fused", 0.0, f"{bwd_op.hbm_bytes:.0f}")
    row("votes-routing-bwd/hbm-bytes-spilled", 0.0, f"{spilled_bytes:.0f}")
    row("votes-routing-bwd/hbm-bytes-uhat-saved", 0.0,
        f"{uhat_bwd:.0f} (u_hat + d_u_hat round-trips killed; fused bwd "
        f"uhat_hbm_bytes={bwd_op.uhat_hbm_bytes:.0f})")

    # DEEP STACK: the 3-block CIFAR-10 ResCaps graph (smoke widths -- the
    # comparison is the per-layer plan + reversible backward, not raw
    # speed off-TPU).  One fused votes_routing OpPlan per routing-layer
    # instance, per-layer modeled HBM bytes, and the flat-in-depth
    # activation residency of the reversible backward.
    deep_cfg = dataclasses.replace(registry.get_smoke_config("capsnet-cifar10"),
                                   use_decoder=False)
    dkey = jax.random.PRNGKey(1)
    dparams = capsnet.init_params(dkey, deep_cfg)
    dimgs = jax.random.uniform(
        dkey, (DEEP_BATCH, deep_cfg.image_hw, deep_cfg.image_hw,
               deep_cfg.in_channels))
    dplan = compile_plan(deep_cfg, batch=DEEP_BATCH, train=True)
    stack = deep_cfg.routing_stack()
    for op in dplan.ops:
        if op.name.startswith(FUSED_NAME) and not op.name.endswith(BWD_SUFFIX):
            row(f"deep-stack/hbm-bytes/{op.name}", 0.0,
                f"{op.hbm_bytes:.0f} (mode={op.mode} block_i={op.block_i})")
    row("deep-stack/activation-bytes-reversible", 0.0,
        f"{execplan.activation_residency_bytes(deep_cfg, batch=DEEP_BATCH):.0f}"
        f" ({len(stack)} routing layers, 3 ResCaps blocks)")
    row("deep-stack/activation-bytes-saved", 0.0,
        f"{execplan.activation_residency_bytes(deep_cfg, batch=DEEP_BATCH, reversible=False):.0f}")
    d_jnp = jax.jit(lambda p, x: capsnet.forward(p, x, deep_cfg)["lengths"])
    d_pal = jax.jit(lambda p, x: capsnet.forward(
        p, x, deep_cfg, backend="pallas", plan=dplan)["lengths"])
    dwant, us = timed(lambda: np.asarray(d_jnp(dparams, dimgs)), repeats=5)
    row("deep-stack-forward-jnp", us,
        f"batch={DEEP_BATCH} layers={len(stack)}")
    dgot, us = timed(lambda: np.asarray(d_pal(dparams, dimgs)), repeats=5)
    row("deep-stack-forward-pallas", us,
        f"maxdiff={np.abs(dgot - dwant).max():.2e}")
    dlabels = jax.random.randint(dkey, (DEEP_BATCH,), 0, deep_cfg.num_classes)
    dg_jnp = jax.jit(jax.grad(
        lambda p, x, y: capsnet.total_loss(p, x, y, deep_cfg)[0]))
    dg_pal = jax.jit(jax.grad(
        lambda p, x, y: capsnet.total_loss(
            p, x, y, deep_cfg, backend="pallas", plan=dplan)[0]))
    _, us = timed(lambda: np.asarray(dg_jnp(dparams, dimgs, dlabels)["cc_w"]),
                  repeats=5)
    row("deep-stack-grad-jnp", us, f"batch={DEEP_BATCH}")
    _, us = timed(lambda: np.asarray(dg_pal(dparams, dimgs, dlabels)["cc_w"]),
                  repeats=5)
    row("deep-stack-grad-pallas", us,
        "reversible bwd: block inputs recomputed, not saved")

    engine = CapsuleEngine(params, CFG, slots=BATCH, plan=plan)
    pool = np.asarray(imgs)
    for i in range(REQUESTS):
        engine.submit(CapsRequest(rid=i, image=pool[i % BATCH]))
    engine.run()
    s = engine.stats()
    row("capsule-serving", 1e6 * s["elapsed_s"] / max(s["requests"], 1),
        f"req/s={s['requests_per_s']:.1f} occupancy={s['occupancy']:.2f} "
        f"mean_lat_ms={s['mean_latency_ms']:.2f}", gate=False)
    for key in ("requests", "ticks", "requests_per_s", "mean_latency_ms",
                "max_latency_ms", "occupancy"):
        row(f"capsule-serving/{key}", 0.0, f"{s[key]}")

    # Degraded-mode throughput next to the healthy row: a mid-run
    # vmem_shrink makes the engine swap in the degrade_plan schedule
    # (shrunk tiles / streamed routing), so the delta IS the price of
    # serving through a gated-down VMEM budget.  Trajectory row, no gate.
    deg = CapsuleEngine(params, CFG, slots=BATCH, backend="pallas")
    for i in range(REQUESTS):
        deg.submit(CapsRequest(rid=i, image=pool[i % BATCH]))
    from repro.core import faults
    with faults.inject(faults.FaultSpec(site=faults.SITE_ENGINE_TICK,
                                        kind="vmem_shrink", at=1, times=1,
                                        factor=0.012)):
        deg.run()
    d = deg.stats()
    row("capsule-serving-degraded",
        1e6 * d["elapsed_s"] / max(d["requests"], 1),
        f"req/s={d['requests_per_s']:.1f} replans={d['replans']} "
        f"degraded={d['degraded']} vmem_budget={d['vmem_budget']} "
        f"ok={d['ok']}/{d['submitted']}", gate=False)
    row("capsule-serving-degraded/requests_per_s", 0.0,
        f"{d['requests_per_s']}")

    # Req/s scaling vs device count: the slot batch row-sharded over a
    # CPU mesh (XLA_FLAGS=--xla_force_host_platform_device_count=8 in
    # the sharded-serving CI job; on a 1-device run only x1 times and
    # the rest are recorded as skipped).  Wall-clock trajectory rows, no
    # gate -- virtual CPU devices contend for the same cores, so the
    # interesting signal is the trend, not the absolute ratio.
    sps = 2
    for n in (1, 2, 4, 8):
        name = f"capsule-serving-sharded/x{n}"
        if n > jax.device_count():
            row(name, 0.0,
                f"skipped: {jax.device_count()} visible device(s)",
                gate=False)
            continue
        sh = CapsuleEngine(params, CFG, slots=n * sps, n_shards=n)
        for i in range(4 * n * sps):
            sh.submit(CapsRequest(rid=i, image=pool[i % BATCH]))
        sh.run()
        st = sh.stats()
        row(name, 1e6 * st["elapsed_s"] / max(st["requests"], 1),
            f"req/s={st['requests_per_s']:.1f} shards={n} "
            f"slots={n * sps} traces={sh._forward_traces} "
            f"ok={st['ok']}/{st['submitted']}", gate=False)
        row(f"{name}/requests_per_s", 0.0, f"{st['requests_per_s']}")


if __name__ == "__main__":
    main()
