"""Paper Table 1/2 + Fig. 10a-c: the six CapStore organizations
(SMP/SEP/HY x +-power-gating): sizes, area, dynamic/static/wakeup energy,
and the full sector-count DSE."""

from benchmarks.common import row, timed
from repro.core import analysis, dse


def main() -> list[str]:
    profiles = analysis.capsnet_profiles()
    orgs = dse.design_organizations(profiles)
    rows = []
    print("\n# Table2: org, bytes, area_mm2, dyn_mJ, stat_mJ, wake_mJ, "
          "total_mJ")
    for name in ("SMP", "PG-SMP", "SEP", "PG-SEP", "HY", "PG-HY"):
        (ev, us) = timed(dse.evaluate, orgs[name], profiles, repeats=1)
        print(f"#   {name:7s} {ev.org.total_bytes:8.0f} {ev.area_mm2:8.3f} "
              f"{ev.dynamic_mj:8.4f} {ev.static_mj:8.4f} "
              f"{ev.wakeup_mj:10.6f} {ev.total_mj:8.4f}")
        rows.append(row(f"table2.{name}.total_mj", us, f"{ev.total_mj:.4f}"))
        rows.append(row(f"table2.{name}.area_mm2", us, f"{ev.area_mm2:.3f}"))

    (results, us) = timed(dse.explore, profiles, repeats=1)
    best = results[0]
    print("# DSE (org x sectors), best 5:")
    for r in results[:5]:
        print(f"#   {r.org_name:7s} S={r.sectors:4d} {r.total_mj:8.4f} mJ "
              f"{r.area_mm2:8.3f} mm2")
    rows.append(row("table2.dse_best", us,
                    f"{best.org_name}/S={best.sectors} (paper: PG-SEP)"))
    evs = {n: dse.evaluate(o, profiles) for n, o in orgs.items()}
    red = 1 - evs["PG-SEP"].total_mj / evs["SMP"].total_mj
    rows.append(row("table2.pgsep_vs_smp_reduction", us,
                    f"{red:.3f} (paper: 0.86)"))
    return rows


if __name__ == "__main__":
    main()
