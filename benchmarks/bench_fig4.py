"""Paper Fig. 4: per-operation resource requirements of CapsuleNet
inference on the CapsAcc 16x16 array -- (a) total on-chip memory,
(b) cycles, (c) per-component memory, (d/e) reads+writes per component."""

from benchmarks.common import row, timed
from repro.core import analysis


def main() -> list[str]:
    profiles, us = timed(analysis.capsnet_profiles)
    rows = []
    print("\n# Fig4a/b: op, total_mem_B, cycles (x repeats)")
    for p in profiles:
        print(f"#   {p.name:14s} {p.total_mem:9.0f} B  "
              f"{p.total_cycles:10.0f} cyc (x{p.repeats})")
    print("# Fig4c: op, data_B, weight_B, accum_B")
    for p in profiles:
        print(f"#   {p.name:14s} {p.data_mem:9.0f} {p.weight_mem:9.0f} "
              f"{p.accum_mem:9.0f}")
    print("# Fig4d/e: op, reads(d/w/a), writes(d/w/a)")
    for p in profiles:
        print(f"#   {p.name:14s} R {p.data_reads:12.0f} {p.weight_reads:12.0f}"
              f" {p.accum_reads:12.0f} | W {p.data_writes:10.0f}"
              f" {p.weight_writes:10.0f} {p.accum_writes:12.0f}")
    peak = analysis.peak_total_mem(profiles)
    cyc = analysis.total_cycles(profiles)
    rows.append(row("fig4.peak_onchip_bytes", us, f"{peak:.0f}"))
    rows.append(row("fig4.total_cycles", us, f"{cyc:.0f}"))
    rows.append(row("fig4.peak_op", us,
                    max(profiles, key=lambda p: p.total_mem).name))
    return rows


if __name__ == "__main__":
    main()
