"""Dataflow ablation: the paper's Fig. 4 bar values are figure-bound, so
two self-consistent CapsAcc dataflows are modeled and the CapStore DSE is
run on both.  'resident' satisfies every qualitative claim (accumulator
dominant, PrimaryCaps peak); 'linebuf' (line-buffered convs, votes in the
data memory) shows materially higher power-gating savings -- explaining
most of the residual gap to the paper's published -86 %."""

from benchmarks.common import row
from repro.core import analysis, dse


def main() -> list[str]:
    rows = []
    for dataflow in ("resident", "linebuf"):
        profiles = analysis.capsnet_profiles(dataflow)
        orgs = dse.design_organizations(profiles)
        evs = {n: dse.evaluate(o, profiles) for n, o in orgs.items()}
        best = dse.best_design(profiles)
        red = 1 - evs["PG-SEP"].total_mj / evs["SMP"].total_mj
        pg_gain = 1 - evs["PG-SEP"].total_mj / evs["SEP"].total_mj
        peak = analysis.peak_total_mem(profiles)
        peak_op = max(profiles, key=lambda p: p.total_mem).name
        print(f"\n# dataflow={dataflow}: peak {peak:.0f} B ({peak_op}), "
              f"best={best.org_name}/S={best.sectors}")
        print(f"#   PG-SEP vs SMP: -{red:.1%} (paper -86%);  "
              f"PG gain over SEP: -{pg_gain:.1%}")
        rows.append(row(f"dataflow.{dataflow}.pgsep_vs_smp", 0.0,
                        f"{red:.3f}"))
        rows.append(row(f"dataflow.{dataflow}.best", 0.0, best.org_name))
        rows.append(row(f"dataflow.{dataflow}.peak_bytes", 0.0,
                        f"{peak:.0f}"))
    return rows


if __name__ == "__main__":
    main()
