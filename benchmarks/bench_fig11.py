"""Paper Fig. 11 + Sec. 5.2: the COMPLETE accelerator with the selected
CapStore design (PG-SEP): energy and area breakdowns, and the headline
reductions vs (a) all-on-chip [11] and (b) the SMP hierarchy."""

from benchmarks.common import row, timed
from repro.core import analysis, dse


def main() -> list[str]:
    profiles = analysis.capsnet_profiles()
    orgs = dse.design_organizations(profiles)
    evs = {n: dse.evaluate(o, profiles) for n, o in orgs.items()}
    a = dse.all_onchip_system(profiles)
    b = dse.hierarchy_system(profiles, evs["SMP"])
    best = dse.best_design(profiles)
    (c, us) = timed(dse.hierarchy_system, profiles, best.evaluation)

    print(f"\n# Fig11 energy (mJ): accel {c.accelerator_mj:.3f} buf "
          f"{c.buffers_mj:.3f} onchip {c.onchip_mj:.3f} offchip "
          f"{c.offchip_mj:.3f} (accel share {c.accelerator_mj/c.total_mj:.1%}"
          f", paper: 4-5%)")
    print(f"# Fig11 area (mm2): onchip {c.onchip_area_mm2:.2f} total "
          f"{c.total_area_mm2:.2f}")
    rows = [
        row("fig11.total_vs_all_onchip", us,
            f"{1 - c.total_mj / a.total_mj:.3f} (paper: 0.78)"),
        row("fig11.total_vs_hierarchy_b", us,
            f"{1 - c.total_mj / b.total_mj:.3f} (paper: 0.46)"),
        row("fig11.onchip_vs_smp", us,
            f"{1 - best.total_mj / evs['SMP'].total_mj:.3f} (paper: 0.86)"),
        row("fig11.onchip_area_vs_smp", us,
            f"{1 - best.evaluation.area_mm2 / evs['SMP'].area_mm2:.3f} "
            f"(paper: 0.47)"),
        row("fig11.total_area_vs_all_onchip", us,
            f"{1 - c.total_area_mm2 / a.total_area_mm2:.3f} (paper: 0.25)"),
        row("fig11.accel_share", us,
            f"{c.accelerator_mj / c.total_mj:.3f} (paper: 0.04-0.05)"),
    ]
    return rows


if __name__ == "__main__":
    main()
