"""Benchmark harness: one module per paper table/figure (+ the TPU-side
planner, kernels, roofline, and paper-claim validation).

Prints ``name,us_per_call,derived`` CSV rows.
Usage: PYTHONPATH=src python -m benchmarks.run [module ...]
"""

import sys
import traceback

from benchmarks import (bench_capsule, bench_dataflow, bench_fig4,
                        bench_fig5, bench_fig10, bench_fig11, bench_kernels,
                        bench_paper_validation, bench_planner, bench_roofline,
                        bench_table2)

MODULES = {
    "capsule": bench_capsule,
    "fig4": bench_fig4,
    "fig5": bench_fig5,
    "table2": bench_table2,
    "fig10": bench_fig10,
    "fig11": bench_fig11,
    "dataflow": bench_dataflow,
    "planner": bench_planner,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
    "validation": bench_paper_validation,
}


def main() -> None:
    names = sys.argv[1:] or list(MODULES)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        try:
            MODULES[name].main()
        except Exception:
            failures += 1
            print(f"{name},0.0,ERROR", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
