"""Benchmark harness: one module per paper table/figure (+ the TPU-side
planner, kernels, roofline, and paper-claim validation).

Prints ``name,us_per_call,derived`` CSV rows.  ``--json PATH`` additionally
writes every row as a machine-readable artifact (CI uploads
``BENCH_capsule.json`` from the ``capsule`` module so the perf trajectory
is tracked across commits).

Usage: PYTHONPATH=src python -m benchmarks.run [module ...] [--json PATH]
"""

import argparse
import json
import platform
import traceback

from benchmarks import (bench_capsule, bench_dataflow, bench_fig4,
                        bench_fig5, bench_fig10, bench_fig11, bench_kernels,
                        bench_paper_validation, bench_planner, bench_roofline,
                        bench_table2, common)

MODULES = {
    "capsule": bench_capsule,
    "fig4": bench_fig4,
    "fig5": bench_fig5,
    "table2": bench_table2,
    "fig10": bench_fig10,
    "fig11": bench_fig11,
    "dataflow": bench_dataflow,
    "planner": bench_planner,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
    "validation": bench_paper_validation,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("modules", nargs="*", default=[], metavar="module",
                    help=f"subset of: {' '.join(MODULES)} (default: all)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a JSON artifact")
    args = ap.parse_args()
    unknown = [n for n in args.modules if n not in MODULES]
    if unknown:
        ap.error(f"unknown module(s) {unknown}; choose from {list(MODULES)}")
    names = args.modules or list(MODULES)
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        try:
            MODULES[name].main()
        except Exception:
            failures.append(name)
            print(f"{name},0.0,ERROR", flush=True)
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(dict(modules=names, failures=failures,
                           python=platform.python_version(),
                           rows=common.RECORDS), fh, indent=1)
        print(f"wrote {len(common.RECORDS)} rows to {args.json}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
