"""Benchmark harness: one module per paper table/figure (+ the TPU-side
planner, kernels, roofline, and paper-claim validation).

Prints ``name,us_per_call,derived`` CSV rows.  ``--json PATH`` additionally
writes every row as a machine-readable artifact (CI uploads
``BENCH_capsule.json`` from the ``capsule`` module so the perf trajectory
is tracked across commits).  ``--baseline PATH`` compares this run's
``us_per_call`` against a prior artifact and FAILS on regressions beyond
``--regression-factor`` (default 1.5x) -- CI runs the capsule module
against the committed ``benchmarks/BENCH_baseline.json`` so the perf
trajectory actually gates.

``--trend PATH [PATH ...]`` watches the drift the gate cannot see: it
compares the last N ``BENCH_capsule.json`` artifacts (chronological; a
single directory argument globs ``BENCH*.json`` by mtime), appends the
CURRENT run's rows as the newest point, and FAILS on rows whose
speed-normalized time creeps up monotonically across the window even
though every single step stayed below the gate's factor.

Usage: PYTHONPATH=src python -m benchmarks.run [module ...] [--json PATH]
       [--baseline PATH] [--regression-factor X]
       [--trend PATH ...] [--trend-window N]
"""

import argparse
import json
import pathlib
import platform
import traceback

from benchmarks import (bench_capsule, bench_dataflow, bench_fig4,
                        bench_fig5, bench_fig10, bench_fig11, bench_kernels,
                        bench_paper_validation, bench_planner, bench_roofline,
                        bench_table2, common)

MODULES = {
    "capsule": bench_capsule,
    "fig4": bench_fig4,
    "fig5": bench_fig5,
    "table2": bench_table2,
    "fig10": bench_fig10,
    "fig11": bench_fig11,
    "dataflow": bench_dataflow,
    "planner": bench_planner,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
    "validation": bench_paper_validation,
}

class BaselineSchemaError(RuntimeError):
    """The committed --baseline artifact cannot gate this run: malformed
    rows, duplicates, or STALE rows naming benchmarks the run no longer
    produces (a rename silently drops the row from the gate -- the
    regression it guarded would never flag again)."""


def check_baseline_schema(baseline: dict, rows: list[dict],
                          modules: list[str]) -> None:
    """Validate the --baseline artifact BEFORE gating against it.

    Structural checks always run: ``rows`` must be a list of dicts with a
    unique string ``name`` and a non-negative numeric ``us_per_call``.
    The staleness check runs only when this run covered every module the
    baseline recorded (a subset run legitimately misses rows): a timed
    baseline row absent from the current output names a benchmark that
    was renamed or removed, so the committed artifact needs a refresh.
    """
    if not isinstance(baseline, dict) \
            or not isinstance(baseline.get("rows"), list):
        raise BaselineSchemaError(
            "baseline artifact has no 'rows' list -- not a --json artifact "
            "of this harness")
    seen: set = set()
    for i, row in enumerate(baseline["rows"]):
        if not isinstance(row, dict) or not isinstance(row.get("name"), str):
            raise BaselineSchemaError(
                f"baseline row {i} has no string 'name': {row!r}")
        us = row.get("us_per_call", 0.0)
        if not isinstance(us, (int, float)) or isinstance(us, bool) \
                or us < 0.0:
            raise BaselineSchemaError(
                f"baseline row {row['name']!r}: us_per_call must be a "
                f"non-negative number, got {us!r}")
        if row["name"] in seen:
            raise BaselineSchemaError(
                f"baseline row {row['name']!r} appears twice -- ambiguous "
                f"gate")
        seen.add(row["name"])
    if set(baseline.get("modules", [])) <= set(modules):
        current = {r["name"] for r in rows}
        stale = sorted(
            row["name"] for row in baseline["rows"]
            if row.get("us_per_call", 0.0) > 0.0
            and row.get("gate", True) and row["name"] not in current)
        if stale:
            raise BaselineSchemaError(
                f"stale baseline row(s) {stale}: this run produced no such "
                f"benchmark -- refresh {BASELINE_NAME}")


def compare_baseline(rows: list[dict], baseline: dict,
                     factor: float) -> list[dict]:
    """Rows regressing beyond ``factor`` vs the baseline artifact.

    Only rows timed in BOTH runs participate (``us_per_call > 0``; the
    0.0-timed derived/plan rows carry no perf signal, and rows emitted
    with ``gate=False`` are wall-clock observations).  Machine speed is
    normalized out by the MEDIAN current/baseline ratio across the shared
    rows: a uniformly slower CI runner shifts every ratio (and the
    median with it) so nothing is flagged, while a single genuinely
    regressed row stands out against the unmoved median.

    Two accepted limitations of self-normalization: a regression hitting
    HALF or more of the gated rows moves the median with it and escapes
    (there is no absolute clock to compare against across machines), and
    machines whose per-row speed RATIOS differ from the baseline
    author's (BLAS/threading/cache differences) shift individual rows --
    CI therefore gates with a looser factor than the local default.
    """
    base = {r["name"]: r.get("us_per_call", 0.0)
            for r in baseline.get("rows", [])}
    cur = {r["name"]: r.get("us_per_call", 0.0) for r in rows
           if r.get("gate", True)}
    shared = {name: us / base[name] for name, us in cur.items()
              if us > 0.0 and base.get(name, 0.0) > 0.0}
    if not shared:
        return []
    ratios = sorted(shared.values())
    scale = ratios[len(ratios) // 2]              # median speed delta
    regressions = []
    for name, ratio in sorted(shared.items()):
        if ratio / scale > factor:
            regressions.append(dict(name=name, ratio=round(ratio / scale, 2),
                                    us_per_call=cur[name],
                                    baseline_us=base[name],
                                    scale=round(scale, 3)))
    return regressions


def detect_trend(histories: list[dict], *, min_points: int = 3,
                 tolerance: float = 0.03, min_total: float = 1.2
                 ) -> list[dict]:
    """Rows whose ``us_per_call`` creeps up monotonically across artifacts.

    The ``--baseline`` gate catches a single-step regression beyond its
    factor (1.5x locally); a drift of +10% per commit stays below that
    threshold forever.  Given the last N artifacts in chronological
    order, each artifact is speed-normalized by the MEDIAN ratio of its
    shared timed rows vs the first artifact (the gate's machine-speed
    cancellation), and a row is flagged when its normalized time never
    drops by more than ``tolerance`` at any step AND the total drift
    across the window exceeds ``min_total`` -- a monotonic slowdown the
    per-commit gate never fired on.

    Returns ``[{name, ratio, us_per_call, first_us, points}, ...]``;
    empty when fewer than ``min_points`` artifacts are given.
    """
    if len(histories) < min_points:
        return []
    runs = [{r["name"]: r.get("us_per_call", 0.0)
             for r in h.get("rows", []) if r.get("gate", True)}
            for h in histories]
    shared = [n for n, us in runs[0].items()
              if us > 0.0 and all(run.get(n, 0.0) > 0.0 for run in runs)]
    if not shared:
        return []
    norm = []
    for run in runs:
        ratios = sorted(run[n] / runs[0][n] for n in shared)
        scale = ratios[len(ratios) // 2]          # median speed delta
        norm.append({n: run[n] / scale for n in shared})
    flagged = []
    for name in sorted(shared):
        seq = [run[name] for run in norm]
        monotone = all(b >= a * (1.0 - tolerance)
                       for a, b in zip(seq, seq[1:]))
        total = seq[-1] / seq[0]
        if monotone and total > min_total:
            flagged.append(dict(name=name, ratio=round(total, 2),
                                us_per_call=runs[-1][name],
                                first_us=runs[0][name],
                                points=len(seq)))
    return flagged


BASELINE_NAME = "BENCH_baseline.json"


def _trend_paths(args_trend: list[str], window: int) -> list[pathlib.Path]:
    """Artifact paths, chronological: explicit files keep their order; a
    single directory argument globs BENCH*.json sorted by mtime.  The
    committed gate baseline (``BENCH_baseline.json``) is NOT a trend
    point: a freshly refreshed baseline has the newest mtime and would
    land as the "newest" run, corrupting the chronology (it still
    participates when named explicitly).  Only the last ``window``
    participate."""
    if len(args_trend) == 1 and pathlib.Path(args_trend[0]).is_dir():
        paths = sorted((p for p in
                        pathlib.Path(args_trend[0]).glob("BENCH*.json")
                        if p.name != BASELINE_NAME),
                       key=lambda p: p.stat().st_mtime)
    else:
        paths = [pathlib.Path(p) for p in args_trend]
    return paths[-window:]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("modules", nargs="*", default=[], metavar="module",
                    help=f"subset of: {' '.join(MODULES)} (default: all)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a JSON artifact")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="prior --json artifact to gate us_per_call against")
    ap.add_argument("--regression-factor", type=float, default=1.5,
                    metavar="X", help="fail when a row exceeds X * baseline "
                    "(speed-normalized; default 1.5)")
    ap.add_argument("--trend", nargs="+", default=None, metavar="PATH",
                    help="prior --json artifacts (chronological), or ONE "
                    "directory of them: fail on monotonic slowdowns the "
                    "per-commit gate stayed below")
    ap.add_argument("--trend-window", type=int, default=5, metavar="N",
                    help="how many of the newest artifacts to compare "
                    "(default 5)")
    args = ap.parse_args()
    unknown = [n for n in args.modules if n not in MODULES]
    if unknown:
        ap.error(f"unknown module(s) {unknown}; choose from {list(MODULES)}")
    names = args.modules or list(MODULES)
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        try:
            MODULES[name].main()
        except Exception:
            failures.append(name)
            print(f"{name},0.0,ERROR", flush=True)
            traceback.print_exc()
    if args.baseline:                 # gate BEFORE the artifact dump so a
        with open(args.baseline) as fh:   # baseline failure is recorded in it
            baseline = json.load(fh)
        try:
            check_baseline_schema(baseline, common.RECORDS, names)
        except BaselineSchemaError as err:
            print(f"BASELINE SCHEMA ERROR for {args.baseline}: {err}")
            failures.append("baseline-schema")
        regressions = compare_baseline(common.RECORDS, baseline,
                                       args.regression_factor)
        if regressions:
            print(f"PERF REGRESSIONS vs {args.baseline} "
                  f"(>{args.regression_factor}x, speed-normalized):")
            for r in regressions:
                print(f"  {r['name']}: {r['us_per_call']:.1f} us vs "
                      f"{r['baseline_us']:.1f} us baseline "
                      f"({r['ratio']}x at scale {r['scale']})")
            failures.append("baseline")
        else:
            print(f"no perf regressions vs {args.baseline} "
                  f"(factor {args.regression_factor}x)")
    if args.trend:
        paths = _trend_paths(args.trend, args.trend_window)
        histories = []
        for p in paths:
            with open(p) as fh:
                histories.append(json.load(fh))
        if common.RECORDS:
            # THIS run is the newest history point: a drift completed by
            # the current commit must flag now, not one artifact later.
            histories.append(dict(rows=common.RECORDS))
            histories = histories[-args.trend_window:]
        trends = detect_trend(histories)
        if trends:
            print(f"PERF TRENDS over {len(histories)} artifacts "
                  f"(monotonic, speed-normalized):")
            for t in trends:
                print(f"  {t['name']}: {t['first_us']:.1f} us -> "
                      f"{t['us_per_call']:.1f} us ({t['ratio']}x over "
                      f"{t['points']} runs)")
            failures.append("trend")
        elif len(histories) < 3:
            print(f"trend: only {len(histories)} artifact(s), need >= 3")
        else:
            print(f"no perf trends over {len(histories)} artifacts")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(dict(modules=names, failures=failures,
                           python=platform.python_version(),
                           rows=common.RECORDS), fh, indent=1)
        print(f"wrote {len(common.RECORDS)} rows to {args.json}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
