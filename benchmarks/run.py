"""Benchmark harness: one module per paper table/figure (+ the TPU-side
planner, kernels, roofline, and paper-claim validation).

Prints ``name,us_per_call,derived`` CSV rows.  ``--json PATH`` additionally
writes every row as a machine-readable artifact (CI uploads
``BENCH_capsule.json`` from the ``capsule`` module so the perf trajectory
is tracked across commits).  ``--baseline PATH`` compares this run's
``us_per_call`` against a prior artifact and FAILS on regressions beyond
``--regression-factor`` (default 1.5x) -- CI runs the capsule module
against the committed ``benchmarks/BENCH_baseline.json`` so the perf
trajectory actually gates.

Usage: PYTHONPATH=src python -m benchmarks.run [module ...] [--json PATH]
       [--baseline PATH] [--regression-factor X]
"""

import argparse
import json
import platform
import traceback

from benchmarks import (bench_capsule, bench_dataflow, bench_fig4,
                        bench_fig5, bench_fig10, bench_fig11, bench_kernels,
                        bench_paper_validation, bench_planner, bench_roofline,
                        bench_table2, common)

MODULES = {
    "capsule": bench_capsule,
    "fig4": bench_fig4,
    "fig5": bench_fig5,
    "table2": bench_table2,
    "fig10": bench_fig10,
    "fig11": bench_fig11,
    "dataflow": bench_dataflow,
    "planner": bench_planner,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
    "validation": bench_paper_validation,
}

def compare_baseline(rows: list[dict], baseline: dict,
                     factor: float) -> list[dict]:
    """Rows regressing beyond ``factor`` vs the baseline artifact.

    Only rows timed in BOTH runs participate (``us_per_call > 0``; the
    0.0-timed derived/plan rows carry no perf signal, and rows emitted
    with ``gate=False`` are wall-clock observations).  Machine speed is
    normalized out by the MEDIAN current/baseline ratio across the shared
    rows: a uniformly slower CI runner shifts every ratio (and the
    median with it) so nothing is flagged, while a single genuinely
    regressed row stands out against the unmoved median.

    Two accepted limitations of self-normalization: a regression hitting
    HALF or more of the gated rows moves the median with it and escapes
    (there is no absolute clock to compare against across machines), and
    machines whose per-row speed RATIOS differ from the baseline
    author's (BLAS/threading/cache differences) shift individual rows --
    CI therefore gates with a looser factor than the local default.
    """
    base = {r["name"]: r.get("us_per_call", 0.0)
            for r in baseline.get("rows", [])}
    cur = {r["name"]: r.get("us_per_call", 0.0) for r in rows
           if r.get("gate", True)}
    shared = {name: us / base[name] for name, us in cur.items()
              if us > 0.0 and base.get(name, 0.0) > 0.0}
    if not shared:
        return []
    ratios = sorted(shared.values())
    scale = ratios[len(ratios) // 2]              # median speed delta
    regressions = []
    for name, ratio in sorted(shared.items()):
        if ratio / scale > factor:
            regressions.append(dict(name=name, ratio=round(ratio / scale, 2),
                                    us_per_call=cur[name],
                                    baseline_us=base[name],
                                    scale=round(scale, 3)))
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("modules", nargs="*", default=[], metavar="module",
                    help=f"subset of: {' '.join(MODULES)} (default: all)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a JSON artifact")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="prior --json artifact to gate us_per_call against")
    ap.add_argument("--regression-factor", type=float, default=1.5,
                    metavar="X", help="fail when a row exceeds X * baseline "
                    "(speed-normalized; default 1.5)")
    args = ap.parse_args()
    unknown = [n for n in args.modules if n not in MODULES]
    if unknown:
        ap.error(f"unknown module(s) {unknown}; choose from {list(MODULES)}")
    names = args.modules or list(MODULES)
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        try:
            MODULES[name].main()
        except Exception:
            failures.append(name)
            print(f"{name},0.0,ERROR", flush=True)
            traceback.print_exc()
    if args.baseline:                 # gate BEFORE the artifact dump so a
        with open(args.baseline) as fh:   # baseline failure is recorded in it
            regressions = compare_baseline(common.RECORDS, json.load(fh),
                                           args.regression_factor)
        if regressions:
            print(f"PERF REGRESSIONS vs {args.baseline} "
                  f"(>{args.regression_factor}x, speed-normalized):")
            for r in regressions:
                print(f"  {r['name']}: {r['us_per_call']:.1f} us vs "
                      f"{r['baseline_us']:.1f} us baseline "
                      f"({r['ratio']}x at scale {r['scale']})")
            failures.append("baseline")
        else:
            print(f"no perf regressions vs {args.baseline} "
                  f"(factor {args.regression_factor}x)")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(dict(modules=names, failures=failures,
                           python=platform.python_version(),
                           rows=common.RECORDS), fh, indent=1)
        print(f"wrote {len(common.RECORDS)} rows to {args.json}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
