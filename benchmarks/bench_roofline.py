"""Roofline table (deliverable g): reads the dry-run JSONs produced by
``python -m repro.launch.dryrun`` and prints the per-(arch x shape x mesh)
three-term roofline + bottleneck + MFU."""

import json
import pathlib

from benchmarks.common import row

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results/dryrun"


def load_cells(mesh: str | None = "pod16x16") -> list[dict]:
    cells = []
    if not RESULTS.exists():
        return cells
    for f in sorted(RESULTS.glob("*.json")):
        d = json.loads(f.read_text())
        if mesh is not None and d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def main() -> list[str]:
    rows = []
    cells = load_cells()
    if not cells:
        print("# no dry-run results found; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun")
        return [row("roofline.cells", 0.0, "0")]
    ok = [c for c in cells if c["status"] == "ok"]
    print("\n# roofline (single-pod): arch, shape, compute_s, memory_s, "
          "collective_s, bottleneck, mfu, useful_ratio")
    for c in ok:
        r = c["roofline"]
        print(f"#   {c['arch']:22s} {c['shape']:12s} {r['compute_s']:9.4f} "
              f"{r['memory_s']:9.4f} {r['collective_s']:9.4f} "
              f"{r['bottleneck']:10s} {r['mfu']:7.4f} "
              f"{r['useful_flops_ratio']:7.3f}")
        rows.append(row(f"roofline.{c['arch']}.{c['shape']}",
                        r["step_time_s"] * 1e6,
                        f"bottleneck={r['bottleneck']};mfu={r['mfu']:.4f}"))
    n_skip = sum(1 for c in cells if c["status"] == "skipped")
    n_err = sum(1 for c in cells if c["status"] == "error")
    rows.append(row("roofline.cells", 0.0,
                    f"ok={len(ok)};skip={n_skip};error={n_err}"))
    return rows


if __name__ == "__main__":
    main()
