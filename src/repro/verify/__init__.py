"""Static verification of the repo's modeled contracts.

Two layers, no kernel execution anywhere:

* ``verify.lowering`` -- the plan auditor: abstract-traces every
  ``OpPlan``'s Pallas lowering (``jax.make_jaxpr``) and diffs the
  *derived* VMEM footprint / HBM traffic / W-stream pass counts against
  the numbers ``core.execplan`` modeled, and proves the
  zero-intermediate claims (``uhat_hbm_bytes=0``,
  ``intermediate_hbm_bytes=0``) from the jaxpr itself.
* ``verify.lint`` -- AST contract lint over ``src/repro``: fault sites
  on every public kernel wrapper, bounded ``lru_cache``s, jitted
  ``custom_vjp`` wrappers, no eager compute inside kernel bodies,
  formatted ``PlanError``s.

``verify.invariants`` holds the runtime-counter invariant checker the
serving test suites share.  CLI: ``python -m repro.verify``.
"""

from repro.verify.invariants import (assert_engine_stats,  # noqa: F401
                                     check_engine_stats)
from repro.verify.lint import (LintViolation, lint_paths,  # noqa: F401
                               lint_repo, lint_source)
from repro.verify.lowering import (Check, OpAudit, PlanAudit,  # noqa: F401
                                   audit_config, audit_op, audit_plan)

__all__ = [
    "audit_config", "audit_op", "audit_plan",
    "Check", "OpAudit", "PlanAudit",
    "lint_source", "lint_paths", "lint_repo", "LintViolation",
    "check_engine_stats", "assert_engine_stats",
]
