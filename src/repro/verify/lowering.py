"""Plan auditor: derive VMEM/HBM truth from the Pallas lowerings.

``core.execplan`` hand-models every ``OpPlan``'s VMEM footprint
(``vmem_bytes``), HBM traffic (``hbm_bytes``), W-stream pass count
(``n_passes``) and the zero-intermediate claims (``uhat_hbm_bytes=0``,
``intermediate_hbm_bytes=0``).  The DSE, the PMU gating schedule, and
``degrade_plan`` all optimize against those numbers, so a kernel edit
that silently drifts them corrupts every downstream decision.

This module closes the loop **statically**: each op's kernel entry
point is traced with ``jax.make_jaxpr`` over ``ShapeDtypeStruct``
operands (abstract eval -- nothing executes), the ``pallas_call``
equations are pulled out of the jaxpr, and the *derived* numbers are
computed from what the lowering actually says:

* **VMEM**: per ``pallas_call``, sum of operand block tiles
  (double-buffered when the operand's block index varies over the grid,
  single-buffered when it is constant -- the Pallas pipeline only
  prefetches blocks that change) plus output tiles (accumulator
  semantics: one buffer) plus every scratch allocation.  An op lowering
  to several sequential calls takes the max.
* **HBM traffic**: per operand, ``fetches x block_bytes`` where
  ``fetches`` counts block-index *transitions* over the grid iteration
  order (last grid axis fastest) -- so a streamed W re-fetched every
  pass derives ``n_passes`` from the index map instead of trusting the
  model's assertion.
* **Pass counts**: ``fetches / distinct_blocks`` of the W operand of
  the fused/pipelined kernels, compared exactly against
  ``OpPlan.n_passes``.
* **Zero-intermediate claims**: no equation *outside* a Pallas kernel
  body produces an array of the forbidden u_hat / inter-layer-u shape
  -- i.e. the tensor provably never exists at the HBM level.

Tolerances come from ``execplan.audit_contract`` (per-kernel: the model
counts in-register temporaries the lowering doesn't allocate, and the
lowering pays padding the model rounds away), so the comparison is
tight but honest.  See ``python -m repro.verify``.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import core as jcore

from repro.core import analysis, execplan
from repro.core.capsnet import CapsNetConfig
from repro.core.execplan import (BWD_SUFFIX, PIPE_NAME, ExecutionPlan,
                                 OpPlan)

_SDS = jax.ShapeDtypeStruct


class AuditError(RuntimeError):
    """An audited lowering could not be traced or matched to its plan op."""


# ---------------------------------------------------------------------------
# Jaxpr extraction
# ---------------------------------------------------------------------------

def _walk(jaxpr, calls: list, outer: list) -> None:
    """Collect ``pallas_call`` eqns and every NON-kernel-body eqn."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            calls.append(eqn)
            continue                      # never descend into kernel bodies
        outer.append(eqn)
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (tuple, list)) else (v,)):
                if isinstance(sub, jcore.ClosedJaxpr):
                    _walk(sub.jaxpr, calls, outer)
                elif isinstance(sub, jcore.Jaxpr):
                    _walk(sub, calls, outer)


def trace_lowering(fn, *avals):
    """Abstract-trace ``fn`` and return ``(pallas_eqns, outer_eqns)``.

    ``outer_eqns`` is every equation at any nesting level EXCEPT inside
    Pallas kernel bodies -- the HBM-level program the zero-intermediate
    checks scan.
    """
    closed = jax.make_jaxpr(fn)(*avals)
    calls: list = []
    outer: list = []
    _walk(closed.jaxpr, calls, outer)
    if not calls:
        raise AuditError("lowering contains no pallas_call")
    return calls, outer


def _index_walk(block_mapping, grid: tuple[int, ...]) -> tuple[int, int]:
    """(fetches, distinct_blocks) of one operand over the grid.

    Evaluates the BlockSpec index-map jaxpr at every grid point in
    iteration order (row-major, last axis fastest) and counts index
    transitions: the Pallas pipeline refetches a block exactly when its
    index differs from the previous step's.
    """
    if not grid:
        return 1, 1
    steps = np.stack(
        np.meshgrid(*[np.arange(g) for g in grid], indexing="ij"),
        axis=-1).reshape(-1, len(grid))
    cj = block_mapping.index_map_jaxpr

    def f(*idx):
        return jcore.eval_jaxpr(cj.jaxpr, cj.consts, *idx)

    outs = jax.vmap(f)(*(jnp.asarray(steps[:, k], jnp.int32)
                         for k in range(steps.shape[1])))
    arr = np.stack([np.asarray(o) for o in outs], axis=1)
    changed = (arr[1:] != arr[:-1]).any(axis=1)
    fetches = int(1 + changed.sum())
    distinct = int(len(np.unique(arr, axis=0)))
    return fetches, distinct


def _block_bytes(block_mapping) -> int:
    shape = tuple(1 if d is None else int(d)
                  for d in block_mapping.block_shape)
    dtype = np.dtype(block_mapping.array_shape_dtype.dtype)
    return math.prod(shape) * dtype.itemsize


@dataclasses.dataclass(frozen=True)
class OperandTrace:
    """One pallas_call operand as the lowering declares it."""

    role: str                 # "in" | "out"
    block_shape: tuple[int, ...]
    array_shape: tuple[int, ...]
    dtype: str
    fetches: int              # block-index transitions over the grid
    distinct: int             # distinct block indices touched
    block_bytes: int
    buffers: int              # 2 = double-buffered stream, 1 = resident
    traffic_bytes: int        # fetches * block_bytes


@dataclasses.dataclass(frozen=True)
class CallTrace:
    """One lowered ``pallas_call``: derived footprint and traffic."""

    kernel: str
    grid: tuple[int, ...]
    operands: tuple[OperandTrace, ...]
    scratch_shapes: tuple[tuple[tuple[int, ...], str], ...]
    scratch_bytes: int
    vmem_bytes: int           # derived peak on-chip bytes
    hbm_bytes: int            # derived traffic


def trace_pallas_eqn(eqn) -> CallTrace:
    """Derive one ``pallas_call``'s footprint/traffic from its params."""
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    bms = gm.block_mappings
    n_in = gm.num_inputs
    operands = []
    vmem = 0
    hbm = 0
    for i, bm in enumerate(bms):
        role = "in" if i < n_in else "out"
        fetches, distinct = _index_walk(bm, grid)
        bb = _block_bytes(bm)
        # Varying input blocks double-buffer (prefetch overlaps compute);
        # constant-index operands are fetched once and stay resident.
        # Outputs live in ONE accumulator buffer (revisited K-steps must
        # accumulate in place).
        buffers = 2 if (role == "in" and distinct > 1) else 1
        vmem += buffers * bb
        hbm += fetches * bb
        operands.append(OperandTrace(
            role=role,
            block_shape=tuple(1 if d is None else int(d)
                              for d in bm.block_shape),
            array_shape=tuple(bm.array_shape_dtype.shape),
            dtype=str(np.dtype(bm.array_shape_dtype.dtype)),
            fetches=fetches, distinct=distinct, block_bytes=bb,
            buffers=buffers, traffic_bytes=fetches * bb))
    scratch = []
    scratch_bytes = 0
    for var in eqn.params["jaxpr"].invars[len(bms):]:
        aval = getattr(var.aval, "inner_aval", var.aval)
        nbytes = math.prod(aval.shape) * np.dtype(aval.dtype).itemsize
        scratch_bytes += nbytes
        scratch.append((tuple(aval.shape), str(np.dtype(aval.dtype))))
    name = getattr(eqn.params.get("name_and_src_info"), "name",
                   None) or "pallas_call"
    return CallTrace(kernel=str(name), grid=grid, operands=tuple(operands),
                     scratch_shapes=tuple(scratch),
                     scratch_bytes=scratch_bytes,
                     vmem_bytes=vmem + scratch_bytes, hbm_bytes=hbm)


# ---------------------------------------------------------------------------
# Per-op entry points: rebuild exactly the call the network makes
# ---------------------------------------------------------------------------

def _conv_shapes(cfg: CapsNetConfig, dims, batch: int, name: str):
    if name == "Conv1":
        x = _SDS((batch, dims.in_hw, dims.in_hw, dims.conv1_cin),
                 jnp.float32)
        w = _SDS((cfg.conv1_kernel, cfg.conv1_kernel, dims.conv1_cin,
                  dims.conv1_cout), jnp.float32)
        b = _SDS((dims.conv1_cout,), jnp.float32)
        return x, w, b, 1, "relu"
    x = _SDS((batch, dims.conv1_out, dims.conv1_out, dims.pc_cin),
             jnp.float32)
    w = _SDS((cfg.pc_kernel, cfg.pc_kernel, dims.pc_cin, dims.pc_cout),
             jnp.float32)
    b = _SDS((dims.pc_cout,), jnp.float32)
    return x, w, b, cfg.pc_stride, "none"


def _layer_for(plan: ExecutionPlan, op_name: str):
    base = op_name[:-len(BWD_SUFFIX)] if op_name.endswith(BWD_SUFFIX) \
        else op_name
    for lay in plan.cfg.routing_stack():
        if lay.name == base:
            return lay
    raise AuditError(f"{op_name}: no routing layer matches this op")


def _trace_conv_fwd(plan: ExecutionPlan, op: OpPlan):
    from repro.kernels import squash as squash_mod
    from repro.kernels.conv_im2col import conv2d_im2col
    dims = analysis.dims_from_config(plan.cfg)
    x, w, b, stride, epilogue = _conv_shapes(plan.cfg, dims, plan.batch,
                                             op.name)
    squash_dim = 0
    if op.name == "PrimaryCaps" and op.fuses_squash:
        epilogue, squash_dim = "squash", dims.primary_dim

    def fn(xv, wv, bv):
        return conv2d_im2col(xv, wv, bv, stride=stride,
                             block_m=op.block.block_m,
                             block_k=op.block.block_k,
                             block_n=op.block.block_n,
                             epilogue=epilogue, squash_dim=squash_dim,
                             block_p=op.patch_rows)

    calls, outer = trace_lowering(fn, x, w, b)
    if op.name == "PrimaryCaps" and not op.fuses_squash:
        # The standalone blocked squash pass rides on this op's plan
        # entry (vmem max'd in); audit its lowering alongside.
        rows = plan.batch * dims.num_primary
        x2 = _SDS((rows, dims.primary_dim), jnp.float32)
        sq_calls, sq_outer = trace_lowering(
            lambda v: squash_mod._squash_core(op.block_rows, True, v), x2)
        calls, outer = calls + sq_calls, outer + sq_outer
    return calls, outer


def _trace_fused_fwd(plan: ExecutionPlan, op: OpPlan):
    from repro.kernels import votes_routing as vr
    lay = _layer_for(plan, op.name)
    st = vr._VRStatics(iters=lay.iters, num_classes=lay.num_caps,
                       mode=op.mode, block_i=op.block_i,
                       bwd_mode=op.mode, bwd_block_i=op.block_i,
                       interpret=True)
    u = _SDS((plan.batch, lay.in_caps, lay.in_dim), jnp.float32)
    w = _SDS((lay.in_caps, lay.jd, lay.in_dim), jnp.float32)
    if lay.residual:
        r = _SDS((plan.batch, lay.jd), jnp.float32)
        return trace_lowering(lambda uv, wv, rv: vr._vr_apply(st, uv, wv, rv),
                              u, w, r)
    return trace_lowering(lambda uv, wv: vr._vr_apply(st, uv, wv), u, w)


def _trace_fused_bwd(plan: ExecutionPlan, op: OpPlan):
    from repro.kernels import votes_routing as vr
    lay = _layer_for(plan, op.name)
    st = vr._VRStatics(iters=lay.iters, num_classes=lay.num_caps,
                       mode=op.mode, block_i=op.block_i,
                       bwd_mode=op.mode, bwd_block_i=op.block_i,
                       interpret=True)
    u = _SDS((plan.batch, lay.in_caps, lay.in_dim), jnp.float32)
    w = _SDS((lay.in_caps, lay.jd, lay.in_dim), jnp.float32)
    g = _SDS((plan.batch, lay.jd), jnp.float32)
    calls, outer = trace_lowering(
        lambda uv, wv, gv: vr._vr_grad(st, uv, wv, gv), u, w, g)
    if lay.residual:
        # Reversible inversion replays this coupling half FORWARD with
        # the forward op's schedule before the VJP proper; the plan's
        # backward entry models max(vmem) / summed traffic over both.
        fwd_op = plan.op(lay.name)
        fst = vr._VRStatics(iters=lay.iters, num_classes=lay.num_caps,
                            mode=fwd_op.mode, block_i=fwd_op.block_i,
                            bwd_mode=fwd_op.mode, bwd_block_i=fwd_op.block_i,
                            interpret=True)
        r = _SDS((plan.batch, lay.jd), jnp.float32)
        fcalls, fouter = trace_lowering(
            lambda uv, wv, rv: vr._vr_apply(fst, uv, wv, rv), u, w, r)
        calls, outer = calls + fcalls, outer + fouter
    return calls, outer


def _trace_pipe_fwd(plan: ExecutionPlan, op: OpPlan):
    from repro.kernels import primary_routing as pr
    dims = analysis.dims_from_config(plan.cfg)
    lay = plan.cfg.routing_stack()[0]
    st = pr._PRStatics(stride=plan.cfg.pc_stride, iters=lay.iters,
                       num_classes=lay.num_caps, mode=op.mode,
                       block_i=op.block_i, block_k=op.block_k,
                       bwd_mode=op.mode, bwd_block_i=op.block_i,
                       conv_block_m=op.block.block_m,
                       conv_block_k=op.block.block_k,
                       conv_block_n=op.block.block_n, interpret=True,
                       block_p=op.patch_rows)
    x = _SDS((plan.batch, dims.conv1_out, dims.conv1_out, dims.pc_cin),
             jnp.float32)
    w_pc = _SDS((plan.cfg.pc_kernel, plan.cfg.pc_kernel, dims.pc_cin,
                 dims.pc_cout), jnp.float32)
    b_pc = _SDS((dims.pc_cout,), jnp.float32)
    w_cc = _SDS((lay.in_caps, lay.jd, lay.in_dim), jnp.float32)
    return trace_lowering(
        lambda xv, wp, bp, wc: pr._pr_apply(st, xv, wp, bp, wc),
        x, w_pc, b_pc, w_cc)


def _trace_conv_bwd(plan: ExecutionPlan, op: OpPlan):
    from repro.kernels import conv_im2col as conv
    dims = analysis.dims_from_config(plan.cfg)
    base = op.name[:-len(BWD_SUFFIX)]
    x, w, b, stride, epilogue = _conv_shapes(plan.cfg, dims, plan.batch,
                                             base)
    squash_dim = 0
    pipelined_pc = base == "PrimaryCaps" and any(
        o.name == PIPE_NAME for o in plan.ops)
    if base == "PrimaryCaps" and (op.fuses_squash or pipelined_pc):
        # The backward recomputes the pre-activation from patches (the
        # third matmul the plan's `matmuls=3` accounts for).
        epilogue, squash_dim = "squash", dims.primary_dim
    st = conv._ConvStatics(stride=stride, block_m=op.block.block_m,
                           block_k=op.block.block_k,
                           block_n=op.block.block_n, epilogue=epilogue,
                           squash_dim=squash_dim, interpret=True,
                           block_p=op.patch_rows)
    kh, kw = w.shape[0], w.shape[1]
    oh = (x.shape[1] - kh) // stride + 1
    ow = (x.shape[2] - kw) // stride + 1
    dy = _SDS((plan.batch, oh, ow, w.shape[3]), jnp.float32)
    if epilogue == "relu":
        return trace_lowering(
            lambda xv, wv, bv, ov, gv: conv._conv_core_bwd(
                st, (xv, wv, bv, ov), gv), x, w, b, dy, dy)
    return trace_lowering(
        lambda xv, wv, bv, gv: conv._conv_core_bwd(
            st, (xv, wv, bv, None), gv), x, w, b, dy)


# ---------------------------------------------------------------------------
# Checks
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Check:
    name: str
    ok: bool
    detail: str


@dataclasses.dataclass(frozen=True)
class OpAudit:
    op: str
    kernel: str
    calls: tuple[CallTrace, ...]
    checks: tuple[Check, ...]

    @property
    def ok(self) -> bool:
        return all(c.ok for c in self.checks)

    def failures(self) -> list[Check]:
        return [c for c in self.checks if not c.ok]


@dataclasses.dataclass(frozen=True)
class PlanAudit:
    label: str
    ops: tuple[OpAudit, ...]

    @property
    def ok(self) -> bool:
        return all(o.ok for o in self.ops)

    def failures(self) -> list[tuple[str, Check]]:
        return [(o.op, c) for o in self.ops for c in o.failures()]


# Fused/pipelined kernel bodies and the grid position of their streamed
# W operand (the one whose derived fetch count IS the pass count).
_W_OPERAND = {
    "_resident_kernel": 1, "_streamed_kernel": 1,
    "_streamed_2pass_kernel": 1,
    "_resident_bwd_kernel": 1, "_streamed_bwd_kernel": 1,
    "_streamed_2pass_bwd_kernel": 1,
    "_pipe_resident_kernel": 3, "_pipe_streamed_kernel": 3,
}


def _main_call(calls: tuple[CallTrace, ...], op: OpPlan) -> CallTrace | None:
    """The fused/pipelined megakernel call carrying the W stream."""
    want_bwd = op.name.endswith(BWD_SUFFIX)
    for c in calls:
        base = c.kernel.split(" ")[0]
        if base in _W_OPERAND and ("bwd" in base) == want_bwd:
            return c
    return None


def _derived_passes(call: CallTrace) -> float:
    w = call.operands[_W_OPERAND[call.kernel.split(" ")[0]]]
    return w.fetches / max(w.distinct, 1)


def _shape_check(outer, forbidden: set, allowed: set, claim: str) -> Check:
    hits = sorted({tuple(v.aval.shape) for eqn in outer for v in eqn.outvars
                   if hasattr(v.aval, "shape")
                   and tuple(v.aval.shape) in forbidden
                   and tuple(v.aval.shape) not in allowed})
    return Check(
        name=claim, ok=not hits,
        detail=("no HBM-level array of a forbidden shape" if not hits else
                f"HBM-level intermediate(s) of forbidden shape {hits} "
                f"contradict the zero-intermediate claim"))


def _i_pad(i_dim: int, block_i: int) -> int:
    return math.ceil(i_dim / max(block_i, 1)) * max(block_i, 1)


def audit_op(plan: ExecutionPlan, op: OpPlan) -> OpAudit:
    """Trace one op's lowering and diff it against its plan entry."""
    tracers = {
        "conv_im2col": _trace_conv_fwd,
        "conv_im2col+squash": _trace_conv_fwd,
        "votes_routing": _trace_fused_fwd,
        "votes_routing_bwd": _trace_fused_bwd,
        "primary_routing": _trace_pipe_fwd,
        "conv_im2col_bwd": _trace_conv_bwd,
    }
    if op.kernel not in tracers:
        raise AuditError(f"{op.name}: no audit tracer for kernel "
                         f"{op.kernel!r} -- teach verify.lowering about it")
    eqns, outer = tracers[op.kernel](plan, op)
    calls = tuple(trace_pallas_eqn(e) for e in eqns)
    contract = execplan.audit_contract(op)
    checks: list[Check] = []

    derived_vmem = max(c.vmem_bytes for c in calls)
    limit = op.vmem_bytes * (1 + contract.vmem_rtol)
    checks.append(Check(
        name="vmem-under-model", ok=derived_vmem <= limit,
        detail=(f"derived {derived_vmem} B vs modeled {op.vmem_bytes} B "
                f"(+{contract.vmem_rtol:.0%} tolerance)")))
    checks.append(Check(
        name="vmem-over-model",
        ok=op.vmem_bytes <= derived_vmem * contract.vmem_over_factor,
        detail=(f"modeled {op.vmem_bytes} B vs derived {derived_vmem} B "
                f"(x{contract.vmem_over_factor} slack)")))

    if op.hbm_bytes is not None:
        derived_hbm = sum(c.hbm_bytes for c in calls)
        rel = abs(derived_hbm - op.hbm_bytes) / max(op.hbm_bytes, 1.0)
        checks.append(Check(
            name="hbm-traffic", ok=rel <= contract.hbm_rtol,
            detail=(f"derived {derived_hbm} B vs modeled "
                    f"{op.hbm_bytes:.0f} B ({rel:.1%} off, tolerance "
                    f"{contract.hbm_rtol:.0%})")))

    if op.n_passes is not None:
        main = _main_call(calls, op)
        if main is None:
            checks.append(Check(
                name="w-pass-count", ok=False,
                detail=f"no fused kernel call found among "
                       f"{[c.kernel for c in calls]}"))
        else:
            got = _derived_passes(main)
            # One block covering the whole i-axis never changes its block
            # index, so W crosses HBM once however many passes the grid
            # makes (the traffic models count the same way).
            w_op = main.operands[_W_OPERAND[main.kernel]]
            want = 1 if w_op.distinct <= 1 else op.n_passes
            checks.append(Check(
                name="w-pass-count", ok=got == want,
                detail=(f"W operand fetched {got:g} passes, plan models "
                        f"{want} ({op.mode}"
                        f"{', single i-block' if want != op.n_passes else ''})"
                        )))

    batch = plan.batch
    if op.uhat_hbm_bytes == 0.0 and op.kernel != "primary_routing":
        lay = _layer_for(plan, op.name)
        pad = _i_pad(lay.in_caps, op.block_i or lay.in_caps)
        forbidden = {(batch, lay.in_caps, lay.jd), (batch, pad, lay.jd)}
        allowed = {(batch, lay.in_caps, lay.in_dim),
                   (batch, pad, lay.in_dim)}
        checks.append(_shape_check(outer, forbidden, allowed,
                                   "uhat-never-in-hbm"))
    if op.kernel == "primary_routing":
        lay = plan.cfg.routing_stack()[0]
        pad = _i_pad(lay.in_caps, op.block_i or lay.in_caps)
        forbidden = {(batch, lay.in_caps, lay.jd), (batch, pad, lay.jd)}
        checks.append(_shape_check(outer, forbidden, set(),
                                   "uhat-never-in-hbm"))
        if op.intermediate_hbm_bytes == 0.0:
            forb_u = {(batch, lay.in_caps, lay.in_dim),
                      (batch, pad, lay.in_dim)}
            checks.append(_shape_check(outer, forb_u, set(),
                                       "u-never-in-hbm"))

    return OpAudit(op=op.name, kernel=op.kernel, calls=calls,
                   checks=tuple(checks))


def audit_plan(plan: ExecutionPlan, label: str = "") -> PlanAudit:
    """Audit every op of a compiled plan (no execution)."""
    return PlanAudit(label=label or f"batch={plan.batch} "
                                    f"train={plan.train}",
                     ops=tuple(audit_op(plan, op) for op in plan.ops))


def audit_config(cfg: CapsNetConfig, *, batch: int = 1,
                 vmem_budget: int | None = None, train: bool = False,
                 pipeline: bool = False, label: str = "") -> PlanAudit:
    """Compile ``cfg`` and audit the resulting plan."""
    kw = dict(batch=batch, train=train, pipeline=pipeline)
    if vmem_budget is not None:
        kw["vmem_budget"] = vmem_budget
    plan = execplan.compile_plan(cfg, **kw)
    return audit_plan(plan, label=label)
