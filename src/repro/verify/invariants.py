"""Runtime-counter invariant checkers shared by the serving test suites.

``CapsuleEngine.stats()`` promises (PR 8/9) that every submitted request
reaches exactly one terminal status and that the sharded per-shard
counters plus the queue bucket tell the same story as the aggregates.
``tests/test_faults.py`` and ``tests/test_sharded_serving.py`` used to
hand-roll that accounting independently; this is the ONE checker both
import (and ``python -m repro.verify`` documents).

Pure-dict checks -- no engine import, so the auditor CLI can run them on
recorded stats payloads too.
"""

from __future__ import annotations

# Mirrors serve.capsule.TERMINAL_STATUSES without importing the serving
# stack (keeps verify importable in jax-free tooling contexts); the
# cross-check test pins the two together.
TERMINAL_STATUSES = ("ok", "timeout", "error", "shed")


def check_engine_stats(stats: dict) -> list[str]:
    """Return every counter-sum invariant violation in a ``stats()`` dict
    (empty list == healthy).

    Invariants:
      * terminal statuses partition submissions:
        ``ok + timeout + error + shed == submitted``
      * one stats row per shard: ``len(per_shard) == n_shards``
      * per-shard counters + the queue bucket (requests that never
        reached a slot) reproduce each aggregate terminal counter
      * per-shard quarantines sum to the aggregate
    """
    problems: list[str] = []
    terminal = sum(stats[st] for st in TERMINAL_STATUSES)
    if terminal != stats["submitted"]:
        problems.append(
            f"terminal statuses sum to {terminal}, not submitted="
            f"{stats['submitted']} "
            f"({ {st: stats[st] for st in TERMINAL_STATUSES} })")
    shards = stats.get("per_shard", [])
    if len(shards) != stats.get("n_shards", len(shards)):
        problems.append(f"{len(shards)} per-shard rows for "
                        f"n_shards={stats.get('n_shards')}")
    queue = stats.get("queue_bucket", {})
    for st in TERMINAL_STATUSES:
        sharded = sum(sh[st] for sh in shards) + queue.get(st, 0)
        if sharded != stats[st]:
            problems.append(
                f"{st}: per-shard+queue accounting {sharded} != "
                f"aggregate {stats[st]}")
    if shards:
        q_sum = sum(sh.get("quarantined", 0) for sh in shards)
        if q_sum != stats.get("quarantined", q_sum):
            problems.append(
                f"quarantined: per-shard sum {q_sum} != aggregate "
                f"{stats.get('quarantined')}")
    return problems


def assert_engine_stats(engine) -> dict:
    """Assert the full terminal-accounting contract on a live engine and
    return its ``stats()`` dict (the shared replacement for the suites'
    hand-rolled ``_assert_terminal``)."""
    s = engine.stats()
    bad = [r.status for r in engine.finished
           if r.status not in TERMINAL_STATUSES]
    assert not bad, f"non-terminal finished statuses: {bad}"
    assert len(engine.finished) == s["submitted"], (
        f"{len(engine.finished)} finished records for "
        f"{s['submitted']} submissions")
    assert not engine.queue and all(a is None for a in engine.active), (
        "engine still holds queued/active work")
    problems = check_engine_stats(s)
    assert not problems, "; ".join(problems)
    return s
