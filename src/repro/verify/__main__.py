"""``python -m repro.verify`` -- the static-audit gate.

Sweeps every registered CapsNet arch across {per-op, pipelined} x
{forward, train} x a degraded-budget ladder, abstract-traces every
``OpPlan``'s Pallas lowering, and diffs the derived VMEM / HBM / W-pass
numbers against the plan's modeled contracts; then runs the AST
contract lint over ``src/repro``.  Exits nonzero on any drift, so CI
can gate on it (the ``static-audit`` job).  No kernel executes and no
array is materialized -- the whole sweep is jaxpr tracing.
"""

from __future__ import annotations

import argparse
import math
import sys

from repro.configs import registry
from repro.core import execplan
from repro.verify.lint import lint_repo
from repro.verify.lowering import audit_plan

# Degraded-budget rungs exercised per (arch, pipeline, train) cell: the
# full budget, then the serving runtime's replan ladder territory.
LADDER = (1.0, 0.5, 0.25)


def _parse(argv):
    ap = argparse.ArgumentParser(
        prog="python -m repro.verify",
        description="Statically verify modeled VMEM/HBM contracts "
                    "against the actual Pallas lowerings.")
    ap.add_argument("--arch", action="append",
                    help="CapsNet arch id (repeatable; default: all "
                         f"of {registry.CAPSNET_ARCHS})")
    ap.add_argument("--batch", type=int, default=1,
                    help="plan batch size (default 1)")
    ap.add_argument("--shards", type=int, default=1,
                    help="audit the per-shard plan of a batch split "
                         "over N engine shards (default 1)")
    ap.add_argument("--train", action="store_true",
                    help="audit ONLY train plans (default: fwd and train)")
    ap.add_argument("--pipeline", action="store_true",
                    help="audit ONLY pipelined plans (default: both)")
    ap.add_argument("--no-ladder", action="store_true",
                    help="skip the degraded-budget rungs")
    ap.add_argument("--lint-only", action="store_true")
    ap.add_argument("--audit-only", action="store_true")
    ap.add_argument("--lint-root", default=None,
                    help="directory to lint (default: the installed "
                         "repro package source)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="print failures only")
    return ap.parse_args(argv)


def _audit_cell(arch, cfg, *, batch, train, pipeline, frac, quiet):
    """Audit one (arch, mode, budget-rung) cell; returns failure count."""
    budget = int(execplan.VMEM_BYTES * frac)
    label = (f"{arch} batch={batch} pipe={pipeline} train={train} "
             f"budget={frac:.0%}")
    try:
        if frac >= 1.0:
            plan = execplan.compile_plan(cfg, batch=batch, train=train,
                                         pipeline=pipeline)
        else:
            plan, report = execplan.degrade_plan(
                cfg, budget, batch=batch, train=train, pipeline=pipeline)
            if report.degraded and not quiet:
                print(f"  [{label}] concessions: "
                      f"{'; '.join(report.concessions)}")
    except execplan.PlanError as err:
        # An infeasible rung is a planner answer, not audit drift.
        if not quiet:
            print(f"  [{label}] no feasible plan: {err}")
        return 0
    audit = audit_plan(plan, label=label)
    fails = 0
    for op_audit in audit.ops:
        for check in op_audit.checks:
            if not check.ok:
                fails += 1
                print(f"DRIFT {label} {op_audit.op} [{check.name}] "
                      f"{check.detail}")
            elif not quiet:
                print(f"  ok {label} {op_audit.op} [{check.name}]")
    return fails


def main(argv=None) -> int:
    args = _parse(argv if argv is not None else sys.argv[1:])
    failures = 0

    if not args.lint_only:
        archs = args.arch or registry.CAPSNET_ARCHS
        trains = (True,) if args.train else (False, True)
        pipes = (True,) if args.pipeline else (False, True)
        rungs = (1.0,) if args.no_ladder else LADDER
        batch = max(1, math.ceil(args.batch / max(args.shards, 1)))
        cells = 0
        for arch in archs:
            cfg = registry.get_config(registry.canonical(arch))
            for pipeline in pipes:
                for train in trains:
                    for frac in rungs:
                        cells += 1
                        failures += _audit_cell(
                            arch, cfg, batch=batch, train=train,
                            pipeline=pipeline, frac=frac, quiet=args.quiet)
        print(f"audit: {cells} plan cells swept, {failures} drift(s)")

    if not args.audit_only:
        root = args.lint_root
        if root is None:
            import repro
            root = repro.__path__[0]
        violations = lint_repo(root)
        for v in violations:
            print(f"LINT {v}")
        failures += len(violations)
        print(f"lint: {len(violations)} violation(s) under {root}")

    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
