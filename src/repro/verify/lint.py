"""Contract lint: AST rules for the invariants that keep recurring in
review.

Rules (each one has bitten this repo at least once):

* ``unfaulted-wrapper`` -- every public eager kernel wrapper in
  ``kernels/ops.py`` (a public function that invokes one of the private
  kernel aliases) must carry a ``faults.corrupt_array`` site, so the
  chaos suite can reach every executor.
* ``unbounded-cache`` -- every ``functools.lru_cache`` must pass a
  finite ``maxsize`` (``functools.cache`` and ``maxsize=None`` grow
  without bound under shape churn; serving replans would leak).
* ``unjitted-custom-vjp-wrapper`` -- every public wrapper around a
  same-module ``jax.custom_vjp`` core must be jitted (an un-jitted
  wrapper re-traces the Pallas lowering per call).
* ``eager-compute-in-kernel`` -- no ``lax.conv*`` anywhere under
  ``kernels/`` (the plan-driven im2col kernels replaced them; a
  reintroduction bypasses the ExecutionPlan), and no nested
  ``pallas_call`` / ``jax.jit`` inside a kernel body (a function whose
  first parameter is a ``*_ref`` or whose name ends ``_kernel``).
* ``nameless-plan-error`` -- every ``raise PlanError(...)`` must format
  its message (f-string / ``.format`` / concatenation naming the op);
  a bare string constant cannot name the offending op/config.

Pure ``ast`` -- no imports of the linted code, so seeded-violation
tests lint source strings directly.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Iterable, Sequence

# Kernel wrappers in ops.py that never reach a Pallas executor (pure
# planning helpers) are exempt from the fault-site rule by not calling a
# kernel alias at all -- there is deliberately NO other exemption hook.

_ALL_ROLES = frozenset({"ops", "kernels"})


@dataclasses.dataclass(frozen=True)
class LintViolation:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression (``jax.lax.conv``)."""
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _decorator_names(fn: ast.FunctionDef) -> list[tuple[str, ast.expr]]:
    """(dotted name, node) per decorator; for ``functools.partial(f, ..)``
    the name reported is f's."""
    out = []
    for dec in fn.decorator_list:
        node = dec
        if isinstance(dec, ast.Call):
            name = _dotted(dec.func)
            if name.endswith("partial") and dec.args:
                out.append((_dotted(dec.args[0]), dec))
                continue
            out.append((name, dec))
        else:
            out.append((_dotted(node), node))
    return out


def _calls_in(fn: ast.AST) -> Iterable[ast.Call]:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            yield node


def _names_in(fn: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(fn) if isinstance(n, ast.Name)}


# ---------------------------------------------------------------------------
# Individual rules
# ---------------------------------------------------------------------------

def _rule_unbounded_cache(tree: ast.Module, path: str) -> list[LintViolation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for name, dec in _decorator_names(node):
            short = name.rsplit(".", 1)[-1]
            if short == "cache" and name in ("functools.cache", "cache"):
                out.append(LintViolation(
                    path, dec.lineno, "unbounded-cache",
                    f"{node.name}: functools.cache is unbounded; use "
                    f"lru_cache(maxsize=N)"))
            if short != "lru_cache":
                continue
            bounded = False
            if isinstance(dec, ast.Call):
                for kw in dec.keywords:
                    if kw.arg == "maxsize" and not (
                            isinstance(kw.value, ast.Constant)
                            and kw.value.value is None):
                        bounded = True
                if dec.args and not any(
                        isinstance(a, ast.Constant) and a.value is None
                        for a in dec.args[:1]):
                    bounded = True
            if not bounded:
                out.append(LintViolation(
                    path, dec.lineno, "unbounded-cache",
                    f"{node.name}: lru_cache without a finite maxsize"))
    return out


def _rule_nameless_plan_error(tree: ast.Module,
                              path: str) -> list[LintViolation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Raise) or node.exc is None:
            continue
        exc = node.exc
        if not (isinstance(exc, ast.Call)
                and _dotted(exc.func).rsplit(".", 1)[-1] == "PlanError"):
            continue
        if not exc.args:
            out.append(LintViolation(
                path, node.lineno, "nameless-plan-error",
                "PlanError raised without a message"))
            continue
        first = exc.args[0]
        if isinstance(first, ast.Constant) and isinstance(first.value, str):
            out.append(LintViolation(
                path, node.lineno, "nameless-plan-error",
                f"PlanError message {first.value!r} is a bare constant -- "
                f"format the op/config name into it"))
    return out


def _kernel_bodies(tree: ast.Module) -> list[ast.FunctionDef]:
    """Kernel-body functions: first param ``*_ref`` or name ``*_kernel``."""
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        args = node.args.args
        if node.name.endswith("_kernel") or (
                args and args[0].arg.endswith("_ref")):
            out.append(node)
    return out


def _rule_eager_compute(tree: ast.Module, path: str) -> list[LintViolation]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute):
            dotted = _dotted(node)
            leaf = dotted.rsplit(".", 1)[-1]
            if leaf.startswith("conv") and ".lax." in f".{dotted}":
                out.append(LintViolation(
                    path, node.lineno, "eager-compute-in-kernel",
                    f"{dotted}: lax convolutions bypass the plan-driven "
                    f"im2col kernels"))
    for body in _kernel_bodies(tree):
        for call in _calls_in(body):
            dotted = _dotted(call.func)
            leaf = dotted.rsplit(".", 1)[-1]
            if leaf in ("pallas_call", "jit"):
                out.append(LintViolation(
                    path, call.lineno, "eager-compute-in-kernel",
                    f"{body.name}: {dotted} inside a kernel body (kernel "
                    f"bodies run per grid step; nested lowering/tracing "
                    f"belongs in the wrapper)"))
    return out


def _rule_unjitted_custom_vjp(tree: ast.Module,
                              path: str) -> list[LintViolation]:
    cores: set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            for name, _dec in _decorator_names(node):
                if name.rsplit(".", 1)[-1] == "custom_vjp":
                    cores.add(node.name)
    if not cores:
        return []
    out = []
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef) \
                or node.name.startswith("_"):
            continue
        if not (_names_in(node) & cores):
            continue
        jitted = any(name.rsplit(".", 1)[-1] == "jit"
                     for name, _dec in _decorator_names(node))
        if not jitted:
            out.append(LintViolation(
                path, node.lineno, "unjitted-custom-vjp-wrapper",
                f"{node.name} calls custom_vjp core(s) "
                f"{sorted(_names_in(node) & cores)} without @jax.jit -- "
                f"every call would re-trace the Pallas lowering"))
    return out


def _rule_unfaulted_wrapper(tree: ast.Module,
                            path: str) -> list[LintViolation]:
    kernel_aliases: set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.ImportFrom) and node.module \
                and node.module.startswith("repro.kernels"):
            for alias in node.names:
                bound = alias.asname or alias.name
                if bound.startswith("_"):
                    kernel_aliases.add(bound)
    out = []
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef) \
                or node.name.startswith("_"):
            continue
        if not (_names_in(node) & kernel_aliases):
            continue                      # planning helper, no executor
        faulted = any(
            _dotted(call.func).rsplit(".", 1)[-1] == "corrupt_array"
            for call in _calls_in(node))
        if not faulted:
            out.append(LintViolation(
                path, node.lineno, "unfaulted-wrapper",
                f"{node.name} invokes kernel(s) "
                f"{sorted(_names_in(node) & kernel_aliases)} without a "
                f"faults.corrupt_array site -- the chaos suite cannot "
                f"reach this executor"))
    return out


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------

def lint_source(source: str, path: str = "<memory>", *,
                roles: frozenset[str] | set[str] = _ALL_ROLES
                ) -> list[LintViolation]:
    """Lint one module's source.  ``roles`` scopes the location-specific
    rules: ``"kernels"`` applies the kernel-module rules, ``"ops"`` the
    fault-site rule; the cache and PlanError rules always run."""
    tree = ast.parse(source, filename=path)
    out = _rule_unbounded_cache(tree, path)
    out += _rule_nameless_plan_error(tree, path)
    if "kernels" in roles:
        out += _rule_eager_compute(tree, path)
        out += _rule_unjitted_custom_vjp(tree, path)
    if "ops" in roles:
        out += _rule_unfaulted_wrapper(tree, path)
    return sorted(out, key=lambda v: (v.path, v.line))


def _roles_for(path: str) -> frozenset[str]:
    norm = path.replace(os.sep, "/")
    roles = set()
    if "/kernels/" in norm:
        roles.add("kernels")
    if norm.endswith("kernels/ops.py"):
        roles.add("ops")
    return frozenset(roles)


def lint_paths(paths: Sequence[str]) -> list[LintViolation]:
    out: list[LintViolation] = []
    for path in paths:
        with open(path, encoding="utf-8") as fh:
            out += lint_source(fh.read(), path, roles=_roles_for(path))
    return out


def lint_repo(root: str) -> list[LintViolation]:
    """Lint every ``.py`` module under ``root`` (typically ``src/repro``)."""
    paths = []
    for dirpath, _dirs, files in os.walk(root):
        for name in sorted(files):
            if name.endswith(".py"):
                paths.append(os.path.join(dirpath, name))
    return lint_paths(sorted(paths))
