"""Fused ClassCaps votes + routing megakernel: u_hat never touches HBM.

CapStore's central claim (Sec. 3.1) is that no routing value leaves the
chip.  The split Pallas path still violated it on TPU: ``caps_votes``
wrote the votes tensor ``u_hat [B, I, J*D]`` -- the single largest
intermediate of the network -- to HBM and ``routing`` immediately read it
back, a produce-once/consume-once round-trip dominating the traffic of
the memory-bound ClassCaps stage (CapsAcc: zero weight reuse, so bytes
moved, not FLOPs, are the lever).  This kernel computes the votes from
the u-tile and streamed ``W`` i-blocks and runs ALL routing iterations
with the routing state (logits ``b``, couplings ``c``, candidates
``s``/``v``) in VMEM scratch, so per forward only ``u [B, I, C]`` and
``W [I, J*D, C]`` are read and only ``v [B, J*D]`` is written.

The ExecutionPlan (``repro.core.execplan.plan_votes_routing``) chooses
between two schedules per configuration -- the DESCNet-style
per-configuration scratchpad decision:

  resident  grid ``(num_i_blocks,)``.  Each step computes one i-block of
            votes for the whole batch into a ``[B, I_pad, J*D]`` VMEM
            scratch; the last step runs every routing iteration on-chip.
            ``W`` and ``u`` are read exactly once.  Requires the full
            votes tensor to fit VMEM.

  streamed  grid ``(iters + 1, num_i_blocks)``.  Only ``u`` (constant
            index map: fetched once) and the routing state stay resident;
            votes are recomputed from streamed ``W`` tiles on every pass.
            Pass ``t`` runs one WHOLE routing iteration per ``W`` stream:
            while accumulating ``s_t`` from the recomputed votes block it
            first folds in the logits update ``b_t = b_{t-1} + <u_hat,
            v_{t-1}>`` for the same rows, against the previous pass's
            ``v_{t-1}`` held in VMEM scratch -- a one-iteration software
            pipeline that halves the old separate-s-pass/b-pass traffic.
            ``W`` is re-read ``iters + 1`` times -- the price of making
            num_primary >> VMEM configurations feasible at all.  The
            unfused two-pass schedule survives as ``mode="streamed-2pass"``
            (never plan-chosen): the oracle the fused pass is
            property-tested against.

Both schedules zero-pad the capsule axis up to a multiple of ``block_i``
(the ``conv_im2col`` K-axis idiom): a clamped ragged tail block would
double-count rows under the i-reduction, while zero rows contribute
nothing to ``s``, leave their logits at the uniform initialisation, and
never perturb the real capsules.

**Backward** (``jax.custom_vjp``): the cotangent of the votes, ``d u_hat``
-- as large as ``u_hat`` itself -- never touches HBM either.  Both
backward kernels recompute the routing iterations from the saved ``(u,
W)`` residuals entirely in VMEM scratch, honoring the jnp reference's
``stop_gradient(u_hat)`` convention (the logits updates and every s-sum
but the last iteration's are u_hat-constant under ``jax.grad``):

  resident  grid ``(2, num_i_blocks)``.  Pass 0 rebuilds the votes into
            the same ``[B, I_pad, J*D]`` scratch the forward used and, at
            the last i-block, replays every routing iteration on-chip and
            overwrites the scratch with ``d u_hat`` in place (the exact
            ``jax.vjp`` of the reference replay).  Pass 1 contracts each
            ``d u_hat`` i-block against the streamed ``W``/``u`` tiles
            into ``du`` / ``dW`` block outputs.

  streamed  grid ``(iters + 4, num_i_blocks)``.  Passes ``0..T`` replay
            the forward with the SAME fused s+b pass as the forward
            kernel (one W stream per replayed iteration) over a ROLLING
            pair of logits slabs (the stop-gradient convention means only
            ``b_{T-1}`` / ``b_T`` are ever consumed again, so slot
            ``t % 2`` suffices); pass ``T+1`` seeds ``db_T`` from the
            output cotangent; pass ``T+2`` accumulates ``dv_{T-1} =
            sum_i u_hat . db_T`` and squash-vjps it into ``ds_{T-1}``;
            the final pass emits ``du``/``dW`` per i-block from
            ``d u_hat = c_T (x) ds_T + c_{T-1} (x) ds_{T-1}`` without
            ever materializing it beyond one i-block.  There is NO deep
            reverse recurrence: with the logits updates u_hat-constant,
            ``db_t`` for ``t < T`` feeds nothing -- the backward is
            exactly one seed + one reverse pass, regardless of the
            iteration count.  The unfused replay survives as
            ``bwd_mode="streamed-2pass"`` (grid ``(2*iters + 4, ...)``),
            the oracle for the fused replay's gradients.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.capsnet import squash

MODES = ("resident", "streamed")        # plan-chooseable schedules
ORACLE_MODE = "streamed-2pass"          # unfused streamed oracle (tests)
ALL_MODES = MODES + (ORACLE_MODE,)


def _votes_block(u, w):
    """u: [B, TI, C], w: [TI, N, C] -> u_hat block [B, TI, N] (fp32)."""
    return jnp.einsum("bic,inc->bin", u.astype(jnp.float32),
                      w.astype(jnp.float32),
                      preferred_element_type=jnp.float32)


def _routing_iterations(uh4, iters: int):
    """All routing iterations on resident votes uh4 [B, I, J, D] -> v."""
    bsz, i_dim, j, _ = uh4.shape

    def iteration(_, b):
        c = jax.nn.softmax(b, axis=2)                 # couplings  [B, I, J]
        v = squash(jnp.einsum("bij,bijd->bjd", c, uh4))
        return b + jnp.einsum("bijd,bjd->bij", uh4, v)

    b = jax.lax.fori_loop(0, iters, iteration,
                          jnp.zeros((bsz, i_dim, j), jnp.float32))
    c = jax.nn.softmax(b, axis=2)
    return squash(jnp.einsum("bij,bijd->bjd", c, uh4))  # [B, J, D]


def _resident_kernel(u_ref, w_ref, *refs, iters: int, j: int,
                     d: int, n_blocks: int, block_i: int,
                     residual: bool = False):
    r_ref = refs[0] if residual else None   # residual-add epilogue operand
    o_ref, votes_scr = refs[-2], refs[-1]
    ib = pl.program_id(0)
    votes_scr[:, pl.ds(ib * block_i, block_i), :] = _votes_block(
        u_ref[...], w_ref[...])

    @pl.when(ib == n_blocks - 1)
    def _():
        bsz, i_pad, jd = votes_scr.shape
        v = _routing_iterations(votes_scr[...].reshape(bsz, i_pad, j, d),
                                iters)
        out = v.reshape(bsz, j * d)
        if residual:
            out = out + r_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


def _streamed_kernel(u_ref, w_ref, *refs, iters: int,
                     j: int, d: int, n_blocks: int, block_i: int,
                     n_passes: int, residual: bool = False):
    """Fused s+b pass: iteration ``t`` streams ``W`` exactly once.

    Before accumulating ``s_t`` from the recomputed votes block, the same
    block first applies the logits update ``b_t[rows] = b_{t-1}[rows] +
    <u_hat, v_{t-1}>`` against the previous pass's ``v`` in scratch -- a
    one-iteration software pipeline (pass 0 starts from the zero logits,
    so its update is skipped).  ``n_passes = iters + 1``: the last pass
    is the final readout.
    """
    del iters  # folded into n_passes = iters + 1
    r_ref = refs[0] if residual else None   # residual-add epilogue operand
    o_ref, b_scr, s_scr, v_scr = refs[-4], refs[-3], refs[-2], refs[-1]
    t = pl.program_id(0)
    ib = pl.program_id(1)
    rows = pl.ds(ib * block_i, block_i)
    bsz = u_ref.shape[0]
    uh4 = _votes_block(u_ref[:, rows, :],
                       w_ref[...]).reshape(bsz, block_i, j, d)

    @pl.when((t == 0) & (ib == 0))
    def _():
        b_scr[...] = jnp.zeros_like(b_scr)

    @pl.when(t > 0)
    def _():  # fold iteration t's logits update into the same W stream
        v = v_scr[...].reshape(bsz, j, d)
        b_scr[:, rows, :] += jnp.einsum("bijd,bjd->bij", uh4, v)

    @pl.when(ib == 0)
    def _():
        s_scr[...] = jnp.zeros_like(s_scr)

    c = jax.nn.softmax(b_scr[:, rows, :], axis=2)
    s_scr[...] += jnp.einsum("bij,bijd->bjd", c, uh4).reshape(bsz, j * d)

    @pl.when(ib == n_blocks - 1)
    def _():
        v_scr[...] = squash(s_scr[...].reshape(bsz, j, d)).reshape(bsz, j * d)

        @pl.when(t == n_passes - 1)
        def _():
            out = v_scr[...]
            if residual:       # epilogue only: v_scr itself stays pure v
                out = out + r_ref[...].astype(jnp.float32)
            o_ref[...] = out.astype(o_ref.dtype)


def _streamed_2pass_kernel(u_ref, w_ref, *refs,
                           iters: int, j: int, d: int, n_blocks: int,
                           block_i: int, n_passes: int,
                           residual: bool = False):
    """Unfused streamed schedule (``mode="streamed-2pass"``): one s-pass
    plus one b-pass per iteration, ``W`` re-read ``2*iters + 1`` times.
    Never plan-chosen -- kept as the oracle the fused pass is tested
    against."""
    del iters  # folded into n_passes = 2*iters + 1
    r_ref = refs[0] if residual else None   # residual-add epilogue operand
    o_ref, b_scr, s_scr, v_scr = refs[-4], refs[-3], refs[-2], refs[-1]
    p = pl.program_id(0)
    ib = pl.program_id(1)
    row0 = ib * block_i
    bsz = u_ref.shape[0]
    uh4 = _votes_block(u_ref[:, pl.ds(row0, block_i), :],
                       w_ref[...]).reshape(bsz, block_i, j, d)

    @pl.when((p == 0) & (ib == 0))
    def _():
        b_scr[...] = jnp.zeros_like(b_scr)

    @pl.when(p % 2 == 0)
    def _():  # s-pass: accumulate s over i-blocks, squash at the last one
        @pl.when(ib == 0)
        def _():
            s_scr[...] = jnp.zeros_like(s_scr)

        c = jax.nn.softmax(b_scr[:, pl.ds(row0, block_i), :], axis=2)
        s_scr[...] += jnp.einsum("bij,bijd->bjd", c, uh4).reshape(bsz, j * d)

        @pl.when(ib == n_blocks - 1)
        def _():
            v_scr[...] = squash(
                s_scr[...].reshape(bsz, j, d)).reshape(bsz, j * d)

            @pl.when(p == n_passes - 1)
            def _():
                out = v_scr[...]
                if residual:   # epilogue only: v_scr itself stays pure v
                    out = out + r_ref[...].astype(jnp.float32)
                o_ref[...] = out.astype(o_ref.dtype)

    @pl.when(p % 2 == 1)
    def _():  # b-pass: logits update from the recomputed votes + resident v
        v = v_scr[...].reshape(bsz, j, d)
        b_scr[:, pl.ds(row0, block_i), :] += jnp.einsum(
            "bijd,bjd->bij", uh4, v)


# ---------------------------------------------------------------------------
# Backward kernels: d u_hat stays in VMEM scratch, like u_hat itself
# ---------------------------------------------------------------------------

def _routing_ref_sg(uh4, *, iters: int):
    """Gradient-faithful replay of ``capsnet.routing_by_agreement``.

    Values match ``_routing_iterations``; under ``jax.vjp`` it honors the
    reference's ``stop_gradient(u_hat)`` convention: the logits update is
    always u_hat-constant, and the s-sum carries u_hat gradient only on
    the LAST body iteration (plus the final readout).
    """
    uh_ng = jax.lax.stop_gradient(uh4)
    b = jnp.zeros(uh4.shape[:3], jnp.float32)
    for it in range(iters):
        c = jax.nn.softmax(b, axis=2)
        u_used = uh4 if it == iters - 1 else uh_ng
        v = squash(jnp.einsum("bij,bijd->bjd", c, u_used))
        b = b + jnp.einsum("bijd,bjd->bij", uh_ng, v)
    c = jax.nn.softmax(b, axis=2)
    return squash(jnp.einsum("bij,bijd->bjd", c, uh4))


def _softmax_bwd(c, dc):
    """VJP of softmax over the class axis given its OUTPUT c."""
    return c * (dc - jnp.sum(c * dc, axis=2, keepdims=True))


def _squash_bwd(s, dv):
    """VJP of the reference squash at pre-activation s."""
    _, pull = jax.vjp(squash, s)
    return pull(dv)[0]


def _resident_bwd_kernel(u_ref, w_ref, g_ref, du_ref, dw_ref, votes_scr, *,
                         iters: int, j: int, d: int, n_blocks: int,
                         block_i: int):
    p = pl.program_id(0)
    ib = pl.program_id(1)

    @pl.when(p == 0)
    def _():  # rebuild the votes, then overwrite them with d u_hat in place
        votes_scr[:, pl.ds(ib * block_i, block_i), :] = _votes_block(
            u_ref[...], w_ref[...])

        @pl.when(ib == n_blocks - 1)
        def _():
            bsz, i_pad, jd = votes_scr.shape
            uh4 = votes_scr[...].reshape(bsz, i_pad, j, d)
            _, pull = jax.vjp(
                functools.partial(_routing_ref_sg, iters=iters), uh4)
            duh = pull(g_ref[...].astype(jnp.float32).reshape(bsz, j, d))[0]
            votes_scr[...] = duh.reshape(bsz, i_pad, jd)

    @pl.when(p == 1)
    def _():  # contract each d u_hat i-block against the streamed tiles
        duh = votes_scr[:, pl.ds(ib * block_i, block_i), :]
        du_ref[...] = jnp.einsum(
            "bin,inc->bic", duh, w_ref[...].astype(jnp.float32)
        ).astype(du_ref.dtype)
        dw_ref[...] = jnp.einsum(
            "bin,bic->inc", duh, u_ref[...].astype(jnp.float32)
        ).astype(dw_ref.dtype)


def _streamed_bwd_tail(p, ib, first_pass, rows, uh4, u_blk, w_ref, g_ref,
                       du_ref, dw_ref, b2_scr, s2_scr, db_scr, ds_last_scr,
                       ds_prev_scr, acc_scr, *, slot_last: int,
                       slot_prev: int, j: int, d: int, n_blocks: int,
                       block_i: int):
    """Seed / reverse / emit passes shared by BOTH streamed backward
    replays (fused and the 2-pass oracle) -- only the index of the first
    tail pass differs between them.  The three blocks are the
    gradient-critical core of the streamed backward, so they exist once."""
    bsz = u_blk.shape[0]

    # ---- seed (first_pass): ds_T from the cotangent, db_T ----
    @pl.when(p == first_pass)
    def _():
        @pl.when(ib == 0)
        def _():
            ds = _squash_bwd(
                s2_scr[pl.ds(slot_last, 1)][0].reshape(bsz, j, d),
                g_ref[...].astype(jnp.float32).reshape(bsz, j, d))
            ds_last_scr[...] = ds.reshape(bsz, j * d)

        ds = ds_last_scr[...].reshape(bsz, j, d)
        dc = jnp.einsum("bijd,bjd->bij", uh4, ds)
        c = jax.nn.softmax(b2_scr[pl.ds(slot_last, 1), :, rows, :][0],
                           axis=2)
        db_scr[:, rows, :] = _softmax_bwd(c, dc)

    # ---- one reverse pass (+1): dv_{T-1} = sum_i u_hat . db_T ----
    @pl.when(p == first_pass + 1)
    def _():
        @pl.when(ib == 0)
        def _():
            acc_scr[...] = jnp.zeros_like(acc_scr)

        acc_scr[...] += jnp.einsum("bijd,bij->bjd", uh4,
                                   db_scr[:, rows, :]).reshape(bsz, j * d)

        @pl.when(ib == n_blocks - 1)
        def _():
            ds = _squash_bwd(s2_scr[pl.ds(slot_prev, 1)][0].reshape(bsz, j, d),
                             acc_scr[...].reshape(bsz, j, d))
            ds_prev_scr[...] = ds.reshape(bsz, j * d)

    # ---- emit (+2): d u_hat one i-block at a time -> du, dW ----
    @pl.when(p == first_pass + 2)
    def _():
        c_last = jax.nn.softmax(
            b2_scr[pl.ds(slot_last, 1), :, rows, :][0], axis=2)
        c_prev = jax.nn.softmax(
            b2_scr[pl.ds(slot_prev, 1), :, rows, :][0], axis=2)
        ds_last = ds_last_scr[...].reshape(bsz, j, d)
        ds_prev = ds_prev_scr[...].reshape(bsz, j, d)
        duh = (c_last[..., None] * ds_last[:, None]
               + c_prev[..., None] * ds_prev[:, None]).reshape(
                   bsz, block_i, j * d)
        du_ref[...] = jnp.einsum(
            "bin,inc->bic", duh, w_ref[...].astype(jnp.float32)
        ).astype(du_ref.dtype)
        dw_ref[...] = jnp.einsum(
            "bin,bic->inc", duh, u_blk.astype(jnp.float32)
        ).astype(dw_ref.dtype)


def _streamed_bwd_kernel(u_ref, w_ref, g_ref, du_ref, dw_ref, b2_scr,
                         s2_scr, db_scr, ds_last_scr, ds_prev_scr, acc_scr,
                         v_scr, *, iters: int, j: int, d: int,
                         n_blocks: int, block_i: int):
    t_total = iters
    p = pl.program_id(0)
    ib = pl.program_id(1)
    row0 = ib * block_i
    rows = pl.ds(row0, block_i)
    bsz = u_ref.shape[0]
    u_blk = u_ref[:, rows, :]
    uh4 = _votes_block(u_blk, w_ref[...]).reshape(bsz, block_i, j, d)

    # Only b_{T-1}/b_T and s_{T-1}/s_T are ever consumed again (the
    # stop-gradient convention kills the deeper reverse chain), so the
    # replay keeps a rolling PAIR of slabs indexed by t % 2: pass t
    # overwrites slot t % 2 = b_{t-2}, which is already dead.
    slot_last = t_total % 2
    slot_prev = (t_total - 1) % 2

    # ---- fused forward replay (passes 0 .. T): one W stream per
    # iteration, the logits update folded into the s-pass exactly like
    # the forward kernel -- b_t = b_{t-1} + <u_hat, v_{t-1}> lands in
    # slot t % 2 before the same rows feed iteration t's softmax ----
    @pl.when((p == 0) & (ib == 0))
    def _():
        b2_scr[pl.ds(0, 1)] = jnp.zeros_like(b2_scr[pl.ds(0, 1)])

    @pl.when((p >= 1) & (p <= t_total))
    def _():  # iteration p's logits update rides this pass's W stream
        b_prev = b2_scr[pl.ds((p - 1) % 2, 1), :, rows, :][0]
        v = v_scr[...].reshape(bsz, j, d)
        b2_scr[pl.ds(p % 2, 1), :, rows, :] = (
            b_prev + jnp.einsum("bijd,bjd->bij", uh4, v))[None]

    @pl.when(p <= t_total)
    def _():  # s-pass of iteration p (p == T is the final readout)
        @pl.when(ib == 0)
        def _():
            acc_scr[...] = jnp.zeros_like(acc_scr)

        c = jax.nn.softmax(b2_scr[pl.ds(p % 2, 1), :, rows, :][0], axis=2)
        acc_scr[...] += jnp.einsum("bij,bijd->bjd", c, uh4).reshape(bsz,
                                                                    j * d)

        @pl.when(ib == n_blocks - 1)
        def _():
            s2_scr[pl.ds(p % 2, 1)] = acc_scr[...][None]
            v_scr[...] = squash(
                acc_scr[...].reshape(bsz, j, d)).reshape(bsz, j * d)

    # ---- seed / reverse / emit (passes T+1 .. T+3) ----
    _streamed_bwd_tail(p, ib, t_total + 1, rows, uh4, u_blk, w_ref, g_ref,
                       du_ref, dw_ref, b2_scr, s2_scr, db_scr, ds_last_scr,
                       ds_prev_scr, acc_scr, slot_last=slot_last,
                       slot_prev=slot_prev, j=j, d=d, n_blocks=n_blocks,
                       block_i=block_i)


def _streamed_2pass_bwd_kernel(u_ref, w_ref, g_ref, du_ref, dw_ref, b2_scr,
                               s2_scr, db_scr, ds_last_scr, ds_prev_scr,
                               acc_scr, v_scr, *, iters: int, j: int, d: int,
                               n_blocks: int, block_i: int):
    """Unfused streamed backward (``bwd_mode="streamed-2pass"``): the
    forward replay runs separate s- and b-passes (grid ``(2*iters + 4,
    num_i_blocks)``).  Never plan-chosen -- the oracle the fused replay's
    gradients are tested against."""
    t_total = iters
    p = pl.program_id(0)
    ib = pl.program_id(1)
    row0 = ib * block_i
    rows = pl.ds(row0, block_i)
    bsz = u_ref.shape[0]
    u_blk = u_ref[:, rows, :]
    uh4 = _votes_block(u_blk, w_ref[...]).reshape(bsz, block_i, j, d)

    slot_last = t_total % 2
    slot_prev = (t_total - 1) % 2

    # ---- forward replay (passes 0 .. 2T) ----
    t_fwd = p // 2

    @pl.when((p == 0) & (ib == 0))
    def _():
        b2_scr[pl.ds(0, 1)] = jnp.zeros_like(b2_scr[pl.ds(0, 1)])

    @pl.when((p <= 2 * t_total) & (p % 2 == 0))
    def _():  # s-pass of iteration t_fwd (t_fwd == T is the final readout)
        @pl.when(ib == 0)
        def _():
            acc_scr[...] = jnp.zeros_like(acc_scr)

        c = jax.nn.softmax(b2_scr[pl.ds(t_fwd % 2, 1), :, rows, :][0],
                           axis=2)
        acc_scr[...] += jnp.einsum("bij,bijd->bjd", c, uh4).reshape(bsz,
                                                                    j * d)

        @pl.when(ib == n_blocks - 1)
        def _():
            s2_scr[pl.ds(t_fwd % 2, 1)] = acc_scr[...][None]
            v_scr[...] = squash(
                acc_scr[...].reshape(bsz, j, d)).reshape(bsz, j * d)

    @pl.when((p <= 2 * t_total) & (p % 2 == 1))
    def _():  # b-pass: b_{t+1} = b_t + <u_hat, v_t>, into the other slot
        b_blk = b2_scr[pl.ds(t_fwd % 2, 1), :, rows, :][0]
        v = v_scr[...].reshape(bsz, j, d)
        b2_scr[pl.ds((t_fwd + 1) % 2, 1), :, rows, :] = (
            b_blk + jnp.einsum("bijd,bjd->bij", uh4, v))[None]

    # ---- seed / reverse / emit (passes 2T+1 .. 2T+3) ----
    _streamed_bwd_tail(p, ib, 2 * t_total + 1, rows, uh4, u_blk, w_ref,
                       g_ref, du_ref, dw_ref, b2_scr, s2_scr, db_scr,
                       ds_last_scr, ds_prev_scr, acc_scr,
                       slot_last=slot_last, slot_prev=slot_prev, j=j, d=d,
                       n_blocks=n_blocks, block_i=block_i)


# ---------------------------------------------------------------------------
# Forward dispatch + custom VJP
# ---------------------------------------------------------------------------

class _VRStatics(NamedTuple):
    """Hashable non-differentiable schedule for the fused custom_vjp."""

    iters: int
    num_classes: int
    mode: str
    block_i: int
    bwd_mode: str
    bwd_block_i: int
    interpret: bool


def _padded(u, w, block_i: int):
    bsz, i_dim, c = u.shape
    n_blocks = pl.cdiv(i_dim, block_i)
    i_pad = n_blocks * block_i
    if i_pad != i_dim:                     # zero-pad the reduction axis: a
        u = jnp.pad(u, ((0, 0), (0, i_pad - i_dim), (0, 0)))   # clamped tail
        w = jnp.pad(w, ((0, i_pad - i_dim), (0, 0), (0, 0)))   # would double-
    return u, w, n_blocks, i_pad                               # count rows


def _vr_apply(st: _VRStatics, u, w, r=None):
    """Forward dispatch.  ``r [B, J*D]`` (optional) is a residual added to
    the routed output just before the store -- the ResCapsBlock coupling
    epilogue; it rides the kernel's output block, never a separate pass."""
    bsz, i_dim, c = u.shape
    _, jd, _ = w.shape
    j = st.num_classes
    d = jd // j
    u, w, n_blocks, i_pad = _padded(u, w, st.block_i)
    out_shape = jax.ShapeDtypeStruct((bsz, jd), u.dtype)
    residual = r is not None
    operands = (u, w, r) if residual else (u, w)

    if st.mode == "resident":
        kernel = functools.partial(_resident_kernel, iters=st.iters, j=j,
                                   d=d, n_blocks=n_blocks,
                                   block_i=st.block_i, residual=residual)
        in_specs = [
            pl.BlockSpec((bsz, st.block_i, c), lambda ib: (0, ib, 0)),
            pl.BlockSpec((st.block_i, jd, c), lambda ib: (ib, 0, 0)),
        ]
        if residual:
            in_specs.append(pl.BlockSpec((bsz, jd), lambda ib: (0, 0)))
        return pl.pallas_call(
            kernel,
            grid=(n_blocks,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bsz, jd), lambda ib: (0, 0)),
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bsz, i_pad, jd), jnp.float32)],
            interpret=st.interpret,
        )(*operands)

    if st.mode == ORACLE_MODE:          # unfused oracle: s+b passes split
        n_passes = 2 * st.iters + 1
        body = _streamed_2pass_kernel
    else:                               # fused: one W stream per iteration
        n_passes = st.iters + 1
        body = _streamed_kernel
    kernel = functools.partial(body, iters=st.iters, j=j, d=d,
                               n_blocks=n_blocks, block_i=st.block_i,
                               n_passes=n_passes, residual=residual)
    in_specs = [
        # u: constant index map -> fetched once, resident for the run
        pl.BlockSpec((bsz, i_pad, c), lambda p, ib: (0, 0, 0)),
        # W: re-streamed every pass (the votes are recomputed on-chip)
        pl.BlockSpec((st.block_i, jd, c), lambda p, ib: (ib, 0, 0)),
    ]
    if residual:
        in_specs.append(pl.BlockSpec((bsz, jd), lambda p, ib: (0, 0)))
    return pl.pallas_call(
        kernel,
        grid=(n_passes, n_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bsz, jd), lambda p, ib: (0, 0)),
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bsz, i_pad, j), jnp.float32),   # logits b
            pltpu.VMEM((bsz, jd), jnp.float32),         # s accumulator
            pltpu.VMEM((bsz, jd), jnp.float32),         # squashed v
        ],
        interpret=st.interpret,
    )(*operands)


def _vr_grad(st: _VRStatics, u, w, g):
    """Backward dispatch: returns (du, dw) via the mode's Pallas kernel."""
    bsz, i_dim, c = u.shape
    _, jd, _ = w.shape
    j = st.num_classes
    d = jd // j
    block_i = max(1, min(st.bwd_block_i, i_dim))
    u_p, w_p, n_blocks, i_pad = _padded(u, w, block_i)
    out_shapes = [jax.ShapeDtypeStruct((bsz, i_pad, c), u.dtype),
                  jax.ShapeDtypeStruct((i_pad, jd, c), w.dtype)]

    def _emit_only_out_specs(last_p):
        # du/dW are written ONLY on the final emit pass.  Pallas shuttles
        # whatever block the index map names through VMEM on every grid
        # step, so an unpredicated ``ib`` map paid one full du + dW sweep
        # per replay pass (the static auditor measured n_passes x the
        # modeled output traffic); pinned to block 0 until the emit pass,
        # each output block crosses HBM exactly once.
        du = pl.BlockSpec(
            (bsz, block_i, c),
            lambda p, ib: (0, jnp.where(p == last_p, ib, 0), 0))
        dw = pl.BlockSpec(
            (block_i, jd, c),
            lambda p, ib: (jnp.where(p == last_p, ib, 0), 0, 0))
        return [du, dw]

    if st.bwd_mode == "resident":
        kernel = functools.partial(_resident_bwd_kernel, iters=st.iters,
                                   j=j, d=d, n_blocks=n_blocks,
                                   block_i=block_i)
        du, dw = pl.pallas_call(
            kernel,
            grid=(2, n_blocks),
            in_specs=[
                pl.BlockSpec((bsz, block_i, c), lambda p, ib: (0, ib, 0)),
                pl.BlockSpec((block_i, jd, c), lambda p, ib: (ib, 0, 0)),
                pl.BlockSpec((bsz, jd), lambda p, ib: (0, 0)),
            ],
            out_specs=_emit_only_out_specs(1),
            out_shape=out_shapes,
            scratch_shapes=[pltpu.VMEM((bsz, i_pad, jd), jnp.float32)],
            interpret=st.interpret,
        )(u_p, w_p, g)
    else:
        t = st.iters
        if st.bwd_mode == ORACLE_MODE:  # unfused replay: 2T+1 fwd passes
            body, n_passes = _streamed_2pass_bwd_kernel, 2 * t + 4
        else:                           # fused replay: T+1 fwd passes
            body, n_passes = _streamed_bwd_kernel, t + 4
        kernel = functools.partial(body, iters=t, j=j, d=d,
                                   n_blocks=n_blocks, block_i=block_i)
        du, dw = pl.pallas_call(
            kernel,
            grid=(n_passes, n_blocks),
            in_specs=[
                pl.BlockSpec((bsz, i_pad, c), lambda p, ib: (0, 0, 0)),
                pl.BlockSpec((block_i, jd, c), lambda p, ib: (ib, 0, 0)),
                pl.BlockSpec((bsz, jd), lambda p, ib: (0, 0)),
            ],
            out_specs=_emit_only_out_specs(n_passes - 1),
            out_shape=out_shapes,
            scratch_shapes=[
                pltpu.VMEM((2, bsz, i_pad, j), jnp.float32),  # b: rolling pair
                pltpu.VMEM((2, bsz, jd), jnp.float32),        # s_{T-1}, s_T
                pltpu.VMEM((bsz, i_pad, j), jnp.float32),     # db_T
                pltpu.VMEM((bsz, jd), jnp.float32),           # ds_T
                pltpu.VMEM((bsz, jd), jnp.float32),           # ds_{T-1}
                pltpu.VMEM((bsz, jd), jnp.float32),           # s/dv acc
                pltpu.VMEM((bsz, jd), jnp.float32),           # v
            ],
            interpret=st.interpret,
        )(u_p, w_p, g)
    return du[:, :i_dim, :], dw[:i_dim]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _vr_core(st: _VRStatics, u, w):
    return _vr_apply(st, u, w)


def _vr_core_fwd(st: _VRStatics, u, w):
    return _vr_apply(st, u, w), (u, w)


def _vr_core_bwd(st: _VRStatics, res, g):
    u, w = res
    return _vr_grad(st, u, w, g)


_vr_core.defvjp(_vr_core_fwd, _vr_core_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _vr_core_res(st: _VRStatics, u, w, r):
    """Fused votes + routing + residual-add epilogue: ``r [B, J*D]`` is
    added to the routed output inside the kernel (one coupling half of a
    ResCapsBlock).  The add is linear, so the backward is exactly
    ``_vr_grad`` plus a pass-through cotangent for ``r``."""
    return _vr_apply(st, u, w, r)


def _vr_core_res_fwd(st: _VRStatics, u, w, r):
    return _vr_apply(st, u, w, r), (u, w)


def _vr_core_res_bwd(st: _VRStatics, res, g):
    u, w = res
    du, dw = _vr_grad(st, u, w, g)
    return du, dw, g


_vr_core_res.defvjp(_vr_core_res_fwd, _vr_core_res_bwd)


# ---------------------------------------------------------------------------
# Reversible residual capsule segment (MoCapsNet-style ResCapsBlocks)
# ---------------------------------------------------------------------------

def _res_segment_run(blocks, x, ws):
    """Forward walk of a run of additive-coupling blocks: for each block
    ``(i1, st_f, st_g)`` split the capsule axis at ``i1`` and apply
    ``y1 = x1 + F(x2)``, ``y2 = x2 + G(y1)`` -- each half one fused
    votes+routing kernel with the residual-add epilogue."""
    h = x
    for k, (i1, st_f, st_g) in enumerate(blocks):
        bsz = h.shape[0]
        x1, x2 = h[:, :i1], h[:, i1:]
        y1 = _vr_core_res(st_f, x2, ws[2 * k],
                          x1.reshape(bsz, -1)).reshape(x1.shape)
        y2 = _vr_core_res(st_g, y1, ws[2 * k + 1],
                          x2.reshape(bsz, -1)).reshape(x2.shape)
        h = jnp.concatenate([y1, y2], axis=1)
    return h


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _res_segment(blocks, x, ws):
    return _res_segment_run(blocks, x, ws)


def _res_segment_fwd(blocks, x, ws):
    # REVERSIBLE: only the segment OUTPUT and the weights are saved --
    # never x or any per-block intermediate -- so activation residency
    # stays flat no matter how many blocks the segment chains.
    y = _res_segment_run(blocks, x, ws)
    return y, (y, ws)


def _res_segment_bwd(blocks, res, g):
    """Invert the coupling block-by-block from the segment output.

    For each block (last first): recompute ``G(y1)`` / ``F(x2)`` forward
    (capturing their VJPs) to reconstruct ``x2 = y2 - G(y1)``, ``x1 = y1
    - F(x2)``, then push the cotangents through the coupling::

        d y1_total = g1 + dG/dy1^T g2
        d x1       = d y1_total
        d x2       = g2 + dF/dx2^T d y1_total

    Each half costs one forward + one backward kernel call -- the same
    recompute-from-(u, W) idiom as ``_vr_core_bwd``, lifted to block
    granularity."""
    y, ws = res
    dws = [None] * len(ws)
    for k in range(len(blocks) - 1, -1, -1):
        i1, st_f, st_g = blocks[k]
        wf, wg = ws[2 * k], ws[2 * k + 1]
        y1, y2 = y[:, :i1], y[:, i1:]
        g1, g2 = g[:, :i1], g[:, i1:]
        gy1, vjp_g = jax.vjp(
            lambda a, w: _vr_core(st_g, a, w).reshape(y2.shape), y1, wg)
        x2 = y2 - gy1
        fx2, vjp_f = jax.vjp(
            lambda a, w: _vr_core(st_f, a, w).reshape(y1.shape), x2, wf)
        x1 = y1 - fx2
        dy1_g, dwg = vjp_g(g2)
        g1_tot = g1 + dy1_g
        dx2_f, dwf = vjp_f(g1_tot)
        g = jnp.concatenate([g1_tot, g2 + dx2_f], axis=1)
        y = jnp.concatenate([x1, x2], axis=1)
        dws[2 * k], dws[2 * k + 1] = dwf, dwg
    return g, tuple(dws)


_res_segment.defvjp(_res_segment_fwd, _res_segment_bwd)


def _seg_statics(stat, i_dim: int, interpret: bool) -> _VRStatics:
    iters, j, mode, block_i, bwd_mode, bwd_block_i = stat
    if mode not in ALL_MODES or bwd_mode not in ALL_MODES:
        raise ValueError(f"unknown mode {mode!r}/{bwd_mode!r}; "
                         f"choose from {ALL_MODES}")
    return _VRStatics(iters=iters, num_classes=j, mode=mode,
                      block_i=max(1, min(block_i, i_dim)),
                      bwd_mode=bwd_mode,
                      bwd_block_i=max(1, min(bwd_block_i, i_dim)),
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def res_caps_segment(x: jax.Array, ws, *, blocks,
                     interpret: bool = True) -> jax.Array:
    """x: [B, I, C] through a run of reversible ResCapsBlocks -> [B, I, C].

    ``blocks`` is a tuple of ``(i1, stats_f, stats_g)`` per block, where
    ``i1`` is the coupling split point and each ``stats`` is the half's
    ``(iters, num_out_caps, mode, block_i, bwd_mode, bwd_block_i)``
    schedule (from its plan op; see ``repro.kernels.ops`` for the
    plan-aware wrapper).  ``ws`` are the flat per-half weights, F then G
    per block: ``wf [I-i1, i1*C, C]``, ``wg [i1, (I-i1)*C, C]``.

    Differentiable with NO saved activations: ``jax.grad`` reconstructs
    each block's input from its output (additive coupling is invertible)
    and replays the halves' fused backward kernels.
    """
    bsz, i_dim, c = x.shape
    if len(ws) != 2 * len(blocks):
        raise ValueError(f"res_caps_segment: {len(blocks)} blocks need "
                         f"{2 * len(blocks)} half-weights, got {len(ws)}")
    resolved = []
    for n, (i1, sf, sg) in enumerate(blocks):
        i2 = i_dim - i1
        if not 1 <= i1 < i_dim:
            raise ValueError(f"res_caps_segment: block {n} split i1={i1} "
                             f"outside [1, {i_dim - 1}]")
        wf, wg = ws[2 * n], ws[2 * n + 1]
        if wf.shape != (i2, i1 * c, c) or wg.shape != (i1, i2 * c, c):
            raise ValueError(
                f"res_caps_segment: block {n} weight shapes {wf.shape}/"
                f"{wg.shape} do not match the i1={i1} coupling of "
                f"[{bsz}, {i_dim}, {c}]")
        resolved.append((i1, _seg_statics(sf, i2, interpret),
                         _seg_statics(sg, i1, interpret)))
    return _res_segment(tuple(resolved), x, tuple(ws))


@functools.partial(jax.jit, static_argnames=(
    "iters", "num_classes", "mode", "block_i", "bwd_mode", "bwd_block_i",
    "interpret"))
def votes_routing(u: jax.Array, w: jax.Array, *, iters: int = 3,
                  num_classes: int = 10, mode: str = "resident",
                  block_i: int = 128, bwd_mode: str | None = None,
                  bwd_block_i: int | None = None,
                  interpret: bool = True) -> jax.Array:
    """u: [B, I, C], w: [I, J*D, C] -> v: [B, J*D]; votes + full routing.

    ``mode``/``block_i`` come from the ExecutionPlan
    (``plan.op("ClassCaps-Routing")``); see ``repro.kernels.ops`` for the
    plan-aware wrapper.  The split ``caps_votes`` -> ``routing`` pair
    remains available as the oracle/fallback path, and
    ``mode="streamed-2pass"`` / ``bwd_mode="streamed-2pass"`` run the
    unfused streamed schedule (2*iters+1 / 2*iters+4 W passes) -- never
    plan-chosen, kept as the oracle for the fused s+b pass.

    Differentiable: ``jax.grad`` runs the mode's backward Pallas kernel
    (``bwd_mode``/``bwd_block_i``, defaulting to the forward schedule --
    the plan chooses them independently because the backward's scratch is
    larger), recomputing the routing iterations from the saved ``(u, W)``
    residuals so neither ``u_hat`` nor its cotangent touches HBM.
    """
    bsz, i_dim, c = u.shape
    _, jd, _ = w.shape
    j = num_classes
    if jd % j:
        raise ValueError(f"votes dim {jd} not divisible by classes {j}")
    if mode not in ALL_MODES:
        raise ValueError(f"unknown mode {mode!r}; choose from {ALL_MODES}")
    if iters < 1:
        raise ValueError(f"routing needs iters >= 1, got {iters}")
    bwd_mode = bwd_mode or mode
    if bwd_mode not in ALL_MODES:
        raise ValueError(
            f"unknown bwd_mode {bwd_mode!r}; choose from {ALL_MODES}")
    st = _VRStatics(iters=iters, num_classes=num_classes, mode=mode,
                    block_i=max(1, min(block_i, i_dim)),
                    bwd_mode=bwd_mode,
                    bwd_block_i=max(1, min(bwd_block_i or block_i, i_dim)),
                    interpret=interpret)
    return _vr_core(st, u, w)
