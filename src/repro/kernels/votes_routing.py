"""Fused ClassCaps votes + routing megakernel: u_hat never touches HBM.

CapStore's central claim (Sec. 3.1) is that no routing value leaves the
chip.  The split Pallas path still violated it on TPU: ``caps_votes``
wrote the votes tensor ``u_hat [B, I, J*D]`` -- the single largest
intermediate of the network -- to HBM and ``routing`` immediately read it
back, a produce-once/consume-once round-trip dominating the traffic of
the memory-bound ClassCaps stage (CapsAcc: zero weight reuse, so bytes
moved, not FLOPs, are the lever).  This kernel computes the votes from
the u-tile and streamed ``W`` i-blocks and runs ALL routing iterations
with the routing state (logits ``b``, couplings ``c``, candidates
``s``/``v``) in VMEM scratch, so per forward only ``u [B, I, C]`` and
``W [I, J*D, C]`` are read and only ``v [B, J*D]`` is written.

The ExecutionPlan (``repro.core.execplan.plan_votes_routing``) chooses
between two schedules per configuration -- the DESCNet-style
per-configuration scratchpad decision:

  resident  grid ``(num_i_blocks,)``.  Each step computes one i-block of
            votes for the whole batch into a ``[B, I_pad, J*D]`` VMEM
            scratch; the last step runs every routing iteration on-chip.
            ``W`` and ``u`` are read exactly once.  Requires the full
            votes tensor to fit VMEM.

  streamed  grid ``(2*iters + 1, num_i_blocks)``.  Only ``u`` (constant
            index map: fetched once) and the routing state stay resident;
            votes are recomputed from streamed ``W`` tiles on every pass.
            Even-numbered passes accumulate ``s`` (and squash into ``v``
            at the last i-block); odd passes update the logits ``b``.
            ``W`` is re-read ``2*iters + 1`` times -- the price of making
            num_primary >> VMEM configurations feasible at all.

Both schedules zero-pad the capsule axis up to a multiple of ``block_i``
(the ``conv_im2col`` K-axis idiom): a clamped ragged tail block would
double-count rows under the i-reduction, while zero rows contribute
nothing to ``s``, leave their logits at the uniform initialisation, and
never perturb the real capsules.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.capsnet import squash

MODES = ("resident", "streamed")


def _votes_block(u, w):
    """u: [B, TI, C], w: [TI, N, C] -> u_hat block [B, TI, N] (fp32)."""
    return jnp.einsum("bic,inc->bin", u.astype(jnp.float32),
                      w.astype(jnp.float32),
                      preferred_element_type=jnp.float32)


def _routing_iterations(uh4, iters: int):
    """All routing iterations on resident votes uh4 [B, I, J, D] -> v."""
    bsz, i_dim, j, _ = uh4.shape

    def iteration(_, b):
        c = jax.nn.softmax(b, axis=2)                 # couplings  [B, I, J]
        v = squash(jnp.einsum("bij,bijd->bjd", c, uh4))
        return b + jnp.einsum("bijd,bjd->bij", uh4, v)

    b = jax.lax.fori_loop(0, iters, iteration,
                          jnp.zeros((bsz, i_dim, j), jnp.float32))
    c = jax.nn.softmax(b, axis=2)
    return squash(jnp.einsum("bij,bijd->bjd", c, uh4))  # [B, J, D]


def _resident_kernel(u_ref, w_ref, o_ref, votes_scr, *, iters: int, j: int,
                     d: int, n_blocks: int, block_i: int):
    ib = pl.program_id(0)
    votes_scr[:, pl.ds(ib * block_i, block_i), :] = _votes_block(
        u_ref[...], w_ref[...])

    @pl.when(ib == n_blocks - 1)
    def _():
        bsz, i_pad, jd = votes_scr.shape
        v = _routing_iterations(votes_scr[...].reshape(bsz, i_pad, j, d),
                                iters)
        o_ref[...] = v.reshape(bsz, j * d).astype(o_ref.dtype)


def _streamed_kernel(u_ref, w_ref, o_ref, b_scr, s_scr, v_scr, *, iters: int,
                     j: int, d: int, n_blocks: int, block_i: int,
                     n_passes: int):
    del iters  # folded into n_passes = 2*iters + 1
    p = pl.program_id(0)
    ib = pl.program_id(1)
    row0 = ib * block_i
    bsz = u_ref.shape[0]
    uh4 = _votes_block(u_ref[:, pl.ds(row0, block_i), :],
                       w_ref[...]).reshape(bsz, block_i, j, d)

    @pl.when((p == 0) & (ib == 0))
    def _():
        b_scr[...] = jnp.zeros_like(b_scr)

    @pl.when(p % 2 == 0)
    def _():  # s-pass: accumulate s over i-blocks, squash at the last one
        @pl.when(ib == 0)
        def _():
            s_scr[...] = jnp.zeros_like(s_scr)

        c = jax.nn.softmax(b_scr[:, pl.ds(row0, block_i), :], axis=2)
        s_scr[...] += jnp.einsum("bij,bijd->bjd", c, uh4).reshape(bsz, j * d)

        @pl.when(ib == n_blocks - 1)
        def _():
            v_scr[...] = squash(
                s_scr[...].reshape(bsz, j, d)).reshape(bsz, j * d)

            @pl.when(p == n_passes - 1)
            def _():
                o_ref[...] = v_scr[...].astype(o_ref.dtype)

    @pl.when(p % 2 == 1)
    def _():  # b-pass: logits update from the recomputed votes + resident v
        v = v_scr[...].reshape(bsz, j, d)
        b_scr[:, pl.ds(row0, block_i), :] += jnp.einsum(
            "bijd,bjd->bij", uh4, v)


@functools.partial(jax.jit, static_argnames=(
    "iters", "num_classes", "mode", "block_i", "interpret"))
def votes_routing(u: jax.Array, w: jax.Array, *, iters: int = 3,
                  num_classes: int = 10, mode: str = "resident",
                  block_i: int = 128, interpret: bool = True) -> jax.Array:
    """u: [B, I, C], w: [I, J*D, C] -> v: [B, J*D]; votes + full routing.

    ``mode``/``block_i`` come from the ExecutionPlan
    (``plan.op("ClassCaps-Routing")``); see ``repro.kernels.ops`` for the
    plan-aware wrapper.  The split ``caps_votes`` -> ``routing`` pair
    remains available as the oracle/fallback path.
    """
    bsz, i_dim, c = u.shape
    _, jd, _ = w.shape
    j = num_classes
    if jd % j:
        raise ValueError(f"votes dim {jd} not divisible by classes {j}")
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; choose from {MODES}")
    d = jd // j
    block_i = max(1, min(block_i, i_dim))
    n_blocks = pl.cdiv(i_dim, block_i)
    i_pad = n_blocks * block_i
    if i_pad != i_dim:                     # zero-pad the reduction axis: a
        u = jnp.pad(u, ((0, 0), (0, i_pad - i_dim), (0, 0)))   # clamped tail
        w = jnp.pad(w, ((0, i_pad - i_dim), (0, 0), (0, 0)))   # would double-
    out_shape = jax.ShapeDtypeStruct((bsz, jd), u.dtype)       # count rows

    if mode == "resident":
        kernel = functools.partial(_resident_kernel, iters=iters, j=j, d=d,
                                   n_blocks=n_blocks, block_i=block_i)
        return pl.pallas_call(
            kernel,
            grid=(n_blocks,),
            in_specs=[
                pl.BlockSpec((bsz, block_i, c), lambda ib: (0, ib, 0)),
                pl.BlockSpec((block_i, jd, c), lambda ib: (ib, 0, 0)),
            ],
            out_specs=pl.BlockSpec((bsz, jd), lambda ib: (0, 0)),
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bsz, i_pad, jd), jnp.float32)],
            interpret=interpret,
        )(u, w)

    n_passes = 2 * iters + 1
    kernel = functools.partial(_streamed_kernel, iters=iters, j=j, d=d,
                               n_blocks=n_blocks, block_i=block_i,
                               n_passes=n_passes)
    return pl.pallas_call(
        kernel,
        grid=(n_passes, n_blocks),
        in_specs=[
            # u: constant index map -> fetched once, resident for the run
            pl.BlockSpec((bsz, i_pad, c), lambda p, ib: (0, 0, 0)),
            # W: re-streamed every pass (the votes are recomputed on-chip)
            pl.BlockSpec((block_i, jd, c), lambda p, ib: (ib, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bsz, jd), lambda p, ib: (0, 0)),
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bsz, i_pad, j), jnp.float32),   # logits b
            pltpu.VMEM((bsz, jd), jnp.float32),         # s accumulator
            pltpu.VMEM((bsz, jd), jnp.float32),         # squashed v
        ],
        interpret=interpret,
    )(u, w)
