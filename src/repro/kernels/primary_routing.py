"""Pipelined PrimaryCaps -> ClassCaps megakernel: u never touches HBM.

CapStore's energy win is a WHOLE-network claim: the paper keeps
inter-layer activations on-chip (DESCNet's inter-layer scratchpad,
CapsAcc's cross-layer reuse), not just the per-op intermediates.  After
PR 3/5 the routing megakernel already keeps ``u_hat`` in VMEM, but the
PrimaryCaps output ``u [B, I, C]`` still round-tripped HBM between two
``pallas_call``s.  This kernel runs the producer AND the consumer as ONE
``pallas_call``:

  produce   grid steps ``0 .. k_steps-1``.  The full producer output
            lives in a ``[B, I_pad, C]`` VMEM scratch (u is the SMALLEST
            tensor in the pair -- ~I*C floats per batch element -- which
            is exactly why the paper parks it on-chip).  Each step
            streams one K tile of the im2col patches and conv weight
            past it, accumulating ``pre += patches_k @ w_k``; the last
            K step applies the bias + per-capsule squash epilogue in
            place.  Patches and the conv weight are read exactly ONCE
            (a per-i-block recompute would re-stream the 21 MB MNIST
            conv weight once per i-block -- strictly worse traffic than
            the unfused pair).

  consume   the remaining grid steps are byte-for-byte the fused
            ``votes_routing`` schedules, reading u i-blocks from the
            produce scratch instead of an HBM operand.  The FIRST
            consume block rides the last produce step (u is fully
            squashed by in-body program order), so the pair overlaps by
            one step:

            resident  ``k_steps - 1 + n_blocks`` total steps; votes
                      into a ``[B, I_pad, J*D]`` scratch, all routing
                      iterations at the last block.
            streamed  ``k_steps - 1 + (iters+1) * n_blocks`` steps; the
                      fused s+b pass over re-streamed W tiles (the PR-5
                      single-stream-per-iteration schedule).

The conv-output -> capsule reshape is layout-free: row ``i = p * groups
+ g`` of u is exactly channels ``[g*C, (g+1)*C)`` of spatial position
``p``, so the produce scratch's rows ARE capsule rows and the epilogue
squashes over the trailing axis directly.  The i axis is zero-padded in
the SCRATCH (rows ``>= I`` stay at their zero initialisation, are
skipped by the epilogue, and are inert under the routing reduction --
the ``votes_routing`` padding argument verbatim, minus the host-side
copy).

**Backward** (``jax.custom_vjp``): recompute-from-patches.  The saved
residuals are the raw operands ``(x, W_pc, b_pc, W_cc)``; the backward
replays the producer (im2col + blocked matmul, epilogue recomputed like
the fused-squash conv backward), feeds the rebuilt u to the routing
backward kernels (``votes_routing._vr_grad`` -- ``d u_hat`` stays in
VMEM), pulls the squash VJP, and finishes with the conv backward's
``matmul_at_b`` / ``matmul_bias_act`` / ``col2im_patches`` kernels.  It
composes exactly the per-op backward OpPlans, so a pipelined training
plan keeps the per-op backward schedule unchanged.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.capsnet import squash
from repro.kernels.conv_im2col import (col2im_patches, im2col_patches,
                                       matmul_at_b, matmul_bias_act)
from repro.kernels.votes_routing import (_routing_iterations, _votes_block,
                                         _vr_grad, _VRStatics)

MODES = ("resident", "streamed")


def _produce_u(t, patches_ref, wpc_ref, bias_ref, u_scr, *, k_steps: int,
               p_pos: int, groups: int, caps_dim: int, i_dim: int):
    """Produce phase: accumulate one K tile of the im2col matmul into the
    resident output scratch; the last K step applies bias + squash in
    place.  Rows ``>= i_dim`` keep their zero initialisation -- the
    i-axis padding the consume phase relies on."""

    @pl.when(t == 0)
    def _():
        u_scr[...] = jnp.zeros_like(u_scr)

    @pl.when(t < k_steps)
    def _():
        bsz = patches_ref.shape[0]
        prod = jnp.einsum("bpk,kn->bpn",
                          patches_ref[...].astype(jnp.float32),
                          wpc_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        u_scr[:, pl.ds(0, i_dim), :] += prod.reshape(bsz, i_dim, caps_dim)

        @pl.when(t == k_steps - 1)
        def _():
            pre = u_scr[:, pl.ds(0, i_dim), :]
            bias = bias_ref[0].reshape(groups, caps_dim)
            caps = (pre.reshape(bsz, p_pos, groups, caps_dim)
                    + bias[None, None])
            u_scr[:, pl.ds(0, i_dim), :] = squash(caps).reshape(
                bsz, i_dim, caps_dim)


def _pipe_resident_kernel(patches_ref, wpc_ref, bias_ref, wcc_ref, o_ref,
                          u_scr, votes_scr, *, k_steps: int, p_pos: int,
                          groups: int, caps_dim: int, i_dim: int, iters: int,
                          j: int, d: int, n_blocks: int, block_i: int):
    t = pl.program_id(0)
    _produce_u(t, patches_ref, wpc_ref, bias_ref, u_scr, k_steps=k_steps,
               p_pos=p_pos, groups=groups, caps_dim=caps_dim, i_dim=i_dim)

    # The first consume block OVERLAPS the last produce step: u is fully
    # squashed by the time the body reaches this point (in-body program
    # order), so the grid is k_steps - 1 + n_blocks, not k_steps +
    # n_blocks.
    @pl.when(t >= k_steps - 1)
    def _():
        ib = t - (k_steps - 1)
        rows = pl.ds(ib * block_i, block_i)
        votes_scr[:, rows, :] = _votes_block(u_scr[:, rows, :], wcc_ref[...])

        @pl.when(ib == n_blocks - 1)
        def _():
            bsz, i_pad, jd = votes_scr.shape
            v = _routing_iterations(
                votes_scr[...].reshape(bsz, i_pad, j, d), iters)
            o_ref[...] = v.reshape(bsz, j * d).astype(o_ref.dtype)


def _pipe_streamed_kernel(patches_ref, wpc_ref, bias_ref, wcc_ref, o_ref,
                          u_scr, b_scr, s_scr, v_scr, *, k_steps: int,
                          p_pos: int, groups: int, caps_dim: int, i_dim: int,
                          j: int, d: int, n_blocks: int, block_i: int,
                          n_passes: int):
    """Consume steps are ``votes_routing._streamed_kernel``'s fused s+b
    pass verbatim, with the votes block recomputed from the produce
    scratch instead of an HBM u operand."""
    t = pl.program_id(0)
    _produce_u(t, patches_ref, wpc_ref, bias_ref, u_scr, k_steps=k_steps,
               p_pos=p_pos, groups=groups, caps_dim=caps_dim, i_dim=i_dim)

    @pl.when(t >= k_steps - 1)
    def _():  # first consume pass overlaps the last produce step
        q = t - (k_steps - 1)
        p = q // n_blocks
        ib = q % n_blocks
        rows = pl.ds(ib * block_i, block_i)
        bsz = u_scr.shape[0]
        uh4 = _votes_block(u_scr[:, rows, :],
                           wcc_ref[...]).reshape(bsz, block_i, j, d)

        @pl.when((p == 0) & (ib == 0))
        def _():
            b_scr[...] = jnp.zeros_like(b_scr)

        @pl.when(p > 0)
        def _():  # iteration p's logits update rides the same W stream
            v = v_scr[...].reshape(bsz, j, d)
            b_scr[:, rows, :] += jnp.einsum("bijd,bjd->bij", uh4, v)

        @pl.when(ib == 0)
        def _():
            s_scr[...] = jnp.zeros_like(s_scr)

        c = jax.nn.softmax(b_scr[:, rows, :], axis=2)
        s_scr[...] += jnp.einsum("bij,bijd->bjd", c, uh4).reshape(bsz, j * d)

        @pl.when(ib == n_blocks - 1)
        def _():
            v_scr[...] = squash(
                s_scr[...].reshape(bsz, j, d)).reshape(bsz, j * d)

            @pl.when(p == n_passes - 1)
            def _():
                o_ref[...] = v_scr[...].astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# Forward dispatch + custom VJP
# ---------------------------------------------------------------------------

class _PRStatics(NamedTuple):
    """Hashable non-differentiable schedule for the pipelined custom_vjp."""

    stride: int
    iters: int
    num_classes: int
    mode: str
    block_i: int
    block_k: int             # produce-phase K tile
    bwd_mode: str            # routing backward (votes_routing._vr_grad)
    bwd_block_i: int
    conv_block_m: int        # producer-replay matmul tiles (backward)
    conv_block_k: int
    conv_block_n: int
    interpret: bool
    block_p: int | None = None   # im2col extraction row block (None = full)


def _pr_apply(st: _PRStatics, x, w_pc, b_pc, w_cc):
    bsz, h, w_hw, _ = x.shape
    kh, kw, cin, n_ch = w_pc.shape
    oh = (h - kh) // st.stride + 1
    ow = (w_hw - kw) // st.stride + 1
    p_pos = oh * ow
    kk = kh * kw * cin
    i_dim, jd, caps_dim = w_cc.shape
    groups = n_ch // caps_dim
    j = st.num_classes
    d = jd // j

    patches = im2col_patches(x, kh=kh, kw=kw, stride=st.stride,
                             block_p=st.block_p,
                             interpret=st.interpret)          # [B, P, K]
    wpc2 = w_pc.reshape(kk, n_ch)
    bk = max(1, min(st.block_k, kk))
    if kk % bk:                        # zero-pad K (conv_im2col idiom): a
        pad = bk - kk % bk             # clamped tail K block would
        patches = jnp.pad(patches, ((0, 0), (0, 0), (0, pad)))   # double-
        wpc2 = jnp.pad(wpc2, ((0, pad), (0, 0)))                 # count rows
    k_steps = patches.shape[2] // bk

    block_i = max(1, min(st.block_i, i_dim))
    n_blocks = pl.cdiv(i_dim, block_i)
    i_pad = n_blocks * block_i
    w_cc_p = (jnp.pad(w_cc, ((0, i_pad - i_dim), (0, 0), (0, 0)))
              if i_pad != i_dim else w_cc)
    bias2 = b_pc.reshape(1, n_ch)
    out_shape = jax.ShapeDtypeStruct((bsz, jd), x.dtype)
    common = dict(k_steps=k_steps, p_pos=p_pos, groups=groups,
                  caps_dim=caps_dim, i_dim=i_dim, j=j, d=d,
                  n_blocks=n_blocks, block_i=block_i)

    # Produce-phase operands park on their final tile after step
    # k_steps-1 (unchanged block index -> no refetch); W holds its first
    # i-block until the consume steps start walking it.
    patch_spec = pl.BlockSpec(
        (bsz, p_pos, bk), lambda t: (0, 0, jnp.minimum(t, k_steps - 1)))
    wpc_spec = pl.BlockSpec(
        (bk, n_ch), lambda t: (jnp.minimum(t, k_steps - 1), 0))
    bias_spec = pl.BlockSpec((1, n_ch), lambda t: (0, 0))
    out_spec = pl.BlockSpec((bsz, jd), lambda t: (0, 0))

    if st.mode == "resident":
        kernel = functools.partial(_pipe_resident_kernel, iters=st.iters,
                                   **common)
        wcc_spec = pl.BlockSpec(
            (block_i, jd, caps_dim),
            lambda t: (jnp.clip(t - (k_steps - 1), 0, n_blocks - 1), 0, 0))
        return pl.pallas_call(
            kernel,
            grid=(k_steps - 1 + n_blocks,),
            in_specs=[patch_spec, wpc_spec, bias_spec, wcc_spec],
            out_specs=out_spec,
            out_shape=out_shape,
            scratch_shapes=[
                pltpu.VMEM((bsz, i_pad, caps_dim), jnp.float32),  # u
                pltpu.VMEM((bsz, i_pad, jd), jnp.float32),        # votes
            ],
            interpret=st.interpret,
        )(patches, wpc2, bias2, w_cc_p)

    n_passes = st.iters + 1
    kernel = functools.partial(_pipe_streamed_kernel, n_passes=n_passes,
                               **common)
    wcc_spec = pl.BlockSpec(
        (block_i, jd, caps_dim),
        lambda t: (jnp.maximum(t - (k_steps - 1), 0) % n_blocks, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(k_steps - 1 + n_passes * n_blocks,),
        in_specs=[patch_spec, wpc_spec, bias_spec, wcc_spec],
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bsz, i_pad, caps_dim), jnp.float32),  # u
            pltpu.VMEM((bsz, i_pad, j), jnp.float32),         # logits b
            pltpu.VMEM((bsz, jd), jnp.float32),               # s accumulator
            pltpu.VMEM((bsz, jd), jnp.float32),               # squashed v
        ],
        interpret=st.interpret,
    )(patches, wpc2, bias2, w_cc_p)


def _pr_grad(st: _PRStatics, x, w_pc, b_pc, w_cc, g):
    """Recompute-from-patches backward: replay the producer, run the
    routing backward on the rebuilt u, pull the squash VJP, finish with
    the conv backward kernels -- exactly the per-op backward OpPlans."""
    bsz, h, w_hw, cin = x.shape
    kh, kw, _, n_ch = w_pc.shape
    oh = (h - kh) // st.stride + 1
    ow = (w_hw - kw) // st.stride + 1
    p_pos = oh * ow
    m = bsz * p_pos
    kk = kh * kw * cin
    i_dim, jd, caps_dim = w_cc.shape
    groups = n_ch // caps_dim

    patches = im2col_patches(x, kh=kh, kw=kw, stride=st.stride,
                             block_p=st.block_p, interpret=st.interpret)
    p2 = patches.reshape(m, kk)
    wpc2 = w_pc.reshape(kk, n_ch)
    pre = matmul_bias_act(p2, wpc2, b_pc, block_m=st.conv_block_m,
                          block_k=st.conv_block_k, block_n=st.conv_block_n,
                          epilogue="none", interpret=st.interpret)
    caps = pre.reshape(m, groups, caps_dim)
    u3, pull = jax.vjp(squash, caps)
    u = u3.reshape(bsz, i_dim, caps_dim)

    vr_st = _VRStatics(iters=st.iters, num_classes=st.num_classes,
                       mode=st.bwd_mode, block_i=st.bwd_block_i,
                       bwd_mode=st.bwd_mode, bwd_block_i=st.bwd_block_i,
                       interpret=st.interpret)
    du, dw_cc = _vr_grad(vr_st, u, w_cc, g.astype(jnp.float32))

    dpre = pull(du.reshape(m, groups, caps_dim))[0].reshape(m, n_ch)
    dbias = jnp.sum(dpre, axis=0).astype(b_pc.dtype)
    dw_pc = matmul_at_b(p2, dpre, block_m=st.conv_block_m,
                        block_k=st.conv_block_k, block_n=st.conv_block_n,
                        interpret=st.interpret)
    dpatches = matmul_bias_act(
        dpre, jnp.transpose(wpc2).astype(jnp.float32),
        jnp.zeros((kk,), jnp.float32),
        block_m=st.conv_block_m, block_k=st.conv_block_n,
        block_n=st.conv_block_k, epilogue="none", interpret=st.interpret)
    dx = col2im_patches(dpatches.reshape(bsz, p_pos, kk), kh=kh, kw=kw,
                        stride=st.stride, h=h, w=w_hw,
                        block_p=st.block_p, interpret=st.interpret)
    return (dx.astype(x.dtype), dw_pc.reshape(w_pc.shape).astype(w_pc.dtype),
            dbias, dw_cc.astype(w_cc.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pr_core(st: _PRStatics, x, w_pc, b_pc, w_cc):
    return _pr_apply(st, x, w_pc, b_pc, w_cc)


def _pr_core_fwd(st: _PRStatics, x, w_pc, b_pc, w_cc):
    # Residuals are the raw operands: u is recomputed from patches in the
    # backward, so the inter-layer activation never exists off-chip in
    # either direction.
    return _pr_apply(st, x, w_pc, b_pc, w_cc), (x, w_pc, b_pc, w_cc)


def _pr_core_bwd(st: _PRStatics, res, g):
    return _pr_grad(st, *res, g)


_pr_core.defvjp(_pr_core_fwd, _pr_core_bwd)


@functools.partial(jax.jit, static_argnames=(
    "stride", "iters", "num_classes", "mode", "block_i", "block_k",
    "bwd_mode", "bwd_block_i", "conv_block_m", "conv_block_k",
    "conv_block_n", "block_p", "interpret"))
def primary_caps_routing(x: jax.Array, w_pc: jax.Array, b_pc: jax.Array,
                         w_cc: jax.Array, *, stride: int = 2, iters: int = 3,
                         num_classes: int = 10, mode: str = "resident",
                         block_i: int = 128, block_k: int = 512,
                         bwd_mode: str | None = None,
                         bwd_block_i: int | None = None,
                         conv_block_m: int = 128, conv_block_k: int = 128,
                         conv_block_n: int = 128, block_p: int | None = None,
                         interpret: bool = True) -> jax.Array:
    """x: [B, H, W, Cin] (Conv1 output), w_pc: [KH, KW, Cin, N] HWIO,
    b_pc: [N], w_cc: [I, J*D, C] -> v: [B, J*D].

    ONE ``pallas_call`` running the PrimaryCaps conv (im2col matmul +
    bias + per-capsule squash) and the full votes+routing consumer with
    the inter-layer activation u resident in VMEM scratch.  Schedule
    parameters come from the ExecutionPlan
    (``plan.op("PrimaryCaps-Routing")``); see ``repro.kernels.ops`` for
    the plan-aware wrapper.  The unfused two-call path
    (``conv2d_im2col`` + ``votes_routing``) remains the fallback and the
    parity oracle.

    Differentiable: the custom VJP replays the producer from patches and
    composes the per-op backward kernels (routing backward per
    ``bwd_mode``/``bwd_block_i``, conv backward over the
    ``conv_block_*`` tiles).
    """
    i_dim, jd, caps_dim = w_cc.shape
    kh, kw, _, n_ch = w_pc.shape
    if jd % num_classes:
        raise ValueError(
            f"votes dim {jd} not divisible by classes {num_classes}")
    if n_ch % caps_dim:
        raise ValueError(
            f"conv channels {n_ch} not divisible by capsule dim {caps_dim}")
    oh = (x.shape[1] - kh) // stride + 1
    ow = (x.shape[2] - kw) // stride + 1
    if oh * ow * (n_ch // caps_dim) != i_dim:
        raise ValueError(
            f"W_cc expects {i_dim} capsules, producer emits "
            f"{oh * ow * (n_ch // caps_dim)}")
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; choose from {MODES}")
    if iters < 1:
        raise ValueError(f"routing needs iters >= 1, got {iters}")
    bwd_mode = bwd_mode or mode
    st = _PRStatics(stride=stride, iters=iters, num_classes=num_classes,
                    mode=mode, block_i=max(1, min(block_i, i_dim)),
                    block_k=block_k, bwd_mode=bwd_mode,
                    bwd_block_i=max(1, min(bwd_block_i or block_i, i_dim)),
                    conv_block_m=conv_block_m, conv_block_k=conv_block_k,
                    conv_block_n=conv_block_n, interpret=interpret,
                    block_p=block_p)
    return _pr_core(st, x, w_pc, b_pc, w_cc)
