"""Blocked online-softmax (flash) attention kernel.

The LM-side application of the CapStore policy: attention at long context is
memory-bound on the KV stream, so the kernel keeps the reused operands --
the Q tile ("data memory") and the running (m, l, acc) state ("accumulator
memory") -- resident in VMEM while K/V tiles ("weight memory") stream
through once.  Exactly the paper's SEP organization, one VMEM region per
role, sized by the planner.

Supports: causal masking, sliding-window (Gemma local layers), logit
softcapping (Gemma-2), decode alignment (Tq < Tk aligns ends).

Grid: (batch*heads, q_blocks, kv_blocks), kv innermost so the scratch
carries across the kv sweep of each q block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float | None, block_q: int, block_k: int,
                  q_offset: int, kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                     # [bq, d]
    k = k_ref[0].astype(jnp.float32)                     # [bk, d]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # [bq, bk]
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap

    # Positions: query rows map to absolute positions q_offset + qi*bq + r
    # (q_offset = Tk - Tq aligns ends for decode), keys to ki*bk + c.
    rows = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                                  # [bq, 1]
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                          # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * alpha
                    + jax.lax.dot_general(
                        p, v_ref[0].astype(jnp.float32),
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        # Fully-masked rows (can happen with tiny windows) produce lsum = 0.
        lsum = l_scr[...]
        safe = jnp.where(lsum == 0.0, 1.0, lsum)
        o_ref[0, ...] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: [B, H, Tq, D], k/v: [B, H, Tk, D] -> [B, H, Tq, D].

    H is the post-GQA-expansion head count (callers expand or vmap KV heads).
    """
    b, h, tq, d = q.shape
    tk = k.shape[2]
    scale = (d ** -0.5) if scale is None else scale
    bq = min(block_q, tq)
    while tq % bq:
        bq //= 2
    bk = min(block_k, tk)
    while tk % bk:
        bk //= 2
    kv_blocks = tk // bk
    grid = (b * h, tq // bq, kv_blocks)

    qr = q.reshape(b * h, tq, d)
    kr = k.reshape(b * h, tk, d)
    vr = v.reshape(b * h, tk, d)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_k=bk, q_offset=tk - tq,
        kv_blocks=kv_blocks)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, tq, d)
