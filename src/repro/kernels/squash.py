"""Squash nonlinearity kernel: v = ||s||^2/(1+||s||^2) * s/||s||.

Elementwise-with-reduction over the capsule dimension; blocked over rows so
arbitrarily many capsules stream through a fixed VMEM tile (the activation
-unit stage of the CapsAcc pipeline).

The squash math itself is ``repro.core.capsnet.squash`` -- the ONE canonical
implementation shared by the jnp reference model, this kernel, and the fused
routing kernel (``repro.kernels.ref.squash`` stays a deliberately separate
oracle for validation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.capsnet import squash as squash_reference


def _squash_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = squash_reference(x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def squash(x: jax.Array, *, block_rows: int = 1024,
           interpret: bool = True) -> jax.Array:
    """x: [..., R, D]; squash along the last axis, blocked over R.

    Rows need not divide ``block_rows``: the grid is ``cdiv`` and the
    ragged tail block is row-parallel safe.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = max(1, min(block_rows, rows))
    out = pl.pallas_call(
        _squash_kernel,
        grid=(pl.cdiv(rows, br),),
        in_specs=[pl.BlockSpec((br, d), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((br, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2)
    return out.reshape(orig_shape)
