"""Squash nonlinearity kernel: v = ||s||^2/(1+||s||^2) * s/||s||.

Elementwise-with-reduction over the capsule dimension; blocked over rows so
arbitrarily many capsules stream through a fixed VMEM tile (the activation
-unit stage of the CapsAcc pipeline).

The squash math itself is ``repro.core.capsnet.squash`` -- the ONE canonical
implementation shared by the jnp reference model, this kernel, and the fused
routing kernel (``repro.kernels.ref.squash`` stays a deliberately separate
oracle for validation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.capsnet import squash as squash_reference


def _squash_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = squash_reference(x).astype(o_ref.dtype)


def _squash_bwd_kernel(x_ref, g_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    _, pull = jax.vjp(squash_reference, x)
    o_ref[...] = pull(g_ref[...].astype(jnp.float32))[0].astype(o_ref.dtype)


def _squash_call(kernel, rows: int, d: int, block_rows: int,
                 interpret: bool, *operands):
    br = max(1, min(block_rows, rows))
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(rows, br),),
        in_specs=[pl.BlockSpec((br, d), lambda r: (r, 0))
                  for _ in operands],
        out_specs=pl.BlockSpec((br, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), operands[0].dtype),
        interpret=interpret,
    )(*operands)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _squash_core(block_rows: int, interpret: bool, x2: jax.Array):
    return _squash_call(_squash_kernel, x2.shape[0], x2.shape[1],
                        block_rows, interpret, x2)


def _squash_core_fwd(block_rows, interpret, x2):
    return _squash_core(block_rows, interpret, x2), x2


def _squash_core_bwd(block_rows, interpret, x2, g):
    dx = _squash_call(_squash_bwd_kernel, x2.shape[0], x2.shape[1],
                      block_rows, interpret, x2, g)
    return (dx,)


_squash_core.defvjp(_squash_core_fwd, _squash_core_bwd)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def squash(x: jax.Array, *, block_rows: int = 1024,
           interpret: bool = True) -> jax.Array:
    """x: [..., R, D]; squash along the last axis, blocked over R.

    Rows need not divide ``block_rows``: the grid is ``cdiv`` and the
    ragged tail block is row-parallel safe.  Differentiable: the custom
    VJP replays the saved input through a blocked Pallas backward kernel
    (the exact ``jax.vjp`` of the reference squash, tile by tile).
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    out = _squash_core(block_rows, interpret, x.reshape(rows, d))
    return out.reshape(orig_shape)
