"""Plan-driven im2col convolution kernels: Conv1 / PrimaryCaps on the MXU.

CapsAcc (Marchisio et al. 2018) and DESCNet run the CapsuleNet conv stack
as im2col matmuls on the same PE array as the capsule operations; CapStore
sizes the on-chip memories from that schedule.  These kernels are the TPU
translation, in two Pallas stages:

  1. ``im2col_patches``: strided patch extraction.  One grid step per batch
     element keeps the (small) input feature map resident in VMEM (the
     paper's data memory) and emits the [OH*OW, KH*KW*C] patch matrix.

  2. ``matmul_bias_act``: blocked [M, K] x [K, N] matmul over the plan's
     ``block_m/k/n`` grid tiles with a fused epilogue (bias + ReLU for
     Conv1, bias + per-capsule squash for PrimaryCaps).  The patch tile is
     the data memory, the weight tile streams (double-buffered), and the
     output block is the accumulator that stays resident across the K grid
     axis -- the paper's accumulator memory.

Ragged final M/N blocks are safe the same way ``caps_votes`` is: Pallas
clamps the tail block identically on the input and output side, and each
(mi, ni) grid cell recomputes its full K reduction, so overlapped rows are
rewritten with identical values.  The K axis is different -- a clamped tail
block would double-count the overlap -- so K is zero-padded up to a
multiple of ``block_k`` instead (zero rows contribute nothing).

``conv2d_im2col`` carries a ``jax.custom_vjp``, so ``jax.grad`` through the
Pallas backend works end to end.  The backward pass is Pallas too:

  * dL/dW = patchesT @ dy via ``matmul_at_b`` (a blocked A^T B matmul over
    the SAME plan ``block_m/k/n`` tiles, with the shared M axis as the
    zero-padded reduction -- no HBM transpose of the patch slab);
  * dL/dpatches = dy @ W^T through ``matmul_bias_act`` (the weight
    transpose is tiny), then dL/dx via the ``col2im_patches`` scatter
    kernel, the exact transpose of the strided patch extraction;
  * epilogue cotangents come from the saved output (ReLU mask) or a
    recomputed pre-activation (per-capsule squash), matching ``jax.grad``
    of the jnp reference to float32 accuracy.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.capsnet import squash as squash_reference

EPILOGUES = ("none", "relu", "squash")


def _patches_kernel(x_ref, o_ref, *, kh: int, kw: int, stride: int,
                    oh: int, ow: int):
    x = x_ref[0]                                   # [H, W, C]
    c = x.shape[-1]
    taps = []
    for i in range(kh):                            # static unroll: one strided
        for j in range(kw):                        # slice per kernel tap
            taps.append(jax.lax.slice(
                x, (i, j, 0),
                (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, c),
                (stride, stride, 1)))              # [OH, OW, C]
    p = jnp.stack(taps, axis=2)                    # [OH, OW, KH*KW, C]
    o_ref[0] = p.reshape(oh * ow, kh * kw * c)


def _patches_block_kernel(x_ref, o_ref, *, kh: int, kw: int, stride: int,
                          ow: int, br: int, bc: int):
    """Row-blocked patch extraction: this grid step emits the ``br x bc``
    window of output positions starting at block ``pl.program_id(1)``.
    The image stays resident (its block index never changes within a
    batch element); only ``br * bc`` patch rows occupy VMEM at once."""
    q = pl.program_id(1)
    per_row = ow // bc
    oy0 = (q // per_row) * br
    ox0 = (q % per_row) * bc
    x = x_ref[0]                                   # [H, W, C]
    c = x.shape[-1]
    xs = jax.lax.dynamic_slice(
        x, (oy0 * stride, ox0 * stride, 0),
        ((br - 1) * stride + kh, (bc - 1) * stride + kw, c))
    taps = []
    for i in range(kh):
        for j in range(kw):
            taps.append(jax.lax.slice(
                xs, (i, j, 0),
                (i + (br - 1) * stride + 1, j + (bc - 1) * stride + 1, c),
                (stride, stride, 1)))              # [br, bc, C]
    p = jnp.stack(taps, axis=2)                    # [br, bc, KH*KW, C]
    o_ref[0] = p.reshape(br * bc, kh * kw * c)


@functools.partial(jax.jit, static_argnames=("kh", "kw", "stride", "block_p",
                                             "interpret"))
def im2col_patches(x: jax.Array, *, kh: int, kw: int, stride: int = 1,
                   block_p: int | None = None,
                   interpret: bool = True) -> jax.Array:
    """x: [B, H, W, C] -> patches [B, OH*OW, KH*KW*C] (VALID padding).

    Patch column order is ``(kh, kw, c)``-major, matching
    ``w.reshape(KH*KW*C, Cout)`` of an HWIO weight tensor.

    ``block_p`` bounds the VMEM held per grid step: ``None`` emits the
    whole patch matrix of one batch element at once (image + full matrix
    resident -- fine under a full budget), while a plan-chosen block
    emits ``block_p`` patch rows per step so a degraded budget only pays
    image + one row block.  ``block_p`` must tile the output grid: a
    divisor of ``OW`` (a within-row window) or a multiple of ``OW``
    whose row count divides ``OH`` (whole output rows).
    """
    b, h, w, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    if block_p is None or block_p >= oh * ow:
        kernel = functools.partial(_patches_kernel, kh=kh, kw=kw,
                                   stride=stride, oh=oh, ow=ow)
        return pl.pallas_call(
            kernel,
            grid=(b,),
            in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
            out_specs=pl.BlockSpec((1, oh * ow, kh * kw * c),
                                   lambda i: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((b, oh * ow, kh * kw * c),
                                           x.dtype),
            interpret=interpret,
        )(x)
    if block_p % ow == 0 and (oh % (block_p // ow)) == 0:
        br, bc = block_p // ow, ow
    elif block_p < ow and ow % block_p == 0:
        br, bc = 1, block_p
    else:
        raise ValueError(
            f"block_p={block_p} does not tile the {oh}x{ow} output grid "
            f"(need a divisor of OW or a multiple of OW dividing OH*OW)")
    kernel = functools.partial(_patches_block_kernel, kh=kh, kw=kw,
                               stride=stride, ow=ow, br=br, bc=bc)
    return pl.pallas_call(
        kernel,
        grid=(b, (oh * ow) // block_p),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i, q: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, block_p, kh * kw * c),
                               lambda i, q: (i, q, 0)),
        out_shape=jax.ShapeDtypeStruct((b, oh * ow, kh * kw * c), x.dtype),
        interpret=interpret,
    )(x)


def _matmul_kernel(p_ref, w_ref, b_ref, o_ref, *, k_steps: int,
                   epilogue: str, squash_dim: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        p_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)

    @pl.when(ki == k_steps - 1)
    def _():
        acc = o_ref[...] + b_ref[...]              # [TM, TN] + [1, TN]
        if epilogue == "relu":
            acc = jnp.maximum(acc, 0.0)
        elif epilogue == "squash":
            tm, tn = acc.shape
            acc = squash_reference(
                acc.reshape(tm, tn // squash_dim, squash_dim)
            ).reshape(tm, tn)
        o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_k", "block_n", "epilogue", "squash_dim", "interpret"))
def matmul_bias_act(p: jax.Array, w: jax.Array, bias: jax.Array, *,
                    block_m: int = 128, block_k: int = 128,
                    block_n: int = 128, epilogue: str = "none",
                    squash_dim: int = 0, interpret: bool = True) -> jax.Array:
    """p: [M, K], w: [K, N], bias: [N] -> epilogue(p @ w + bias): [M, N].

    ``epilogue="squash"`` treats every ``squash_dim`` consecutive output
    channels as one capsule and squashes it in-register before writeback
    (requires ``block_n`` and ``N`` to be multiples of ``squash_dim`` so
    ragged/clamped N tiles stay capsule-aligned).
    """
    m, k = p.shape
    _, n = w.shape
    if epilogue not in EPILOGUES:
        raise ValueError(f"unknown epilogue {epilogue!r}")
    bm = max(1, min(block_m, m))
    bn = max(1, min(block_n, n))
    bk = max(1, min(block_k, k))
    if epilogue == "squash" and (squash_dim < 1 or bn % squash_dim
                                 or n % squash_dim):
        raise ValueError(
            f"squash epilogue needs a positive capsule dim dividing both "
            f"block_n ({bn}) and N ({n}); got squash_dim={squash_dim}")
    if k % bk:                                     # zero-pad K: a clamped tail
        pad = bk - k % bk                          # K-block would double-count
        p = jnp.pad(p, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
        k += pad
    k_steps = k // bk
    kernel = functools.partial(_matmul_kernel, k_steps=k_steps,
                               epilogue=epilogue, squash_dim=squash_dim)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(m, bm), pl.cdiv(n, bn), k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((1, bn), lambda mi, ni, ki: (0, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(p, w, bias.reshape(1, n))


# ---------------------------------------------------------------------------
# Backward kernels
# ---------------------------------------------------------------------------

def _at_b_kernel(a_ref, b_ref, o_ref):
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32).T, b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_k", "block_n", "interpret"))
def matmul_at_b(a: jax.Array, b: jax.Array, *, block_m: int = 128,
                block_k: int = 128, block_n: int = 128,
                interpret: bool = True) -> jax.Array:
    """a: [M, K], b: [M, N] -> a^T @ b: [K, N] without an HBM transpose.

    The backward-pass dW matmul (patches^T @ dy): the shared M axis is the
    reduction here, so like the forward K axis it is zero-padded up to a
    multiple of ``block_m`` (a clamped tail block would double-count the
    overlap); ragged K/N tail blocks are rewrite-safe as in the forward.
    """
    m, k = a.shape
    mb, n = b.shape
    if m != mb:
        raise ValueError(f"matmul_at_b: M mismatch {m} vs {mb}")
    bm = max(1, min(block_m, m))
    bk = max(1, min(block_k, k))
    bn = max(1, min(block_n, n))
    if m % bm:
        pad = bm - m % bm
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
        m += pad
    return pl.pallas_call(
        _at_b_kernel,
        grid=(pl.cdiv(k, bk), pl.cdiv(n, bn), m // bm),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda ki, ni, mi: (mi, ki)),
            pl.BlockSpec((bm, bn), lambda ki, ni, mi: (mi, ni)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda ki, ni, mi: (ki, ni)),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.float32),
        interpret=interpret,
    )(a, b)


def _col2im_kernel(dp_ref, o_ref, *, kh: int, kw: int, stride: int,
                   oh: int, ow: int, h: int, w: int):
    c = o_ref.shape[-1]
    dp = dp_ref[0].reshape(oh, ow, kh * kw, c)
    dx = jnp.zeros((h, w, c), jnp.float32)
    tap = 0
    for i in range(kh):                            # static unroll: one strided
        for j in range(kw):                        # scatter-add per kernel tap
            dx = dx.at[i:i + (oh - 1) * stride + 1:stride,
                       j:j + (ow - 1) * stride + 1:stride, :].add(
                dp[:, :, tap].astype(jnp.float32))
            tap += 1
    o_ref[0] = dx.astype(o_ref.dtype)


def _col2im_block_kernel(dp_ref, o_ref, *, kh: int, kw: int, stride: int,
                         ow: int, br: int, bc: int, h: int, w: int):
    """Row-blocked col2im: dx stays resident as the accumulator across
    the row-block grid axis; each step scatter-adds one ``br x bc``
    window of patch cotangents into its strided dx region (windows of
    adjacent blocks overlap when ``stride < k``; the sequential grid
    makes the read-modify-write safe)."""
    q = pl.program_id(1)

    @pl.when(q == 0)
    def _():
        o_ref[0] = jnp.zeros_like(o_ref[0])

    per_row = ow // bc
    oy0 = (q // per_row) * br
    ox0 = (q % per_row) * bc
    c = o_ref.shape[-1]
    dp = dp_ref[0].reshape(br, bc, kh * kw, c)
    hs = (br - 1) * stride + kh
    ws = (bc - 1) * stride + kw
    dx = jnp.zeros((hs, ws, c), jnp.float32)
    tap = 0
    for i in range(kh):
        for j in range(kw):
            dx = dx.at[i:i + (br - 1) * stride + 1:stride,
                       j:j + (bc - 1) * stride + 1:stride, :].add(
                dp[:, :, tap].astype(jnp.float32))
            tap += 1
    base = o_ref[0]
    cur = jax.lax.dynamic_slice(
        base, (oy0 * stride, ox0 * stride, 0), (hs, ws, c))
    o_ref[0] = jax.lax.dynamic_update_slice(
        base, (cur + dx).astype(base.dtype),
        (oy0 * stride, ox0 * stride, 0))


@functools.partial(jax.jit, static_argnames=(
    "kh", "kw", "stride", "h", "w", "block_p", "interpret"))
def col2im_patches(dp: jax.Array, *, kh: int, kw: int, stride: int,
                   h: int, w: int, block_p: int | None = None,
                   interpret: bool = True) -> jax.Array:
    """dp: [B, OH*OW, KH*KW*C] -> dx: [B, H, W, C].

    The exact transpose of ``im2col_patches``: each kernel tap's cotangent
    slab is scatter-added back onto the strided input positions it was
    sliced from (one grid step per batch element, dx resident in VMEM).
    ``block_p`` streams the cotangent ``block_p`` patch rows at a time
    (same tiling constraints as ``im2col_patches``) so a degraded budget
    never holds the whole dpatches slab on chip.
    """
    bsz = dp.shape[0]
    c = dp.shape[2] // (kh * kw)
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    if block_p is None or block_p >= oh * ow:
        kernel = functools.partial(_col2im_kernel, kh=kh, kw=kw,
                                   stride=stride, oh=oh, ow=ow, h=h, w=w)
        return pl.pallas_call(
            kernel,
            grid=(bsz,),
            in_specs=[pl.BlockSpec((1, oh * ow, kh * kw * c),
                                   lambda i: (i, 0, 0))],
            out_specs=pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((bsz, h, w, c), jnp.float32),
            interpret=interpret,
        )(dp)
    if block_p % ow == 0 and (oh % (block_p // ow)) == 0:
        br, bc = block_p // ow, ow
    elif block_p < ow and ow % block_p == 0:
        br, bc = 1, block_p
    else:
        raise ValueError(
            f"block_p={block_p} does not tile the {oh}x{ow} output grid "
            f"(need a divisor of OW or a multiple of OW dividing OH*OW)")
    kernel = functools.partial(_col2im_block_kernel, kh=kh, kw=kw,
                               stride=stride, ow=ow, br=br, bc=bc, h=h, w=w)
    return pl.pallas_call(
        kernel,
        grid=(bsz, (oh * ow) // block_p),
        in_specs=[pl.BlockSpec((1, block_p, kh * kw * c),
                               lambda i, q: (i, q, 0))],
        out_specs=pl.BlockSpec((1, h, w, c), lambda i, q: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, w, c), jnp.float32),
        interpret=interpret,
    )(dp)


# ---------------------------------------------------------------------------
# conv2d_im2col: forward + custom VJP
# ---------------------------------------------------------------------------

class _ConvStatics(NamedTuple):
    """Hashable non-differentiable schedule for the conv custom_vjp."""

    stride: int
    block_m: int
    block_k: int
    block_n: int
    epilogue: str
    squash_dim: int
    interpret: bool
    block_p: int | None = None


def _conv_apply(st: _ConvStatics, x, w, bias):
    b, h, w_hw, cin = x.shape
    kh, kw, _, cout = w.shape
    oh = (h - kh) // st.stride + 1
    ow = (w_hw - kw) // st.stride + 1
    patches = im2col_patches(x, kh=kh, kw=kw, stride=st.stride,
                             block_p=st.block_p, interpret=st.interpret)
    out = matmul_bias_act(
        patches.reshape(b * oh * ow, kh * kw * cin),
        w.reshape(kh * kw * cin, cout), bias,
        block_m=st.block_m, block_k=st.block_k, block_n=st.block_n,
        epilogue=st.epilogue, squash_dim=st.squash_dim,
        interpret=st.interpret)
    return out.reshape(b, oh, ow, cout).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _conv_core(st: _ConvStatics, x, w, bias):
    return _conv_apply(st, x, w, bias)


def _conv_core_fwd(st: _ConvStatics, x, w, bias):
    out = _conv_apply(st, x, w, bias)
    # Only the ReLU backward reads the saved output (its mask); keeping
    # the [B,OH,OW,Cout] activation alive to the backward for the other
    # epilogues would waste the largest conv tensor per layer per step.
    return out, (x, w, bias, out if st.epilogue == "relu" else None)


def _conv_core_bwd(st: _ConvStatics, res, dy):
    x, w, bias, out = res
    b, h, w_hw, cin = x.shape
    kh, kw, _, cout = w.shape
    oh = (h - kh) // st.stride + 1
    ow = (w_hw - kw) // st.stride + 1
    m = b * oh * ow
    kk = kh * kw * cin
    dy2 = dy.reshape(m, cout).astype(jnp.float32)
    w2 = w.reshape(kk, cout)
    patches = im2col_patches(x, kh=kh, kw=kw, stride=st.stride,
                             block_p=st.block_p, interpret=st.interpret)
    p2 = patches.reshape(m, kk)

    # Epilogue cotangent: ReLU masks from the saved output; the fused
    # per-capsule squash recomputes the pre-activation (one extra blocked
    # matmul -- the recompute the backward plan accounts for).
    if st.epilogue == "relu":
        dpre = dy2 * (out.reshape(m, cout) > 0)
    elif st.epilogue == "squash":
        pre = matmul_bias_act(p2, w2, bias, block_m=st.block_m,
                              block_k=st.block_k, block_n=st.block_n,
                              epilogue="none", interpret=st.interpret)
        caps = pre.reshape(m, cout // st.squash_dim, st.squash_dim)
        _, pull = jax.vjp(squash_reference, caps)
        dpre = pull(dy2.reshape(caps.shape))[0].reshape(m, cout)
    else:
        dpre = dy2

    dbias = jnp.sum(dpre, axis=0).astype(bias.dtype)
    dw = matmul_at_b(p2, dpre, block_m=st.block_m, block_k=st.block_k,
                     block_n=st.block_n, interpret=st.interpret)
    dpatches = matmul_bias_act(
        dpre, jnp.transpose(w2).astype(jnp.float32),
        jnp.zeros((kk,), jnp.float32),
        block_m=st.block_m, block_k=st.block_n, block_n=st.block_k,
        epilogue="none", interpret=st.interpret)
    dx = col2im_patches(dpatches.reshape(b, oh * ow, kk), kh=kh, kw=kw,
                        stride=st.stride, h=h, w=w_hw,
                        block_p=st.block_p, interpret=st.interpret)
    return (dx.astype(x.dtype), dw.reshape(w.shape).astype(w.dtype), dbias)


_conv_core.defvjp(_conv_core_fwd, _conv_core_bwd)


@functools.partial(jax.jit, static_argnames=(
    "stride", "block_m", "block_k", "block_n", "epilogue", "squash_dim",
    "block_p", "interpret"))
def conv2d_im2col(x: jax.Array, w: jax.Array, bias: jax.Array, *,
                  stride: int = 1, block_m: int = 128, block_k: int = 128,
                  block_n: int = 128, epilogue: str = "none",
                  squash_dim: int = 0, block_p: int | None = None,
                  interpret: bool = True) -> jax.Array:
    """VALID conv as im2col matmul: x [B,H,W,Cin], w [KH,KW,Cin,Cout] HWIO.

    Returns ``epilogue(conv(x, w) + bias)`` as [B, OH, OW, Cout].  Block
    shapes come from the ExecutionPlan (see ``kernels/ops.py``).
    Differentiable: carries a custom VJP whose backward runs the Pallas
    ``matmul_at_b`` (dW), ``matmul_bias_act`` (dpatches) and
    ``col2im_patches`` (dx) kernels over the same block tiles.
    """
    st = _ConvStatics(stride=stride, block_m=block_m, block_k=block_k,
                      block_n=block_n, epilogue=epilogue,
                      squash_dim=squash_dim, interpret=interpret,
                      block_p=block_p)
    return _conv_core(st, x, w, bias)
