"""Plan-driven im2col convolution kernels: Conv1 / PrimaryCaps on the MXU.

CapsAcc (Marchisio et al. 2018) and DESCNet run the CapsuleNet conv stack
as im2col matmuls on the same PE array as the capsule operations; CapStore
sizes the on-chip memories from that schedule.  These kernels are the TPU
translation, in two Pallas stages:

  1. ``im2col_patches``: strided patch extraction.  One grid step per batch
     element keeps the (small) input feature map resident in VMEM (the
     paper's data memory) and emits the [OH*OW, KH*KW*C] patch matrix.

  2. ``matmul_bias_act``: blocked [M, K] x [K, N] matmul over the plan's
     ``block_m/k/n`` grid tiles with a fused epilogue (bias + ReLU for
     Conv1, bias + per-capsule squash for PrimaryCaps).  The patch tile is
     the data memory, the weight tile streams (double-buffered), and the
     output block is the accumulator that stays resident across the K grid
     axis -- the paper's accumulator memory.

Ragged final M/N blocks are safe the same way ``caps_votes`` is: Pallas
clamps the tail block identically on the input and output side, and each
(mi, ni) grid cell recomputes its full K reduction, so overlapped rows are
rewritten with identical values.  The K axis is different -- a clamped tail
block would double-count the overlap -- so K is zero-padded up to a
multiple of ``block_k`` instead (zero rows contribute nothing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.capsnet import squash as squash_reference

EPILOGUES = ("none", "relu", "squash")


def _patches_kernel(x_ref, o_ref, *, kh: int, kw: int, stride: int,
                    oh: int, ow: int):
    x = x_ref[0]                                   # [H, W, C]
    c = x.shape[-1]
    taps = []
    for i in range(kh):                            # static unroll: one strided
        for j in range(kw):                        # slice per kernel tap
            taps.append(jax.lax.slice(
                x, (i, j, 0),
                (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, c),
                (stride, stride, 1)))              # [OH, OW, C]
    p = jnp.stack(taps, axis=2)                    # [OH, OW, KH*KW, C]
    o_ref[0] = p.reshape(oh * ow, kh * kw * c)


@functools.partial(jax.jit, static_argnames=("kh", "kw", "stride", "interpret"))
def im2col_patches(x: jax.Array, *, kh: int, kw: int, stride: int = 1,
                   interpret: bool = True) -> jax.Array:
    """x: [B, H, W, C] -> patches [B, OH*OW, KH*KW*C] (VALID padding).

    Patch column order is ``(kh, kw, c)``-major, matching
    ``w.reshape(KH*KW*C, Cout)`` of an HWIO weight tensor.
    """
    b, h, w, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    kernel = functools.partial(_patches_kernel, kh=kh, kw=kw, stride=stride,
                               oh=oh, ow=ow)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, oh * ow, kh * kw * c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, oh * ow, kh * kw * c), x.dtype),
        interpret=interpret,
    )(x)


def _matmul_kernel(p_ref, w_ref, b_ref, o_ref, *, k_steps: int,
                   epilogue: str, squash_dim: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        p_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)

    @pl.when(ki == k_steps - 1)
    def _():
        acc = o_ref[...] + b_ref[...]              # [TM, TN] + [1, TN]
        if epilogue == "relu":
            acc = jnp.maximum(acc, 0.0)
        elif epilogue == "squash":
            tm, tn = acc.shape
            acc = squash_reference(
                acc.reshape(tm, tn // squash_dim, squash_dim)
            ).reshape(tm, tn)
        o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_k", "block_n", "epilogue", "squash_dim", "interpret"))
def matmul_bias_act(p: jax.Array, w: jax.Array, bias: jax.Array, *,
                    block_m: int = 128, block_k: int = 128,
                    block_n: int = 128, epilogue: str = "none",
                    squash_dim: int = 0, interpret: bool = True) -> jax.Array:
    """p: [M, K], w: [K, N], bias: [N] -> epilogue(p @ w + bias): [M, N].

    ``epilogue="squash"`` treats every ``squash_dim`` consecutive output
    channels as one capsule and squashes it in-register before writeback
    (requires ``block_n`` and ``N`` to be multiples of ``squash_dim`` so
    ragged/clamped N tiles stay capsule-aligned).
    """
    m, k = p.shape
    _, n = w.shape
    if epilogue not in EPILOGUES:
        raise ValueError(f"unknown epilogue {epilogue!r}")
    bm = max(1, min(block_m, m))
    bn = max(1, min(block_n, n))
    bk = max(1, min(block_k, k))
    if epilogue == "squash" and (squash_dim < 1 or bn % squash_dim
                                 or n % squash_dim):
        raise ValueError(
            f"squash epilogue needs a positive capsule dim dividing both "
            f"block_n ({bn}) and N ({n}); got squash_dim={squash_dim}")
    if k % bk:                                     # zero-pad K: a clamped tail
        pad = bk - k % bk                          # K-block would double-count
        p = jnp.pad(p, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
        k += pad
    k_steps = k // bk
    kernel = functools.partial(_matmul_kernel, k_steps=k_steps,
                               epilogue=epilogue, squash_dim=squash_dim)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(m, bm), pl.cdiv(n, bn), k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((1, bn), lambda mi, ni, ki: (0, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(p, w, bias.reshape(1, n))


def conv2d_im2col(x: jax.Array, w: jax.Array, bias: jax.Array, *,
                  stride: int = 1, block_m: int = 128, block_k: int = 128,
                  block_n: int = 128, epilogue: str = "none",
                  squash_dim: int = 0, interpret: bool = True) -> jax.Array:
    """VALID conv as im2col matmul: x [B,H,W,Cin], w [KH,KW,Cin,Cout] HWIO.

    Returns ``epilogue(conv(x, w) + bias)`` as [B, OH, OW, Cout].  Block
    shapes come from the ExecutionPlan (see ``kernels/ops.py``).
    """
    b, h, w_hw, cin = x.shape
    kh, kw, _, cout = w.shape
    oh = (h - kh) // stride + 1
    ow = (w_hw - kw) // stride + 1
    patches = im2col_patches(x, kh=kh, kw=kw, stride=stride,
                             interpret=interpret)
    out = matmul_bias_act(
        patches.reshape(b * oh * ow, kh * kw * cin),
        w.reshape(kh * kw * cin, cout), bias,
        block_m=block_m, block_k=block_k, block_n=block_n,
        epilogue=epilogue, squash_dim=squash_dim, interpret=interpret)
    return out.reshape(b, oh, ow, cout).astype(x.dtype)
