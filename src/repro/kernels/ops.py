"""Jit'd public wrappers for the Pallas kernels, driven by one ExecutionPlan.

Every wrapper takes ``interpret`` (default True: CPU-validated execution;
on real TPU pass False).  Block shapes come from an ``ExecutionPlan``
(``repro.core.execplan.compile_plan``) when one is passed; otherwise the
planner pick is computed once per shape and memoized -- wrappers never
re-run the block-shape DSE per invocation.  The oracles live in
``repro.kernels.ref``.
"""

from __future__ import annotations

import functools

import jax

from repro.core.planner import MatmulWorkload, plan_matmul
from repro.kernels import ref
from repro.kernels.caps_votes import caps_votes as _caps_votes
from repro.kernels.conv_im2col import conv2d_im2col as _conv2d
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.routing import routing as _routing
from repro.kernels.squash import squash as _squash


@functools.lru_cache(maxsize=64)            # m folds in the batch: bounded
def planned_conv_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    """CapStore planner pick for a conv's im2col matmul tiles (memoized,
    fp32 elements -- the dtype the conv kernels run in)."""
    plan = plan_matmul(MatmulWorkload(m=m, k=k, n=n, in_bytes=4))
    return plan.block_m, plan.block_k, plan.block_n


def conv2d(x, w, b, *, stride: int = 1, plan_op=None, epilogue: str = "none",
           squash_dim: int = 0, interpret: bool = True):
    """Plan-driven im2col conv: x [B,H,W,Cin], w [KH,KW,Cin,Cout] (HWIO).

    ``plan_op`` is the matching ``OpPlan`` (``plan.op("Conv1")`` /
    ``plan.op("PrimaryCaps")``); without one the planner pick is computed
    once per shape and memoized.  A plan op that fuses the squash
    activation (``plan_op.fuses_squash``) forces the squash epilogue --
    callers only supply ``squash_dim``.
    """
    if plan_op is not None:
        bm, bk, bn = (plan_op.block.block_m, plan_op.block.block_k,
                      plan_op.block.block_n)
        if plan_op.fuses_squash:
            epilogue = "squash"
    else:
        kh, kw, cin, cout = w.shape
        oh = (x.shape[1] - kh) // stride + 1
        ow = (x.shape[2] - kw) // stride + 1
        bm, bk, bn = planned_conv_blocks(x.shape[0] * oh * ow,
                                         kh * kw * cin, cout)
    return _conv2d(x, w, b, stride=stride, block_m=bm, block_k=bk,
                   block_n=bn, epilogue=epilogue, squash_dim=squash_dim,
                   interpret=interpret)


@functools.lru_cache(maxsize=None)
def planned_block_i(num_caps: int, caps_dim: int, out_dim: int) -> int:
    """CapStore planner pick for the caps-votes i-tile (memoized).

    The kernel handles ragged final i-blocks, so the planned block is only
    clamped to ``num_caps`` -- it no longer degenerates to 1 for
    non-power-of-two capsule counts.
    """
    plan = plan_matmul(MatmulWorkload(m=num_caps, k=caps_dim, n=out_dim))
    return max(min(plan.block_m, num_caps), 1)


def caps_votes(u: jax.Array, w: jax.Array, *, plan=None,
               block_i: int | None = None, interpret: bool = True) -> jax.Array:
    """u: [B, I, C], w: [I, N, C] -> [B, I, N]."""
    if block_i is None:
        if plan is not None:
            block_i = plan.op("ClassCaps-FC").block_i
        else:
            block_i = planned_block_i(u.shape[1], u.shape[2], w.shape[1])
    return _caps_votes(u, w, block_i=block_i, interpret=interpret)


def routing(u_hat: jax.Array, *, plan=None, iters: int | None = None,
            num_classes: int | None = None,
            interpret: bool = True) -> jax.Array:
    if iters is None:
        iters = plan.cfg.routing_iters if plan is not None else 3
    if num_classes is None:
        num_classes = plan.cfg.num_classes if plan is not None else 10
    return _routing(u_hat, iters=iters, num_classes=num_classes,
                    interpret=interpret)


def squash(x: jax.Array, *, plan=None, block_rows: int | None = None,
           interpret: bool = True) -> jax.Array:
    if block_rows is None:
        if plan is not None:
            block_rows = plan.op("PrimaryCaps").block_rows
        else:
            block_rows = 1024
    return _squash(x, block_rows=block_rows, interpret=interpret)


def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
            interpret: bool = True) -> jax.Array:
    return _rmsnorm(x, weight, eps=eps, interpret=interpret)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128, interpret=True):
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  scale=scale, block_q=block_q, block_k=block_k,
                  interpret=interpret)


__all__ = ["conv2d", "caps_votes", "routing", "squash", "rmsnorm",
           "flash_attention", "planned_block_i", "planned_conv_blocks", "ref"]
