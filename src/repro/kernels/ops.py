"""Jit'd public wrappers for the Pallas kernels, driven by one ExecutionPlan.

Every wrapper takes ``interpret`` (default True: CPU-validated execution;
on real TPU pass False).  Block shapes come from an ``ExecutionPlan``
(``repro.core.execplan.compile_plan``) when one is passed; otherwise the
planner pick is computed once per shape and memoized -- wrappers never
re-run the block-shape DSE per invocation.  The oracles live in
``repro.kernels.ref``.
"""

from __future__ import annotations

import functools
import warnings

import jax

from repro.core import execplan, faults
from repro.core.planner import VMEM_BYTES, MatmulWorkload, plan_matmul
from repro.kernels import ref
from repro.kernels.caps_votes import caps_votes as _caps_votes
from repro.kernels.conv_im2col import conv2d_im2col as _conv2d
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.primary_routing import \
    primary_caps_routing as _primary_routing
from repro.kernels.routing import routing as _routing
from repro.kernels.squash import squash as _squash
from repro.kernels.votes_routing import \
    res_caps_segment as _res_caps_segment
from repro.kernels.votes_routing import votes_routing as _votes_routing


@functools.lru_cache(maxsize=64)            # m folds in the batch: bounded
def planned_conv_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    """CapStore planner pick for a conv's im2col matmul tiles (memoized,
    fp32 elements -- the dtype the conv kernels run in)."""
    plan = plan_matmul(MatmulWorkload(m=m, k=k, n=n, in_bytes=4))
    return plan.block_m, plan.block_k, plan.block_n


def conv2d(x, w, b, *, stride: int = 1, plan_op=None, epilogue: str = "none",
           squash_dim: int = 0, interpret: bool = True):
    """Plan-driven im2col conv: x [B,H,W,Cin], w [KH,KW,Cin,Cout] (HWIO).

    ``plan_op`` is the matching ``OpPlan`` (``plan.op("Conv1")`` /
    ``plan.op("PrimaryCaps")``); without one the planner pick is computed
    once per shape and memoized.  A plan op that fuses the squash
    activation (``plan_op.fuses_squash``) forces the squash epilogue --
    callers only supply ``squash_dim``.  Differentiable: the kernel's
    custom VJP reuses the same block tiles for the backward matmuls and
    the col2im scatter.
    """
    bp = None
    if plan_op is not None:
        bm, bk, bn = (plan_op.block.block_m, plan_op.block.block_k,
                      plan_op.block.block_n)
        bp = plan_op.patch_rows
        if plan_op.fuses_squash:
            epilogue = "squash"
    else:
        kh, kw, cin, cout = w.shape
        oh = (x.shape[1] - kh) // stride + 1
        ow = (x.shape[2] - kw) // stride + 1
        bm, bk, bn = planned_conv_blocks(x.shape[0] * oh * ow,
                                         kh * kw * cin, cout)
    out = _conv2d(x, w, b, stride=stride, block_m=bm, block_k=bk,
                  block_n=bn, epilogue=epilogue, squash_dim=squash_dim,
                  block_p=bp, interpret=interpret)
    if faults.enabled():                 # chaos-test site; zero cost when off
        out = faults.corrupt_array(faults.SITE_CONV2D, out)
    return out


@functools.lru_cache(maxsize=64)                    # bounded: was unbounded
def planned_block_i(num_caps: int, caps_dim: int, out_dim: int,
                    batch: int = 1, vmem_budget: int = VMEM_BYTES) -> int:
    """CapStore planner pick for the split caps-votes i-tile (memoized).

    Shares ``execplan._votes_block_i_raw``: the planner block is shrunk
    until the kernel's footprint at the REAL ``batch`` fits the budget
    (the old pick ignored batch, so a batched call could exceed the
    footprint the planner guarantees), and only clamped to ``num_caps``
    -- never degenerating to 1 for non-power-of-two capsule counts.
    """
    return execplan._votes_block_i_raw(num_caps, caps_dim, out_dim,
                                       batch, vmem_budget)


def caps_votes(u: jax.Array, w: jax.Array, *, plan=None,
               block_i: int | None = None, interpret: bool = True) -> jax.Array:
    """u: [B, I, C], w: [I, N, C] -> [B, I, N] (split-path oracle/fallback;
    the plan executes the fused ``votes_routing`` instead)."""
    if block_i is None:
        if plan is not None:
            block_i = plan.op(execplan.FUSED_NAME).block_i
        else:
            block_i = planned_block_i(u.shape[1], u.shape[2], w.shape[1],
                                      u.shape[0])
    out = _caps_votes(u, w, block_i=block_i, interpret=interpret)
    if faults.enabled():                 # chaos-test site; zero cost when off
        out = faults.corrupt_array(faults.SITE_CAPS_VOTES, out)
    return out


def routing(u_hat: jax.Array, *, plan=None, iters: int | None = None,
            num_classes: int | None = None,
            interpret: bool = True) -> jax.Array:
    if iters is None:
        iters = plan.cfg.routing_iters if plan is not None else 3
    if num_classes is None:
        num_classes = plan.cfg.num_classes if plan is not None else 10
    out = _routing(u_hat, iters=iters, num_classes=num_classes,
                   interpret=interpret)
    if faults.enabled():                 # chaos-test site; zero cost when off
        out = faults.corrupt_array(faults.SITE_ROUTING, out)
    return out


@functools.lru_cache(maxsize=64)
def planned_votes_routing(num_caps: int, caps_dim: int, jd: int,
                          num_classes: int, iters: int, batch: int,
                          vmem_budget: int = VMEM_BYTES) -> tuple[str, int]:
    """Memoized (mode, block_i) decision for the fused megakernel."""
    sched = execplan.plan_votes_routing(num_caps, caps_dim, jd, num_classes,
                                        batch=batch, iters=iters,
                                        vmem_budget=vmem_budget)
    return sched.mode, sched.block_i


@functools.lru_cache(maxsize=64)            # bounded like the plan caches
def _warn_bwd_fallback_once(msg: str) -> None:
    """Warn once per distinct infeasible-backward schedule (the message
    embeds shapes, budget, and the fallback schedule, so it IS the key);
    repeat calls hit the cache and stay silent."""
    warnings.warn(msg, RuntimeWarning, stacklevel=4)


@functools.lru_cache(maxsize=64)
def planned_votes_routing_bwd(num_caps: int, caps_dim: int, jd: int,
                              num_classes: int, iters: int, batch: int,
                              vmem_budget: int = VMEM_BYTES
                              ) -> tuple[str, int]:
    """Memoized (mode, block_i) decision for the fused BACKWARD kernel
    (independent of the forward's: its scratch is larger)."""
    sched = execplan.plan_votes_routing_bwd(
        num_caps, caps_dim, jd, num_classes, batch=batch, iters=iters,
        vmem_budget=vmem_budget)
    return sched.mode, sched.block_i


def votes_routing(u: jax.Array, w: jax.Array, *, plan=None,
                  op_name: str | None = None,
                  iters: int | None = None, num_classes: int | None = None,
                  mode: str | None = None, block_i: int | None = None,
                  bwd_mode: str | None = None, bwd_block_i: int | None = None,
                  interpret: bool = True) -> jax.Array:
    """u: [B, I, C], w: [I, J*D, C] -> v: [B, J*D]: fused votes + routing
    (u_hat never leaves the chip).  Schedule (``mode``/``block_i``) comes
    from ``plan.op(op_name)`` -- default ``"ClassCaps-Routing"``, the
    final classification layer; deep-stack callers pass the intermediate
    layer's plan-op name (``"ClassCaps-Routing[0]"``, ...) -- or the
    memoized plan decision.

    Differentiable: under ``jax.grad`` the backward schedule
    (``bwd_mode``/``bwd_block_i``) comes from the plan's backward op
    (``compile_plan(train=True)``), falling back to the memoized backward
    plan decision at the plan's VMEM budget -- ``d u_hat`` stays on-chip
    either way.
    """
    if op_name is None:
        op_name = execplan.FUSED_NAME
    if iters is None:
        iters = plan.cfg.routing_iters if plan is not None else 3
    if num_classes is None:
        num_classes = plan.cfg.num_classes if plan is not None else 10
    if mode is None or block_i is None:
        if plan is not None:
            if u.shape[0] > plan.batch:
                # A bigger batch than planned would scale the VMEM scratch
                # past the footprint the plan validated (smaller is safe:
                # the footprint is an upper bound).
                raise ValueError(
                    f"votes_routing: batch {u.shape[0]} exceeds the plan's "
                    f"batch {plan.batch}; recompile the plan for this batch")
            op = plan.op(op_name)
            mode = mode or op.mode
            block_i = block_i or op.block_i
        else:
            pmode, pbi = planned_votes_routing(
                u.shape[1], u.shape[2], w.shape[1], num_classes, iters,
                u.shape[0])
            mode = mode or pmode
            block_i = block_i or pbi
    if bwd_mode is None or bwd_block_i is None:
        budget = plan.vmem_budget if plan is not None else VMEM_BYTES
        bwd_op = None
        if plan is not None and plan.train:
            bwd_op = plan.op(op_name + execplan.BWD_SUFFIX)
        if bwd_op is not None:
            bwd_mode = bwd_mode or bwd_op.mode
            bwd_block_i = bwd_block_i or bwd_op.block_i
        else:
            try:
                pbmode, pbbi = planned_votes_routing_bwd(
                    u.shape[1], u.shape[2], w.shape[1], num_classes, iters,
                    u.shape[0], budget)
            except execplan.PlanError as err:
                # Forward-only callers must not fail on backward planning;
                # a caller who then differentiates anyway gets the forward
                # schedule (numerically correct, footprint model exceeded)
                # -- warned ONCE per schedule so the silent-footprint case
                # is at least visible.
                _warn_bwd_fallback_once(
                    f"votes_routing: no feasible backward schedule "
                    f"under the {budget} B VMEM budget ({err}); the "
                    f"forward runs fine, but differentiating this call "
                    f"will reuse the forward schedule "
                    f"(mode={mode!r}, block_i={block_i}) with a "
                    f"backward VMEM footprint the plan never validated")
                pbmode, pbbi = mode, block_i
            bwd_mode = bwd_mode or pbmode
            bwd_block_i = bwd_block_i or pbbi
    out = _votes_routing(u, w, iters=iters, num_classes=num_classes,
                         mode=mode, block_i=block_i, bwd_mode=bwd_mode,
                         bwd_block_i=bwd_block_i, interpret=interpret)
    if faults.enabled():                 # chaos-test site; zero cost when off
        out = faults.corrupt_array(faults.SITE_VOTES_ROUTING, out)
    return out


@functools.lru_cache(maxsize=64)
def planned_primary_routing(p_pos: int, k_in: int, n_ch: int, num_caps: int,
                            caps_dim: int, jd: int, num_classes: int,
                            iters: int, batch: int,
                            vmem_budget: int = VMEM_BYTES
                            ) -> tuple[str, int, int, tuple[int, int, int]]:
    """Memoized (mode, block_i, block_k, conv tiles) decision for the
    pipelined PrimaryCaps->ClassCaps megakernel."""
    sched = execplan.plan_primary_routing(
        p_pos, k_in, n_ch, num_caps, caps_dim, jd, num_classes,
        batch=batch, iters=iters, vmem_budget=vmem_budget)
    return (sched.mode, sched.block_i, sched.block_k,
            (sched.block.block_m, sched.block.block_k, sched.block.block_n))


def primary_routing(x: jax.Array, w_pc: jax.Array, b_pc: jax.Array,
                    w_cc: jax.Array, *, plan=None, stride: int | None = None,
                    iters: int | None = None, num_classes: int | None = None,
                    routing_op_name: str | None = None,
                    mode: str | None = None, block_i: int | None = None,
                    block_k: int | None = None, bwd_mode: str | None = None,
                    bwd_block_i: int | None = None,
                    interpret: bool = True) -> jax.Array:
    """Pipelined PrimaryCaps conv + votes/routing as ONE kernel: x is the
    Conv1 output [B, H, W, Cin], w_pc/b_pc the PrimaryCaps conv params,
    w_cc [I, J*D, C] the routing weights -> v [B, J*D].  The inter-layer
    activation u streams from VMEM scratch, never HBM.

    Schedule (``mode``/``block_i``/``block_k`` and the backward-replay
    conv tiles) comes from ``plan.op("PrimaryCaps-Routing")``
    (``compile_plan(pipeline=True)``) or the memoized plan decision.
    Differentiable: the routing-backward schedule resolves exactly like
    ``votes_routing``'s (the pipelined VJP composes the per-op backward
    kernels, so the plan's backward OpPlans apply unchanged).
    """
    if routing_op_name is None:
        routing_op_name = execplan.FUSED_NAME
    if stride is None:
        stride = plan.cfg.pc_stride if plan is not None else 2
    if iters is None:
        iters = plan.cfg.routing_iters if plan is not None else 3
    if num_classes is None:
        num_classes = plan.cfg.num_classes if plan is not None else 10
    num_caps, jd, caps_dim = w_cc.shape
    kh, kw, cin, n_ch = w_pc.shape
    oh = (x.shape[1] - kh) // stride + 1
    ow = (x.shape[2] - kw) // stride + 1
    patch_rows = None
    if mode is None or block_i is None or block_k is None:
        if plan is not None:
            if x.shape[0] > plan.batch:
                raise ValueError(
                    f"primary_routing: batch {x.shape[0]} exceeds the "
                    f"plan's batch {plan.batch}; recompile the plan for "
                    f"this batch")
            op = plan.op(execplan.PIPE_NAME)
            mode = mode or op.mode
            block_i = block_i or op.block_i
            block_k = block_k or op.block_k
            patch_rows = op.patch_rows
            cb = (op.block.block_m, op.block.block_k, op.block.block_n)
        else:
            pmode, pbi, pbk, cb = planned_primary_routing(
                oh * ow, kh * kw * cin, n_ch, num_caps, caps_dim, jd,
                num_classes, iters, x.shape[0])
            mode = mode or pmode
            block_i = block_i or pbi
            block_k = block_k or pbk
    else:
        cb = planned_conv_blocks(x.shape[0] * oh * ow, kh * kw * cin, n_ch)
    if bwd_mode is None or bwd_block_i is None:
        budget = plan.vmem_budget if plan is not None else VMEM_BYTES
        bwd_op = None
        if plan is not None and plan.train:
            bwd_op = plan.op(routing_op_name + execplan.BWD_SUFFIX)
        if bwd_op is not None:
            bwd_mode = bwd_mode or bwd_op.mode
            bwd_block_i = bwd_block_i or bwd_op.block_i
        else:
            try:
                pbmode, pbbi = planned_votes_routing_bwd(
                    num_caps, caps_dim, jd, num_classes, iters, x.shape[0],
                    budget)
            except execplan.PlanError as err:
                _warn_bwd_fallback_once(
                    f"primary_routing: no feasible routing-backward "
                    f"schedule under the {budget} B VMEM budget ({err}); "
                    f"the forward runs fine, but differentiating this "
                    f"call will reuse the forward schedule "
                    f"(mode={mode!r}, block_i={block_i}) with a backward "
                    f"VMEM footprint the plan never validated")
                pbmode, pbbi = mode, block_i
            bwd_mode = bwd_mode or pbmode
            bwd_block_i = bwd_block_i or pbbi
    out = _primary_routing(
        x, w_pc, b_pc, w_cc, stride=stride, iters=iters,
        num_classes=num_classes, mode=mode, block_i=block_i,
        block_k=block_k, bwd_mode=bwd_mode, bwd_block_i=bwd_block_i,
        conv_block_m=cb[0], conv_block_k=cb[1], conv_block_n=cb[2],
        block_p=patch_rows, interpret=interpret)
    if faults.enabled():                 # chaos-test site; zero cost when off
        out = faults.corrupt_array(faults.SITE_PRIMARY_ROUTING, out)
    return out


def _layer_schedule(lay, batch: int, plan) -> tuple[int, int, str, int,
                                                    str, int]:
    """Resolve one routing layer's (iters, j, mode, block_i, bwd_mode,
    bwd_block_i) kernel statics from the plan's per-layer OpPlans (or the
    memoized plan decision), with the same backward-fallback semantics as
    ``votes_routing``."""
    if plan is not None:
        op = plan.op(lay.name)
        mode, block_i = op.mode, op.block_i
    else:
        mode, block_i = planned_votes_routing(
            lay.in_caps, lay.in_dim, lay.jd, lay.num_caps, lay.iters, batch)
    budget = plan.vmem_budget if plan is not None else VMEM_BYTES
    if plan is not None and plan.train:
        bwd_op = plan.op(lay.name + execplan.BWD_SUFFIX)
        bwd_mode, bwd_block_i = bwd_op.mode, bwd_op.block_i
    else:
        try:
            bwd_mode, bwd_block_i = planned_votes_routing_bwd(
                lay.in_caps, lay.in_dim, lay.jd, lay.num_caps, lay.iters,
                batch, budget)
        except execplan.PlanError as err:
            _warn_bwd_fallback_once(
                f"res_caps_segment[{lay.name}]: no feasible backward "
                f"schedule under the {budget} B VMEM budget ({err}); "
                f"differentiating this call will reuse the forward "
                f"schedule (mode={mode!r}, block_i={block_i}) with a "
                f"backward VMEM footprint the plan never validated")
            bwd_mode, bwd_block_i = mode, block_i
    return (lay.iters, lay.num_caps, mode, block_i, bwd_mode, bwd_block_i)


def res_caps_segment(x: jax.Array, ws, pairs, *, plan=None,
                     interpret: bool = True) -> jax.Array:
    """Reversible residual capsule segment: x [B, I, C] through a maximal
    run of ``ResCapsBlock`` coupling pairs -> [B, I, C].

    ``pairs`` is a tuple of ``(f_layer, g_layer)`` ``RoutingLayer`` pairs
    (from ``CapsNetConfig.routing_stack()``); ``ws`` the matching flat
    per-half weights ``[in_caps, jd, in_dim]``.  Each half runs the fused
    votes+routing megakernel with a residual-add epilogue, scheduled by
    its own plan op.  Differentiable with NO saved activations: the
    backward inverts the coupling block-by-block from the segment output
    (see ``kernels.votes_routing._res_segment_bwd``).
    """
    if plan is not None and x.shape[0] > plan.batch:
        raise ValueError(
            f"res_caps_segment: batch {x.shape[0]} exceeds the plan's "
            f"batch {plan.batch}; recompile the plan for this batch")
    blocks = tuple(
        (lf.num_caps, _layer_schedule(lf, x.shape[0], plan),
         _layer_schedule(lg, x.shape[0], plan)) for lf, lg in pairs)
    out = _res_caps_segment(x, tuple(ws), blocks=blocks,
                            interpret=interpret)
    if faults.enabled():                 # chaos-test site; zero cost when off
        out = faults.corrupt_array(faults.SITE_RES_CAPS_SEGMENT, out)
    return out


def squash(x: jax.Array, *, plan=None, block_rows: int | None = None,
           interpret: bool = True) -> jax.Array:
    if block_rows is None:
        if plan is not None:
            block_rows = plan.op("PrimaryCaps").block_rows
        else:
            block_rows = 1024
    out = _squash(x, block_rows=block_rows, interpret=interpret)
    if faults.enabled():                 # chaos-test site; zero cost when off
        out = faults.corrupt_array(faults.SITE_SQUASH, out)
    return out


def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
            interpret: bool = True) -> jax.Array:
    out = _rmsnorm(x, weight, eps=eps, interpret=interpret)
    if faults.enabled():                 # chaos-test site; zero cost when off
        out = faults.corrupt_array(faults.SITE_RMSNORM, out)
    return out


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128, interpret=True):
    out = _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                 scale=scale, block_q=block_q, block_k=block_k,
                 interpret=interpret)
    if faults.enabled():                 # chaos-test site; zero cost when off
        out = faults.corrupt_array(faults.SITE_FLASH_ATTENTION, out)
    return out


__all__ = ["conv2d", "caps_votes", "routing", "votes_routing",
           "primary_routing", "res_caps_segment", "squash", "rmsnorm",
           "flash_attention",
           "planned_block_i", "planned_conv_blocks",
           "planned_votes_routing", "planned_votes_routing_bwd",
           "planned_primary_routing", "ref"]
