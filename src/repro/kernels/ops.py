"""Jit'd public wrappers for the Pallas kernels, with CapStore-planned
default block shapes.

Every wrapper takes ``interpret`` (default True: CPU-validated execution;
on real TPU pass False) and falls back to documented planner defaults for
block sizes.  The oracles live in ``repro.kernels.ref``.
"""

from __future__ import annotations

import jax

from repro.core.planner import MatmulWorkload, plan_matmul
from repro.kernels import ref
from repro.kernels.caps_votes import caps_votes as _caps_votes
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.routing import routing as _routing
from repro.kernels.squash import squash as _squash


def planned_block_i(num_caps: int, caps_dim: int, out_dim: int) -> int:
    """CapStore planner pick for the caps-votes i-tile."""
    plan = plan_matmul(MatmulWorkload(m=num_caps, k=caps_dim, n=out_dim))
    bi = max(min(plan.block_m, num_caps), 8)
    while num_caps % bi:
        bi //= 2
    return max(bi, 1)


def caps_votes(u: jax.Array, w: jax.Array, *, block_i: int | None = None,
               interpret: bool = True) -> jax.Array:
    """u: [B, I, C], w: [I, N, C] -> [B, I, N]."""
    if block_i is None:
        block_i = planned_block_i(u.shape[1], u.shape[2], w.shape[1])
    return _caps_votes(u, w, block_i=block_i, interpret=interpret)


def routing(u_hat: jax.Array, *, iters: int = 3, num_classes: int = 10,
            interpret: bool = True) -> jax.Array:
    return _routing(u_hat, iters=iters, num_classes=num_classes,
                    interpret=interpret)


def squash(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    return _squash(x, interpret=interpret)


def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
            interpret: bool = True) -> jax.Array:
    return _rmsnorm(x, weight, eps=eps, interpret=interpret)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128, interpret=True):
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  scale=scale, block_q=block_q, block_k=block_k,
                  interpret=interpret)


__all__ = ["caps_votes", "routing", "squash", "rmsnorm", "flash_attention",
           "planned_block_i", "ref"]
