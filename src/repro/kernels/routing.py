"""Fused routing-by-agreement kernel (legacy split-path fallback/oracle).

The paper's key memory observation: during the routing iterations *no value
leaves the chip* (Sec. 3.1 -- "all the values that have to be saved during
the routing-by-agreement are stored on-chip").  The TPU translation: run
ALL routing iterations inside one ``pallas_call`` so the routing state
(logits b, couplings c, candidate outputs s/v) lives on-chip for the whole
loop, and only the votes (read once) and the final v (written once) cross
HBM.  The plan-driven path goes further: ``kernels/votes_routing.py``
fuses the vote computation in as well, so the votes themselves never
round-trip through HBM -- this kernel survives as the split-path
oracle/fallback consuming a materialized ``u_hat``.

VMEM budget per grid step (one batch element):
    votes  [I, J*D]  : the "accumulator memory" contents (fp32)
    b      [I, J]    : routing logits     (loop carry)
    v      [J*D]     : squashed output    (stored as [1, J*D])

For CapsuleNet-MNIST (I=1152, J=10, D=16) that is ~0.8 MiB -- comfortably
inside the 16 MiB VMEM envelope the planner manages.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.capsnet import squash


def _routing_kernel(uhat_ref, o_ref, *, iters: int, j: int, d: int):
    uh = uhat_ref[0].astype(jnp.float32)                  # [I, J*D]
    i_dim = uh.shape[0]
    uh4 = uh.reshape(i_dim, j, d)

    def iteration(_, b):
        c = jax.nn.softmax(b, axis=1)                     # [I, J]
        s = jnp.einsum("ij,ijd->jd", c, uh4)              # Sum
        v = squash(s)                                     # Squash
        return b + jnp.einsum("ijd,jd->ij", uh4, v)       # Update(+Sum)

    b = jax.lax.fori_loop(0, iters, iteration,
                          jnp.zeros((i_dim, j), jnp.float32))
    c = jax.nn.softmax(b, axis=1)
    v = squash(jnp.einsum("ij,ijd->jd", c, uh4))
    o_ref[...] = v.reshape(1, j * d).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("iters", "num_classes", "interpret"))
def routing(u_hat: jax.Array, *, iters: int = 3, num_classes: int = 10,
            interpret: bool = True) -> jax.Array:
    """u_hat: [B, I, J*D] -> v: [B, J*D]; fused dynamic routing."""
    bsz, i_dim, jd = u_hat.shape
    j = num_classes
    if jd % j:
        raise ValueError(f"votes dim {jd} not divisible by classes {j}")
    d = jd // j
    kernel = functools.partial(_routing_kernel, iters=iters, j=j, d=d)
    return pl.pallas_call(
        kernel,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, i_dim, jd), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, jd), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, jd), u_hat.dtype),
        interpret=interpret,
    )(u_hat)
