"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the ground truth the kernels are validated against
(tests sweep shapes/dtypes and assert_allclose kernel-vs-ref).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def squash(s: jax.Array, axis: int = -1, eps: float = 1e-7) -> jax.Array:
    sq = jnp.sum(jnp.square(s), axis=axis, keepdims=True)
    return (sq / (1.0 + sq)) * s * jax.lax.rsqrt(sq + eps)


def caps_votes(u: jax.Array, w: jax.Array) -> jax.Array:
    """u: [B, I, C], w: [I, JD, C] -> votes [B, I, JD] (JD = classes*dim)."""
    return jnp.einsum("bic,inc->bin", u, w)


def routing(u_hat: jax.Array, iters: int) -> jax.Array:
    """u_hat: [B, I, J, D] -> v: [B, J, D] (inference-mode dynamic routing)."""
    b = jnp.zeros(u_hat.shape[:3], u_hat.dtype)
    for _ in range(iters):
        c = jax.nn.softmax(b, axis=2)
        v = squash(jnp.einsum("bij,bijd->bjd", c, u_hat))
        b = b + jnp.einsum("bijd,bjd->bij", u_hat, v)
    c = jax.nn.softmax(b, axis=2)
    return squash(jnp.einsum("bij,bijd->bjd", c, u_hat))


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + weight.astype(jnp.float32))
            ).astype(dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              softcap: float | None = None, scale: float | None = None
              ) -> jax.Array:
    """q: [B, H, Tq, D], k/v: [B, H, Tk, D] -> [B, H, Tq, D] (fp32 softmax).

    ``window`` is a sliding-window radius: query t attends to keys in
    (t - window, t] (causal) -- Gemma-style local attention.
    """
    d = q.shape[-1]
    scale = (d ** -0.5) if scale is None else scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    tq, tk = q.shape[2], k.shape[2]
    qi = jnp.arange(tq)[:, None] + (tk - tq)     # align ends (decode-friendly)
    ki = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
