"""Capsule vote kernel: u_hat[b, i, n] = sum_c W[i, n, c] * u[b, i, c].

This is the ClassCaps-FC operation the paper profiles as the *memory-bound*
stage (its weights have zero reuse -- every W element is read exactly once
per inference).  The CapStore insight on TPU: the only thing tiling can do
for a reuse-free operand is (1) stream it through VMEM in blocks big enough
to saturate HBM (the paper's weight-memory prefetch buffer) and (2) keep
the *reused* operands (u: the data memory, accumulator tile) resident.

The plan-driven path no longer materializes u_hat at all --
``kernels/votes_routing.py`` fuses this operation into the routing loop.
This kernel survives as the split-path oracle/fallback.

Block layout per grid step (i-block `bi` of size TI):
    data memory   : u tile   [B, TI, C]      (reused across all N outputs)
    weight memory : W tile   [TI, N, C]      (streamed, read once)
    accumulator   : out tile [B, TI, N]      (written once)

The i-dimension is the only grid axis -> "arbitrary" semantics, a pure
streaming pass, exactly the paper's CC-FC dataflow.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _votes_kernel(u_ref, w_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)        # [B, TI, C]
    w = w_ref[...].astype(jnp.float32)        # [TI, N, C]
    o_ref[...] = jnp.einsum(
        "bic,inc->bin", u, w,
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_i", "interpret"))
def caps_votes(u: jax.Array, w: jax.Array, *, block_i: int = 128,
               interpret: bool = True) -> jax.Array:
    """u: [B, I, C], w: [I, N, C] -> [B, I, N].

    ``block_i`` is the CapStore-planned i-tile (see
    ``repro.core.execplan``).  I need NOT be divisible by block_i: the grid
    is ``cdiv(I, block_i)`` and the final ragged block is safe because each
    output row depends only on the same input row (Pallas clamps/masks the
    tail block identically on the input and output side).
    """
    b, i, c = u.shape
    _, n, _ = w.shape
    block_i = max(1, min(block_i, i))
    grid = (pl.cdiv(i, block_i),)
    return pl.pallas_call(
        _votes_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, block_i, c), lambda bi: (0, bi, 0)),
            pl.BlockSpec((block_i, n, c), lambda bi: (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_i, n), lambda bi: (0, bi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, i, n), u.dtype),
        interpret=interpret,
    )(u, w)
