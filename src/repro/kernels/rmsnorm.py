"""Fused RMSNorm kernel (LM-side hot spot).

y = x * rsqrt(mean(x^2) + eps) * (1 + w); fp32 statistics, blocked over
rows.  Fusing norm+scale keeps the activation tile in VMEM for a single
HBM round-trip (vs. three for the unfused version) -- the CapStore
"minimize off-chip accesses" policy applied to normalization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * (1.0 + w)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm(x: jax.Array, weight: jax.Array, *, block_rows: int = 256,
            eps: float = 1e-6, interpret: bool = True) -> jax.Array:
    """x: [..., D], weight: [D] -> same shape as x."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    while rows % br:
        br //= 2
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, weight)
    return out.reshape(orig_shape)
