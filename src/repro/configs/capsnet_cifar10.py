"""CapsuleNet on CIFAR-10: a DEEP residual capsule stack.

The 32x32x3 variant the CapsuleNet literature scales to (Sabour et al.
report 10.6% CIFAR-10 error with an ensemble; MoCapsNet-style residual
routing blocks are what make *depth* affordable).  Three reversible
``ResCapsBlock``s sit between PrimaryCaps and ClassCaps, so this config
exercises the layer-graph plan compiler: per-layer fused megakernel ops,
per-instance PMU phases, and the flat-in-depth reversible backward.
Selectable via ``--arch capsnet-cifar10``.
"""

from repro.core.capsnet import CapsNetConfig, ResCapsBlock


def config() -> CapsNetConfig:
    return CapsNetConfig(
        image_hw=32,
        in_channels=3,
        conv1_channels=256,
        conv1_kernel=9,
        pc_kernel=9,
        pc_stride=2,
        num_primary_groups=32,
        primary_dim=8,
        num_classes=10,
        class_dim=16,
        decoder_hidden=(512, 1024),
        caps_layers=(ResCapsBlock(), ResCapsBlock(), ResCapsBlock()),
    )


def smoke_config() -> CapsNetConfig:
    """Same topology (3 reversible blocks), toy widths for CI."""
    return CapsNetConfig(
        image_hw=16,
        in_channels=3,
        conv1_channels=32,
        conv1_kernel=5,
        pc_kernel=3,
        pc_stride=2,
        num_primary_groups=4,
        primary_dim=4,
        class_dim=8,
        decoder_hidden=(32, 64),
        caps_layers=(ResCapsBlock(), ResCapsBlock(), ResCapsBlock()),
    )
