"""gemma-7b [dense]: 28L d_model=3072 16H (GQA kv=16) d_ff=24576
vocab=256000 -- GeGLU, head_dim=256.  [arXiv:2403.08295; hf]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense",
        d_model=3072, num_heads=16, num_kv_heads=16, head_dim=256,
        d_ff=24576, vocab_size=256000,
        pattern=("global",), repeats=28,
        mlp_act="gelu",
        tie_embeddings=True, scale_embeddings=True,
        rope_theta=10000.0,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b-smoke", family="dense",
        d_model=48, num_heads=4, num_kv_heads=4, head_dim=32,  # dh > d/H
        d_ff=192, vocab_size=512,
        pattern=("global",), repeats=2,
        mlp_act="gelu", tie_embeddings=True, scale_embeddings=True,
    ).validate()
