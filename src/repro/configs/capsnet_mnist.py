"""The paper's own workload: CapsuleNet on MNIST (Sabour et al. 2017),
profiled by CapStore.  Not part of the LM pool -- selectable via
``--arch capsnet-mnist`` in the quickstart / benchmarks.
"""

from repro.core.capsnet import CapsNetConfig


def config() -> CapsNetConfig:
    return CapsNetConfig()


def smoke_config() -> CapsNetConfig:
    return CapsNetConfig(image_hw=14, conv1_channels=32,
                         conv1_kernel=5, pc_kernel=3,
                         num_primary_groups=4, primary_dim=4,
                         class_dim=8, decoder_hidden=(32, 64))
