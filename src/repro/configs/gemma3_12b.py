"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 -- 5:1 local:global, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense",
        d_model=3840, num_heads=16, num_kv_heads=8, head_dim=256,
        d_ff=15360, vocab_size=262144,
        pattern=("local",) * 5 + ("global",), repeats=8,   # 48 layers
        sliding_window=1024,
        attn_logit_softcap=None, final_logit_softcap=None,  # dropped in v3
        query_scale=256.0 ** -0.5,
        mlp_act="gelu", use_post_norms=True,
        tie_embeddings=True, scale_embeddings=True,
        rope_theta=1_000_000.0,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b-smoke", family="dense",
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        pattern=("local",) * 5 + ("global",), repeats=1,
        sliding_window=8,
        query_scale=16.0 ** -0.5,
        mlp_act="gelu", use_post_norms=True,
        tie_embeddings=True, scale_embeddings=True,
        rope_theta=1_000_000.0,
    ).validate()
