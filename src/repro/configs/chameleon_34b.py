"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 -- early-fusion: images are VQ-VAE tokens in the SAME
vocabulary, so the backbone is a plain token transformer (the VQ tokenizer
is the stubbed frontend).  [arXiv:2405.09818; unverified]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
        d_ff=22016, vocab_size=65536,
        pattern=("global",), repeats=48,
        mlp_act="silu", tie_embeddings=False,
        rope_theta=10000.0,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b-smoke", family="vlm",
        d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=160, vocab_size=512,
        pattern=("global",), repeats=2,
        mlp_act="silu", tie_embeddings=False,
    ).validate()
