"""CapsuleNet on SVHN: a mixed plain + residual capsule stack.

Street-view digits at CIFAR geometry (32x32x3).  The stack leads with a
PLAIN bottleneck layer (64 capsules x 8D -- routing compresses the
primary grid before depth is added) and follows with two reversible
``ResCapsBlock``s, so the graph compiler's plain-then-residual walk, the
PrimaryCaps pipeline eligibility (first layer non-residual), and the
mixed saved/reversible activation accounting all get a named workload.
Selectable via ``--arch capsnet-svhn``.
"""

from repro.core.capsnet import CapsLayerSpec, CapsNetConfig, ResCapsBlock


def config() -> CapsNetConfig:
    return CapsNetConfig(
        image_hw=32,
        in_channels=3,
        conv1_channels=256,
        conv1_kernel=9,
        pc_kernel=9,
        pc_stride=2,
        num_primary_groups=32,
        primary_dim=8,
        num_classes=10,
        class_dim=16,
        decoder_hidden=(512, 1024),
        caps_layers=(CapsLayerSpec(num_caps=64, caps_dim=8),
                     ResCapsBlock(), ResCapsBlock()),
    )


def smoke_config() -> CapsNetConfig:
    """Same topology (plain bottleneck + 2 blocks), toy widths for CI."""
    return CapsNetConfig(
        image_hw=16,
        in_channels=3,
        conv1_channels=32,
        conv1_kernel=5,
        pc_kernel=3,
        pc_stride=2,
        num_primary_groups=4,
        primary_dim=4,
        class_dim=8,
        decoder_hidden=(32, 64),
        caps_layers=(CapsLayerSpec(num_caps=16, caps_dim=4),
                     ResCapsBlock(), ResCapsBlock()),
    )
