"""mamba2-370m [ssm]: 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128 -- SSD (state-space duality).  [arXiv:2405.21060; unverified]
"""

from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        d_model=1024, num_heads=1, num_kv_heads=1, head_dim=1,  # attn-free
        d_ff=0, vocab_size=50280,
        pattern=("mamba",), repeats=48,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=128),
        tie_embeddings=True,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-smoke", family="ssm",
        d_model=64, num_heads=1, num_kv_heads=1, head_dim=1,
        d_ff=0, vocab_size=256,
        pattern=("mamba",), repeats=3,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk=8),
        tie_embeddings=True,
    ).validate()
