from repro.configs.registry import (  # noqa: F401
    LM_ARCHS,
    canonical,
    get_config,
    get_smoke_config,
    list_archs,
)
