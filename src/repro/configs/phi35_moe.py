"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8)
d_ff=6400/expert, vocab=32064, MoE 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""

from repro.models.config import MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe",
        d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
        d_ff=6400, vocab_size=32064,
        pattern=("global",), repeats=32,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400),
        mlp_act="silu", tie_embeddings=False,
        rope_theta=10000.0,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-smoke", family="moe",
        d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=96, vocab_size=256,
        pattern=("global",), repeats=2,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=96),
        mlp_act="silu", tie_embeddings=False,
    ).validate()
