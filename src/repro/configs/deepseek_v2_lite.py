"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H, MLA kv_lora=512,
d_ff_expert=1408, vocab=102400, 2 shared + 64 routed experts top-6;
first layer uses a dense MLP (d_ff=10944).  [arXiv:2405.04434; hf]
"""

from repro.models.config import MLAConfig, MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
        d_ff=10944,                              # dense MLP of layer 0
        vocab_size=102400,
        prefix=("global",),                      # dense first layer
        pattern=("global",), repeats=26,         # 27 layers total
        mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                      qk_rope_head_dim=64, v_head_dim=128),
        moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                      num_shared_experts=2),
        moe_in_prefix=False,
        mlp_act="silu", tie_embeddings=False,
        rope_theta=10000.0,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe",
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=160, vocab_size=256,
        prefix=("global",),
        pattern=("global",), repeats=2,
        mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=3, d_ff_expert=48,
                      num_shared_experts=2),
        moe_in_prefix=False,
        mlp_act="silu", tie_embeddings=False,
    ).validate()
