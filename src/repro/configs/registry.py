"""Architecture registry: ``--arch <id>`` -> config module."""

from __future__ import annotations

import importlib

_MODULES = {
    "gemma2-9b": "gemma2_9b",
    "gemma3-12b": "gemma3_12b",
    "granite-3-2b": "granite_3_2b",
    "gemma-7b": "gemma_7b",
    "mamba2-370m": "mamba2_370m",
    "hubert-xlarge": "hubert_xlarge",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "chameleon-34b": "chameleon_34b",
    "zamba2-1.2b": "zamba2_1p2b",
    "capsnet-mnist": "capsnet_mnist",
    "capsnet-cifar10": "capsnet_cifar10",
    "capsnet-svhn": "capsnet_svhn",
}

# Short aliases accepted on the CLI (underscore spellings included, so
# ``--arch capsnet_mnist`` works the way the module files are named).
_ALIASES = {
    "phi3.5-moe": "phi3.5-moe-42b-a6.6b",
    "deepseek-v2-lite": "deepseek-v2-lite-16b",
    "capsnet": "capsnet-mnist",
    "capsnet_mnist": "capsnet-mnist",
    "capsnet_cifar10": "capsnet-cifar10",
    "capsnet_svhn": "capsnet-svhn",
}

# The LM benchmark pool: every arch that is not a CapsuleNet workload.
LM_ARCHS = [a for a in _MODULES if not a.startswith("capsnet")]

CAPSNET_ARCHS = [a for a in _MODULES if a.startswith("capsnet")]


def canonical(name: str) -> str:
    return _ALIASES.get(name, name)


def _module(name: str):
    name = canonical(name)
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str):
    return _module(name).config()


def get_smoke_config(name: str):
    return _module(name).smoke_config()


def list_archs() -> list[str]:
    return list(_MODULES)
