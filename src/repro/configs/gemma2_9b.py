"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 -- local+global alternating, logit softcaps.
[arXiv:2408.00118; hf]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b", family="dense",
        d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
        d_ff=14336, vocab_size=256000,
        pattern=("local", "global"), repeats=21,          # 42 layers
        sliding_window=4096,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        query_scale=224.0 ** -0.5,                         # d_model / heads
        mlp_act="gelu", use_post_norms=True,
        tie_embeddings=True, scale_embeddings=True,
        rope_theta=10000.0,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b-smoke", family="dense",
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        pattern=("local", "global"), repeats=2,
        sliding_window=8,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        query_scale=16.0 ** -0.5,
        mlp_act="gelu", use_post_norms=True,
        tie_embeddings=True, scale_embeddings=True,
    ).validate()
