"""hubert-xlarge [audio]: 48L d_model=1280 16H d_ff=5120 vocab=504
-- encoder-only (bidirectional), masked-frame cluster prediction.
The conv waveform frontend is a STUB per the assignment: ``input_specs``
provides precomputed 512-d frame embeddings.  [arXiv:2106.07447; unverified]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        d_model=1280, num_heads=16, num_kv_heads=16, head_dim=80,
        d_ff=5120, vocab_size=504,            # k-means codebook targets
        pattern=("bidir",), repeats=48,
        causal=False, mlp_act="gelu",
        tie_embeddings=False,
        frontend="audio_frames", frontend_dim=512,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", family="audio",
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=32,
        pattern=("bidir",), repeats=2,
        causal=False, mlp_act="gelu",
        tie_embeddings=False,
        frontend="audio_frames", frontend_dim=24,
    ).validate()
