"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 -- GQA.  [hf:ibm-granite/granite-3.0-2b-base; hf]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b", family="dense",
        d_model=2048, num_heads=32, num_kv_heads=8, head_dim=64,
        d_ff=8192, vocab_size=49155,
        pattern=("global",), repeats=40,
        mlp_act="silu", tie_embeddings=True,
        rope_theta=10000.0,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b-smoke", family="dense",
        d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=128, vocab_size=515,            # deliberately non-power-of-two
        pattern=("global",), repeats=3,
        mlp_act="silu", tie_embeddings=True,
    ).validate()
