"""zamba2-1.2b [hybrid]: 38 Mamba2 layers (d_model=2048, ssm_state=64)
+ one weight-SHARED attention+MLP block (32H kv=32, d_ff=8192) applied
every 6 Mamba layers.  vocab=32000.  [arXiv:2411.15242; hf]

Stack: (6x mamba + shared_attn) x 6 + 2x mamba = 38 mamba applications,
6 shared-block applications (one set of attention weights).
"""

from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
        d_ff=8192, vocab_size=32000,
        pattern=("mamba",) * 6 + ("shared_attn",), repeats=6,
        suffix=("mamba", "mamba"),
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=128),
        mlp_act="gelu", tie_embeddings=True,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b-smoke", family="hybrid",
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        pattern=("mamba", "mamba", "shared_attn"), repeats=2,
        suffix=("mamba",),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk=8),
        mlp_act="gelu", tie_embeddings=True,
    ).validate()
