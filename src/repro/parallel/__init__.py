from repro.parallel.sharding import (  # noqa: F401
    MeshRules,
    ShardingCtx,
    make_rules,
    param_pspecs,
)
