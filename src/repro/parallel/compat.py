"""Version-compat shims for jax APIs that moved between releases.

The repo targets the jax documented in CI; these helpers keep it running on
the adjacent releases too:

  * ``jax.shard_map`` (new) vs ``jax.experimental.shard_map.shard_map``
    (<= 0.4.x), whose replication-check kwarg also renamed
    ``check_rep`` -> ``check_vma``;
  * ``jax.make_mesh(..., axis_types=...)`` / ``jax.sharding.AxisType``,
    absent on <= 0.4.x where every mesh axis is implicitly Auto.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking disabled, any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` where AxisType exists, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return (axis_type.Auto,) * n if axis_type is not None else None


def make_mesh(shape, axis_names):
    """An all-Auto mesh on any jax version."""
    types = auto_axis_types(len(shape))
    if types is not None:
        return jax.make_mesh(shape, axis_names, axis_types=types)
    return jax.make_mesh(shape, axis_names)
