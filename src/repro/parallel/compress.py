"""Gradient compression: int8 quantization with error feedback.

Distributed-optimization trick for the DP all-reduce (the single biggest
collective in the train step: 2 x 4 bytes x N params).  Each data-parallel
worker quantizes its local gradient to int8 with a per-tensor scale,
all-reduces the int8 payload (4x fewer bytes on the wire; the inter-pod
links carry exactly this traffic), dequantizes, and keeps the quantization
residual locally -- error feedback makes the scheme unbiased over time
(Seide et al.; 1-bit Adam lineage).

Two entry points:
  * ``ef_quantize/ef_dequantize`` -- numerics, testable anywhere;
  * ``compressed_psum`` -- for use inside ``shard_map`` (manual-DP step);
    the pre-scaling by 1/world guards int8 overflow during the sum.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def ef_quantize(g: jax.Array, residual: jax.Array | None
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (q_int8, scale, new_residual).  g fp; residual same shape."""
    g32 = g.astype(jnp.float32)
    if residual is not None:
        g32 = g32 + residual
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / INT8_MAX
    q = jnp.clip(jnp.round(g32 / scale), -INT8_MAX, INT8_MAX)
    new_residual = g32 - q * scale
    return q.astype(jnp.int8), scale, new_residual


def ef_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_tree(grads: Any, residuals: Any | None
                        ) -> tuple[Any, Any]:
    """Quantize-dequantize every leaf with error feedback (numerics of the
    compressed all-reduce without needing a mesh -- used in tests and the
    single-process loop)."""
    flat, treedef = jax.tree_util.tree_flatten(grads)
    res_flat = (treedef.flatten_up_to(residuals) if residuals is not None
                else [None] * len(flat))
    out, new_res = [], []
    for g, r in zip(flat, res_flat):
        q, s, nr = ef_quantize(g, r)
        out.append(ef_dequantize(q, s).astype(g.dtype))
        new_res.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, out),
            jax.tree_util.tree_unflatten(treedef, new_res))


def compressed_psum(g: jax.Array, axis_name, world: int,
                    residual: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Inside shard_map: int8 all-reduce with per-tensor scale.

    The local gradient is pre-divided by ``world`` so the int8 sum cannot
    overflow; scales are max-reduced so all workers dequantize identically.
    """
    g32 = g.astype(jnp.float32) / world
    if residual is not None:
        g32 = g32 + residual
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / INT8_MAX
    scale = jax.lax.pmax(scale, axis_name)          # tiny f32 all-reduce
    q = jnp.clip(jnp.round(g32 / scale), -INT8_MAX, INT8_MAX
                 ).astype(jnp.int8)
    new_residual = g32 - q.astype(jnp.float32) * scale
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)  # wire: int8 payload
    return summed.astype(jnp.float32) * scale, new_residual
