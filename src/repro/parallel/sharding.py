"""Logical sharding rules -> PartitionSpecs for params, activations, caches.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  ``pod`` composes with ``data`` for pure data parallelism, so the
slow inter-pod links carry exactly one gradient all-reduce per step.

Parallelism schemes expressed here:
  * TP (Megatron): attention heads / d_ff / experts / vocab over ``model``;
  * SP (sequence parallelism): the residual stream between blocks is
    sharded over ``model`` on the *sequence* dim (``sp=True``), which is
    what lets 4k x 256 training activations fit HBM;
  * EP: MoE expert dim over ``model``;
  * KV cache: ``kv_mode="heads"`` shards the cache over KV heads (dense
    decode) or ``kv_mode="seq"`` over the sequence dim (flash-decoding
    style -- required for batch=1 long-context).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# Slot-shard axis for the CapsuleEngine serving mesh
# ---------------------------------------------------------------------------

# The serving mesh is 1-D: the engine's slot batch is laid out
# [n_shards, slots_per_shard, ...] with rows sharded over this axis and
# everything else (params, config) replicated.  ``serve/capsule.py``
# consumes these through ``parallel/compat.shard_map``.
SLOT_AXIS = "shards"


def slot_mesh(n_shards: int) -> Mesh:
    """1-D serving mesh over the first ``n_shards`` local devices."""
    devices = jax.devices()
    if not 1 <= n_shards <= len(devices):
        raise ValueError(
            f"n_shards={n_shards} needs 1..{len(devices)} of the visible "
            f"devices (force a CPU mesh with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return Mesh(np.asarray(devices[:n_shards]), (SLOT_AXIS,))


def slot_batch_spec() -> P:
    """Spec for slot-major tensors: rows sharded over ``SLOT_AXIS``."""
    return P(SLOT_AXIS)


def slot_param_spec() -> P:
    """Params are replicated across the serving mesh (pytree-prefix spec)."""
    return P()


@dataclasses.dataclass(frozen=True)
class MeshRules:
    dp: tuple[str, ...]            # data-parallel axes (("pod","data") or ("data",))
    tp: str = "model"
    sp: bool = True                # sequence-parallel residual stream
    kv_mode: str = "heads"         # "heads" | "seq"

    # ---- activation specs -------------------------------------------------
    def act(self, kind: str) -> P:
        dp, tp = P(self.dp), self.tp
        seq = tp if self.sp else None
        table = {
            "tokens": P(self.dp, None),                  # [B, T]
            "btd": P(self.dp, seq, None),                # residual stream
            "btf": P(self.dp, None, tp),                 # MLP hidden
            "bthd": P(self.dp, None, tp, None),          # per-head acts
            "btkd": P(self.dp, None, tp, None),          # per-kv-head acts
            "logits": P(self.dp, None, tp),              # vocab-sharded
            "bte": P(self.dp, None, None),               # router probs
            "ecd": P(tp, None, None),                    # expert dispatch buf
            "becd": P(self.dp, tp, None, None),          # grouped dispatch
            "frames": P(self.dp, None, None),            # frontend stub embeds
        }
        return table[kind]

    def kv_cache(self, stacked: bool = True) -> P:
        # cache leaf: [B, S, KvH, Dh] (+ leading layer-stack dim if stacked)
        # NOTE: prefer ``cache_leaf_pspec`` (divisibility-aware); this is
        # the static preference only.
        if self.kv_mode == "seq":
            base = (self.dp, self.tp, None, None)
        else:
            base = (self.dp, None, self.tp, None)
        return P(*(((None,) + base) if stacked else base))

    def ssm_cache(self, stacked: bool = True) -> P:
        # conv state [B, d_conv-1, CH]; ssd state [B, H, dh, N] -> shard H/CH.
        base = (self.dp, None, self.tp)
        return P(*(((None,) + base) if stacked else base))


def cache_leaf_pspec(path, shape, rules: MeshRules, mesh: Mesh) -> P:
    """Divisibility-aware PartitionSpec for one KV/SSM cache leaf.

    Preference order per leaf kind; an axis is only assigned when the dim
    divides evenly and the axis is not already used.  A batch=1 long-context
    cache falls back to sharding the sequence dim over ALL axes (the
    flash-decoding layout).
    """
    names = [str(getattr(p, "key", "")) for p in path]
    leaf = names[-1] if names else ""
    stacked = "blocks" in names
    dp, tp = rules.dp, rules.tp

    def size(axes) -> int:
        axes = (axes,) if isinstance(axes, str) else tuple(axes)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n

    base_ndim = len(shape) - (1 if stacked else 0)
    dims = shape[1:] if stacked else shape
    all_axes = dp + (tp,)
    if leaf in ("k", "v"):                       # [B, S, KvH, Dh]
        prefs = ([(0, dp), (1, (tp,))] if rules.kv_mode == "seq"
                 else [(0, dp), (2, (tp,)), (1, (tp,))])
        seq_dim = 1
    elif leaf in ("c_kv", "k_rope"):             # [B, S, R]
        prefs = ([(0, dp), (1, (tp,))] if rules.kv_mode == "seq"
                 else [(0, dp), (2, (tp,)), (1, (tp,))])
        seq_dim = 1
    elif leaf == "conv":                         # [B, K-1, CH]
        prefs = [(0, dp), (2, (tp,))]
        seq_dim = None
    elif leaf == "ssd":                          # [B, H, P, N]
        prefs = [(0, dp), (1, (tp,)), (2, (tp,))]
        seq_dim = None
    else:
        return P()

    assign: list = [None] * base_ndim
    used: set = set()
    for dim, axes in prefs:
        if axes is None or dim >= base_ndim or assign[dim] is not None:
            continue
        axes = tuple(axes)
        if any(a in used for a in axes):
            continue
        if dims[dim] % size(axes) == 0 and dims[dim] >= size(axes):
            assign[dim] = axes if len(axes) > 1 else axes[0]
            used.update(axes)
    # batch=1 fallback: spread the sequence over every unused axis.
    if seq_dim is not None and assign[seq_dim] is None:
        free = tuple(a for a in all_axes if a not in used)
        if free and dims[seq_dim] % size(free) == 0:
            assign[seq_dim] = free
            used.update(free)
    if stacked:
        assign = [None] + assign
    return P(*assign)


def cache_shardings(cache_specs, rules: MeshRules, mesh: Mesh):
    """NamedSharding tree for a model cache (specs or arrays)."""
    def mk(path, leaf):
        return NamedSharding(mesh, cache_leaf_pspec(path, leaf.shape, rules,
                                                    mesh))
    return jax.tree_util.tree_map_with_path(mk, cache_specs)


def make_rules(multi_pod: bool = False, sp: bool = True,
               kv_mode: str = "heads") -> MeshRules:
    return MeshRules(dp=("pod", "data") if multi_pod else ("data",),
                     sp=sp, kv_mode=kv_mode)


@dataclasses.dataclass
class ShardingCtx:
    """Carried through the model; no-op when mesh is None (CPU tests)."""

    mesh: Mesh | None = None
    rules: MeshRules | None = None

    def _axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def act(self, x: jax.Array, kind: str) -> jax.Array:
        if self.mesh is None or self.rules is None:
            return x
        spec = tuple(self.rules.act(kind))
        if len(spec) < x.ndim:
            spec = spec + (None,) * (x.ndim - len(spec))
        spec = spec[:x.ndim]
        # Drop axes that do not divide the dim: constraining e.g. 8 KV heads
        # over a 16-way model axis forces XLA into replicate+pad (the SPMD
        # "involuntary full rematerialization" path).
        fixed = tuple(a if x.shape[i] % self._axis_size(a) == 0 else None
                      for i, a in enumerate(spec))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*fixed)))


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs by path-name rules
# ---------------------------------------------------------------------------

# (regex on the '/'-joined param path) -> spec builder taking ndim.
# Specs are written for the UNSTACKED parameter; scanned blocks get a
# leading layer dim which we prepend as None (detected from ndim).
_PARAM_RULES: list[tuple[str, tuple[Any, ...]]] = [
    (r"embed$",              ("model", None)),        # [V, D] vocab-sharded
    (r"unembed$",            (None, "model")),        # [D, V]
    (r"(q_proj|k_proj|v_proj)$", (None, "model")),    # [D, H*dh]
    (r"o_proj$",             ("model", None)),        # [H*dh, D]
    (r"kv_down$",            (None, None)),           # [D, lora+rope] small
    (r"kv_up$",              (None, "model")),        # [lora, H*(nope+v)]
    (r"(gate_proj|up_proj)$", (None, "model")),       # [D, F]
    (r"down_proj$",          ("model", None)),        # [F, D]
    (r"router$",             (None, None)),           # [D, E]
    (r"experts_(gate|up)$",  ("model", None, None)),  # [E, D, Fe] EP
    (r"experts_down$",       ("model", None, None)),  # [E, Fe, D] EP
    (r"shared_(gate|up)_proj$", (None, "model")),
    (r"shared_down_proj$",   ("model", None)),
    (r"(z_proj|x_proj)$",    (None, "model")),        # mamba [D, di]
    (r"(b_proj|c_proj)$",    (None, "model")),        # [D, G*N]
    (r"dt_proj$",            (None, "model")),        # [D, nH]
    (r"conv_w$",             ("model", None)),        # [CH, d_conv]
    (r"conv_b$",             ("model",)),
    (r"(a_log|ssm_d|dt_bias)$", ("model",)),          # per-head [nH]
    (r"(frontend_proj)$",    (None, None)),
    (r".*norm.*",            None),                   # replicated
    (r"(conv1_w|pc_w|cc_w|dec_w\d|conv1_b|pc_b|dec_b\d)$", None),  # capsnet
]


def _spec_for_path(path: str, ndim: int) -> P:
    for pattern, axes in _PARAM_RULES:
        if re.search(pattern, path):
            if axes is None:
                return P()
            axes = tuple(axes)
            if len(axes) < ndim:   # scanned stack: leading layer dim(s)
                axes = (None,) * (ndim - len(axes)) + axes
            elif len(axes) > ndim:
                axes = axes[-ndim:]
            return P(*axes)
    return P()                     # default: replicate


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspecs(params: Any) -> Any:
    """Tree of PartitionSpecs matching a parameter pytree (or its shapes)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_spec_for_path(_path_str(path), leaf.ndim) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_pspecs(params),
        is_leaf=lambda x: isinstance(x, P))


def zero1_pspecs(params: Any, mesh: Mesh, dp_axes: tuple[str, ...]) -> Any:
    """ZeRO-1: optimizer-state specs = param spec + dp sharding on the
    largest dim that is still unsharded and divisible by the dp size."""
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]

    def shard_one(path, leaf):
        spec = _spec_for_path(_path_str(path), leaf.ndim)
        axes = list(spec) + [None] * (leaf.ndim - len(spec))
        # pick the largest unsharded, divisible dim
        cand = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
        for i in cand:
            if axes[i] is None and leaf.shape[i] % dp_size == 0 \
                    and leaf.shape[i] >= dp_size:
                axes[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                break
        return P(*axes)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef, [shard_one(path, leaf) for path, leaf in flat])
