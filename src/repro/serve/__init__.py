from repro.serve.capsule import (CapsRequest, CapsuleEngine,  # noqa: F401
                                 EngineStalled)
from repro.serve.engine import Request, ServeEngine  # noqa: F401
