from repro.serve.capsule import (AsyncCapsuleServer,  # noqa: F401
                                 CapsRequest, CapsuleEngine, EngineStalled)
from repro.serve.engine import Request, ServeEngine  # noqa: F401
