"""Slot-based batched CapsuleNet inference engine.

Mirrors ``serve/engine.py``'s admission/refill loop for the paper's own
(non-autoregressive) workload: a fixed number of batch slots share ONE
jit-compiled, plan-driven forward pass.  New requests fill free slots from
the queue each tick; every tick runs the whole batch through the compiled
forward once, so the ExecutionPlan (block shapes, VMEM schedule) is
compiled once and amortized across the request stream.  Inactive slots
carry zero images -- the capsule head is per-sample, so padding never
perturbs active requests.

On the pallas backend the engine compiles the FUSED plan: every routing
layer of the config's graph (the single ClassCaps head, or a deep
ResCaps stack's per-layer instances) is one ``votes_routing`` megakernel
(resident or streamed schedule per the plan's VMEM decision), so no slot
tick ever round-trips a votes tensor through HBM.  The engine is
graph-agnostic -- it serves whatever stack ``compile_plan`` scheduled
for the config.  A caller-supplied plan must be compiled for
``batch >= slots_per_shard``: the jitted forward always runs all slot
rows (of its shard), so a smaller plan batch would blow the plan's
validated VMEM footprint (or raise the opaque kernel-level batch error
on the first tick) -- ``__init__`` rejects it up front, naming both
numbers.

**Sharded serving.**  ``n_shards=k`` lays the slot batch out over a
k-device mesh (``slots = n_shards * slots_per_shard``, slot ``s`` lives
on shard ``s // slots_per_shard``) and runs the SAME jitted forward
under ``parallel/compat.shard_map`` with the specs from
``parallel/sharding.py`` (params replicated, batch row-sharded).  ONE
``compile_plan`` call produces the per-shard plan
(``plan.batch == slots_per_shard``), so the resident / streamed /
pipelined machinery is untouched inside a shard, and the body still
traces exactly once -- the single-trace invariant holds across shard
counts, and degrade/breaker swaps re-trace ONCE across the whole mesh.
The capsule head is per-sample (no cross-batch reductions), so sharded
outputs are bit-identical to the single-device engine's.

Host<->device traffic is tick-size, not batch-size: the slot batch lives
ON DEVICE and only slots dirtied since the last tick (new admissions,
freed slots zeroing out) are uploaded (dirty set padded to the next
power of two so the scatter compiles O(log slots) times, not once per
occupancy); classification finishes on device and the active slots' rows
are gathered INSIDE the jit through a fixed-size padded index, so the
forward traces exactly once no matter how occupancy varies tick to tick
(the old eager ``jnp.take`` compiled a fresh gather per distinct
occupancy count).

**Graceful degradation.**  The engine is hardened against the failure
modes the chaos suite (``tests/test_faults.py``, ``core/faults.py``)
injects; with injection disabled none of these paths add a trace or
change a result:

* Every request reaches exactly ONE terminal ``status``: ``ok`` /
  ``timeout`` (its ``deadline_s`` expired in queue or in a slot) /
  ``error`` (non-finite output survived ``max_retries``) / ``shed``
  (bounded-queue admission or an unservable drain).  ``stats()``
  counters satisfy ``ok + timeout + error + shed == submitted``.
* **Bounded queue**: ``max_queue`` caps the backlog; ``admission``
  picks who pays -- ``"reject"`` sheds the NEW request, ``"shed-oldest"``
  sheds the head of the queue.  Shedding is a terminal status, never a
  raise: the caller reads it off the request.
* **Non-finite guard**: a slot row whose lengths come back NaN/Inf is
  retried with per-retry tick backoff (the clean host-side image is
  re-uploaded, healing device-side corruption); a request whose
  ``deadline_s`` has already expired is terminated ``timeout`` instead
  of being re-dispatched.  After ``max_retries`` the request errors
  out, and ``quarantine_after`` consecutive poisoned results quarantine
  the SLOT -- a storm cannot grind the engine through one bad lane
  forever.  Quarantine is PROBATIONARY, not permanent:
  ``probation_ticks`` consecutive clean ticks (or a breaker trip /
  degrade-replan swap, both of which change the serving path) lift it,
  so capacity returns once a transient fault window closes.  When every
  slot is quarantined the remaining queue is shed rather than hung.
* **Circuit breaker**: ``breaker_after`` consecutive forward-dispatch
  exceptions re-trace the forward on the jnp reference backend and keep
  serving with ``degraded=True`` -- one failing Pallas lowering does not
  take the service down.
* **Degraded-VMEM replanning**: a ``vmem_shrink(factor)`` fault (sector
  power-gating, co-tenancy) makes the engine call
  ``execplan.degrade_plan`` at the next tick boundary and swap in the
  reduced-budget plan -- ONE new trace, the device slot batch preserved
  -- walking compile_plan's own fallback ladder (pipelined pair ->
  per-op, resident -> streamed, shrunk tiles); if not even a degraded
  plan fits the slot batch, the breaker path serves on the reference
  backend instead.
* **Stall detection**: ``run(max_ticks=...)`` bounds the host loop, and
  ``stall_ticks`` consecutive ticks without a single terminal event
  while work is pending raise ``EngineStalled`` instead of spinning
  forever.

Per-request latency (submit -> classified) and engine throughput
(requests/s) are reported by ``stats()``; tests validate slot-batched
outputs against the direct single-request forward.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core import capsnet, execplan, faults
from repro.core.capsnet import CapsNetConfig
from repro.core.execplan import ExecutionPlan, PlanError, compile_plan
from repro.core.planner import VMEM_BYTES
from repro.parallel import compat
from repro.parallel.sharding import slot_batch_spec, slot_mesh, slot_param_spec

TERMINAL_STATUSES = ("ok", "timeout", "error", "shed")


class EngineStalled(RuntimeError):
    """``CapsuleEngine.run`` detected zero progress (or exhausted
    ``max_ticks``) with work still pending -- raised instead of hanging
    the host loop."""


@dataclasses.dataclass
class CapsRequest:
    rid: int
    image: np.ndarray                  # [H, W, C] float in [0, 1]
    deadline_s: float | None = None    # submit-relative expiry (None: never)
    submitted_s: float | None = None
    finished_s: float | None = None
    queue_ticks: int = 0               # ticks spent waiting for a slot
    retries: int = 0                   # non-finite-output retries consumed
    status: str = "pending"            # -> ok | timeout | error | shed
    lengths: np.ndarray | None = None  # [num_classes] capsule lengths
    pred: int | None = None

    @property
    def latency_s(self) -> float | None:
        if self.submitted_s is None or self.finished_s is None:
            return None
        return self.finished_s - self.submitted_s


class CapsuleEngine:
    """Continuous-batching CapsNet classifier over a request queue."""

    def __init__(self, params, cfg: CapsNetConfig = CapsNetConfig(), *,
                 slots: int = 8, backend: str = "jnp",
                 interpret: bool = True, plan: ExecutionPlan | None = None,
                 n_shards: int | None = None,
                 max_queue: int | None = None, admission: str = "reject",
                 max_retries: int = 2, retry_backoff_ticks: int = 1,
                 quarantine_after: int = 3, breaker_after: int = 3,
                 probation_ticks: int | None = 8, stall_ticks: int = 32):
        if admission not in ("reject", "shed-oldest"):
            raise ValueError(f"unknown admission policy {admission!r} "
                             f"(choices: 'reject', 'shed-oldest')")
        self.params = params
        self.cfg = cfg
        self.slots = slots
        # n_shards=None is the legacy single-device layout (no mesh in
        # play at all); n_shards=k >= 1 shards the slot batch row-wise
        # over the first k local devices (k=1 exercises the mesh path on
        # a single device, so parity is testable without a real mesh).
        self.n_shards = n_shards if n_shards is not None else 1
        if slots % self.n_shards:
            raise ValueError(
                f"slots={slots} does not divide over n_shards="
                f"{self.n_shards}: the slot batch is laid out "
                f"[n_shards, slots_per_shard, ...]")
        self.mesh = slot_mesh(n_shards) if n_shards is not None else None
        self.slots_per_shard = slots // self.n_shards
        if plan is None and backend == "pallas":
            # ONE compile_plan produces the per-shard plan: under
            # shard_map each shard's forward sees slots_per_shard rows,
            # so the PIPELINED plan (Conv1 -> one primary_routing
            # megakernel when the combined footprint fits, per-op
            # fallback otherwise) is compiled for that local batch and
            # replicated across the mesh unchanged.
            plan = compile_plan(cfg, batch=self.slots_per_shard,
                                pipeline=True)
        elif plan is not None and plan.batch < self.slots_per_shard:
            # The jitted forward runs ALL slot rows every tick; a plan
            # compiled for fewer would either raise the kernel-level
            # votes_routing batch error on the first step() or (jnp path)
            # silently exceed the VMEM footprint the plan validated.
            shard_note = (
                f" per shard (slots = n_shards * plan.batch: {slots} slots "
                f"over {self.n_shards} shards)" if self.mesh is not None
                else "")
            raise PlanError(
                f"plan compiled for batch {plan.batch} cannot serve "
                f"{self.slots_per_shard} slots{shard_note}: every tick runs "
                f"the full {self.slots_per_shard}-row slot batch; compile "
                f"the plan with batch >= slots")
        self.plan = plan          # None on the jnp path unless caller-supplied
        self.max_queue = max_queue
        self.admission = admission
        self.max_retries = max_retries
        self.retry_backoff_ticks = retry_backoff_ticks
        self.quarantine_after = quarantine_after
        self.breaker_after = breaker_after
        self.probation_ticks = probation_ticks
        self.stall_ticks = stall_ticks
        self.degraded = False            # breaker tripped or plan degraded
        self.degrade_report = None       # execplan.DegradeReport after replan
        self.quarantined: set[int] = set()
        self.active: list[CapsRequest | None] = [None] * slots
        self.queue: deque[CapsRequest] = deque()
        self.finished: list[CapsRequest] = []
        self.ticks = 0
        self._backend = backend
        self._interpret = interpret
        self._occupancy = 0
        self._now = time.perf_counter    # injectable clock (deadline tests)
        self._started_s: float | None = None
        self._stopped_s: float | None = None
        self._vmem_budget = (plan.vmem_budget if plan is not None
                             else VMEM_BYTES)
        self._orig_budget = self._vmem_budget
        self._counters = {s: 0 for s in TERMINAL_STATUSES}
        self._counters.update(submitted=0, retries=0, replans=0,
                              breaker_trips=0, forward_failures=0,
                              poisoned=0, unquarantined=0)
        # Terminal events attributed per shard (slot-resident terminals)
        # plus a "queue" bucket for requests that never reached a slot;
        # stats() asserts their sum equals the aggregate counters.
        self._shard_counters = [{s: 0 for s in TERMINAL_STATUSES}
                                for _ in range(self.n_shards)]
        self._queue_counters = {s: 0 for s in TERMINAL_STATUSES}
        self._poison_streak = [0] * slots   # consecutive bad results / slot
        self._backoff_until = [0] * slots   # tick a retrying slot resumes at
        self._breaker_fails = 0             # consecutive dispatch exceptions
        self._clean_streak = 0              # ticks since the last poison
        self._stall_pending = False         # injected stall: skip one tick
        self._batch = np.zeros(
            (slots, cfg.image_hw, cfg.image_hw, cfg.in_channels), np.float32)
        self._batch_dev = jnp.asarray(self._batch)   # device-resident slots
        if self.mesh is not None:
            self._batch_dev = jax.device_put(
                self._batch_dev,
                NamedSharding(self.mesh, slot_batch_spec()))
        self._dirty: set[int] = set()                # slots to re-upload
        self._forward_traces = 0                     # (re)compilations seen
        self._forward = self._make_forward(backend, plan)
        self._scatter = jax.jit(lambda b, i, x: b.at[i].set(x))

    def _make_forward(self, backend: str, plan: ExecutionPlan | None):
        """One jitted forward over the full slot batch.  Rebuilt (ONE new
        trace) only when the engine degrades: a vmem_shrink replan swaps
        in the reduced-budget plan, a tripped breaker swaps in the jnp
        reference backend.  Under a mesh the body runs per shard through
        ``compat.shard_map`` (params replicated, batch and index
        row-sharded) -- still ONE trace for the whole mesh, and a
        degrade/breaker rebuild is likewise ONE re-trace mesh-wide."""
        def body(p, images, idx):
            out = capsnet.forward(p, images, self.cfg, backend=backend,
                                  plan=plan, interpret=self._interpret)
            # Gather the active slots ON DEVICE through the fixed-size
            # padded index and classify there: one trace for any
            # occupancy, and only slot-count-many result rows ever cross.
            # Under shard_map the index is shard-local ([slots_per_shard]
            # values in [0, slots_per_shard)), so the gather never
            # crosses shards.
            lengths = jnp.take(out["lengths"], idx, axis=0)
            return lengths, jnp.argmax(lengths, axis=-1)

        if self.mesh is not None:
            batch_spec = slot_batch_spec()
            body = compat.shard_map(
                body, mesh=self.mesh,
                in_specs=(slot_param_spec(), batch_spec, batch_spec),
                out_specs=(batch_spec, batch_spec))

        def fwd(p, images, idx):
            self._forward_traces += 1                # counts traces, not calls
            return body(p, images, idx)

        return jax.jit(fwd)

    # -- admission -------------------------------------------------------
    def _shard_of(self, s: int) -> int:
        return s // self.slots_per_shard

    def _finish(self, req: CapsRequest, status: str,
                shard: int | None = None) -> None:
        """Assign the terminal ``status`` and retire the request; every
        submitted request passes through here exactly once.  ``shard``
        attributes slot-resident terminals to their shard's counters;
        queue-side terminals (admission sheds, queued timeouts) land in
        the "queue" bucket, so per-shard + queue always sums to the
        aggregate."""
        req.status = status
        req.finished_s = self._now()
        self.finished.append(req)
        self._counters[status] += 1
        if shard is None:
            self._queue_counters[status] += 1
        else:
            self._shard_counters[shard][status] += 1

    def submit(self, req: CapsRequest) -> None:
        """Queue ``req``; rejects images whose layout does not match the
        engine input (a same-size [C, H, W] array would otherwise be
        silently reinterpreted as [H, W, C] garbage).  A full bounded
        queue sheds per the admission policy -- a terminal ``"shed"``
        status on the victim, never a raise."""
        img = np.asarray(req.image, np.float32)
        want = self._batch.shape[1:]
        if img.shape != want:
            raise ValueError(
                f"request {req.rid}: image shape {img.shape} does not match "
                f"the engine input shape {want} (H, W, C for "
                f"image_hw={self.cfg.image_hw}, "
                f"in_channels={self.cfg.in_channels}); refusing to reshape")
        req.image = img
        req.submitted_s = self._now()
        self._counters["submitted"] += 1
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            if self.admission == "reject":
                self._finish(req, "shed")            # the newcomer pays
                return
            self._finish(self.queue.popleft(), "shed")   # the oldest pays
        self.queue.append(req)

    def _admit_order(self):
        """Slot fill order: shard-interleaved under a mesh, so a
        part-full queue spreads over all shards instead of saturating
        shard 0 while the rest idle.  Placement never changes a result
        (the head is per-sample), only balance."""
        if self.n_shards == 1:
            return range(self.slots)
        return (shard * self.slots_per_shard + k
                for k in range(self.slots_per_shard)
                for shard in range(self.n_shards))

    def _admit(self) -> None:
        for s in self._admit_order():
            if s in self.quarantined:
                continue
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self._batch[s] = req.image        # shape-checked in submit()
                self._dirty.add(s)
                self.active[s] = req

    def _clear_slot(self, s: int) -> None:
        self.active[s] = None
        self._batch[s] = 0.0
        self._dirty.add(s)          # freed slot returns to zero images
        self._backoff_until[s] = 0

    def _upload_dirty(self) -> None:
        """Scatter only the slots dirtied since the last tick into the
        device-resident batch.  The dirty set is padded to the next power
        of two by repeating its last entry (duplicate indices write the
        same row), so the scatter compiles O(log slots) distinct shapes
        instead of one per occupancy delta."""
        dirty = sorted(self._dirty)
        self._dirty.clear()
        n = min(1 << (len(dirty) - 1).bit_length(), self.slots)
        dirty.extend(dirty[-1:] * (n - len(dirty)))
        idx = np.asarray(dirty, np.int32)
        self._batch_dev = self._scatter(self._batch_dev, jnp.asarray(idx),
                                        jnp.asarray(self._batch[idx]))

    # -- fault reactions -------------------------------------------------
    def _apply_tick_faults(self, tick: int) -> None:
        for spec in faults.poll(faults.SITE_ENGINE_TICK, index=tick):
            if spec.kind == "vmem_shrink":
                self._replan(spec.factor)
            elif spec.kind == "slot_corrupt":
                self._corrupt_slot(spec, tick)
            elif spec.kind == "stall":
                self._stall_pending = True

    def _replan(self, factor: float) -> None:
        """React to a shrunk VMEM budget at a tick boundary: swap in the
        degraded plan (ONE new trace, device slot batch preserved); fall
        back to the reference backend when not even a degraded plan fits
        the slot batch.  Idempotent across a multi-tick fault window --
        the factor applies to the ORIGINAL budget."""
        new_budget = max(int(self._orig_budget * factor), 1)
        if new_budget == self._vmem_budget:
            return
        self._vmem_budget = new_budget
        if self._backend != "pallas":
            return                       # the jnp path plans nothing
        try:
            plan, report = execplan.degrade_plan(
                self.cfg, new_budget, batch=self.slots_per_shard,
                pipeline=True, min_batch=self.slots_per_shard)
        except PlanError:
            self._trip_breaker()         # not even degraded fits: reference
            return
        if plan == self.plan:
            return                       # shrunk budget still fits as-is
        self.plan = plan
        self.degrade_report = report
        self.degraded = self.degraded or report.degraded
        self._counters["replans"] += 1
        self._forward = self._make_forward("pallas", plan)
        self._lift_quarantine()          # new plan: lanes get a fresh chance

    def _corrupt_slot(self, spec: faults.FaultSpec, tick: int) -> None:
        """NaN-fill one seeded ACTIVE slot's device row (the host copy
        stays clean, so the retry path's re-upload heals it -- exactly
        the transient-device-corruption scenario)."""
        act = [s for s in range(self.slots) if self.active[s] is not None]
        if not act:
            return
        rng = np.random.default_rng(spec.seed + tick)
        s = act[int(rng.integers(len(act)))]
        if self._dirty:
            self._upload_dirty()    # land pending admissions first, or the
        bad = np.full((1,)          # dispatch upload would erase the NaN row
                      + self._batch.shape[1:], np.nan, np.float32)
        self._batch_dev = self._scatter(
            self._batch_dev, jnp.asarray([s], np.int32), jnp.asarray(bad))

    def _trip_breaker(self) -> None:
        if self._backend == "jnp":
            return                       # already on the reference path
        self._backend = "jnp"
        self.plan = None
        self.degraded = True
        self._counters["breaker_trips"] += 1
        self._breaker_fails = 0
        self._forward = self._make_forward("jnp", None)
        self._lift_quarantine()          # new backend: lanes get a fresh chance

    def _lift_quarantine(self) -> None:
        """Return quarantined slots to the admission pool with their
        poison streaks reset.  Called after ``probation_ticks``
        consecutive clean ticks, and on breaker trips / plan swaps (the
        serving path changed, so the old lanes' verdicts are stale)."""
        if not self.quarantined:
            return
        for s in self.quarantined:
            self._poison_streak[s] = 0
        self._counters["unquarantined"] += len(self.quarantined)
        self.quarantined.clear()
        self._clean_streak = 0

    def _maybe_lift_quarantine(self) -> None:
        if (self.probation_ticks is not None and self.quarantined
                and self._clean_streak >= self.probation_ticks):
            self._lift_quarantine()

    def _expired(self, req: CapsRequest) -> bool:
        return (req.deadline_s is not None
                and self._now() - req.submitted_s > req.deadline_s)

    def _sweep_deadlines(self, now: float) -> None:
        for req in [r for r in self.queue
                    if r.deadline_s is not None
                    and now - r.submitted_s > r.deadline_s]:
            self.queue.remove(req)
            self._finish(req, "timeout")
        for s in range(self.slots):
            req = self.active[s]
            if (req is not None and req.deadline_s is not None
                    and now - req.submitted_s > req.deadline_s):
                self._finish(req, "timeout", self._shard_of(s))
                self._clear_slot(s)

    # -- main loop -------------------------------------------------------
    def _end_tick(self, act_count: int, poisoned: bool = False) -> None:
        for waiting in self.queue:
            waiting.queue_ticks += 1
        self.ticks += 1
        self._occupancy += act_count
        self._clean_streak = 0 if poisoned else self._clean_streak + 1
        self._stopped_s = self._now()

    def step(self) -> int:
        """One engine tick: fault reactions, deadline sweep, admit, then
        classify all dispatchable slots.  Returns the number of requests
        that reached ``ok`` this tick."""
        if self._started_s is None:
            self._started_s = self._now()
        self._sweep_deadlines(self._now())
        self._maybe_lift_quarantine()
        self._admit()
        # Tick faults land AFTER admission (slot_corrupt must see the
        # rows resident this tick) and BEFORE dispatch (a vmem_shrink
        # replan swaps the plan at the tick boundary, never mid-forward).
        if faults.enabled():
            self._apply_tick_faults(self.ticks)
        if self._stall_pending:
            # Injected stall: the tick passes with no dispatch (run()'s
            # zero-progress detection is the guardrail).
            self._stall_pending = False
            self._end_tick(0)
            return 0
        if self.queue and len(self.quarantined) == self.slots:
            # Every lane is quarantined: the backlog can never be served.
            # Shed it (terminal status) instead of spinning until the
            # stall detector fires.
            while self.queue:
                self._finish(self.queue.popleft(), "shed")
        act = [s for s in range(self.slots)
               if self.active[s] is not None
               and self._backoff_until[s] <= self.ticks]
        if not act:
            if any(a is not None for a in self.active) or self.queue:
                self._end_tick(0)        # backed-off slots need time to pass
            return 0
        if self._dirty:
            self._upload_dirty()
        # Fixed-size index: the active slots, padded by repeating the
        # first (result rows not named in ``pos`` are ignored).  Under a
        # mesh the index is built PER SHARD in shard-local coordinates
        # (shard_map hands each device its own [slots_per_shard] block),
        # and ``pos`` maps slot -> global result row either way.
        pos: dict[int, int] = {}
        if self.mesh is None:
            idx = np.full(self.slots, act[0], np.int32)
            idx[:len(act)] = act
            pos = {s: i for i, s in enumerate(act)}
        else:
            sps = self.slots_per_shard
            idx = np.zeros(self.slots, np.int32)
            for shard in range(self.n_shards):
                base = shard * sps
                local = [s for s in act if base <= s < base + sps]
                idx[base:base + sps] = (local[0] - base) if local else 0
                for k, s in enumerate(local):
                    idx[base + k] = s - base
                    pos[s] = base + k
        try:
            if faults.enabled() and faults.poll(
                    faults.SITE_ENGINE_FORWARD, index=self.ticks,
                    kinds=("plan_error",)):
                raise PlanError(
                    f"injected plan_error at {faults.SITE_ENGINE_FORWARD} "
                    f"(tick {self.ticks})")
            lengths, preds = jax.device_get(
                self._forward(self.params, self._batch_dev, jnp.asarray(idx)))
            self._breaker_fails = 0
        except Exception:
            # One forward failure loses one tick, never the engine:
            # consecutive failures trip the breaker onto the reference
            # backend (re-traced once) and the engine keeps serving.
            self._counters["forward_failures"] += 1
            self._breaker_fails += 1
            if self._breaker_fails >= self.breaker_after:
                self._trip_breaker()
            self._end_tick(0)
            return 0
        if faults.enabled():
            for spec in faults.poll(faults.SITE_ENGINE_FORWARD,
                                    index=self.ticks,
                                    kinds=("nan_output", "inf_output")):
                fill = np.nan if spec.kind == "nan_output" else np.inf
                lengths = np.full_like(lengths, fill)
        done = 0
        poisoned_tick = False
        for s in act:
            req = self.active[s]
            row = lengths[pos[s]]
            shard = self._shard_of(s)
            if not np.all(np.isfinite(row)):
                poisoned_tick = True
                self._counters["poisoned"] += 1
                self._poison_streak[s] += 1
                if self._poison_streak[s] >= self.quarantine_after:
                    # K consecutive poisoned results through one lane:
                    # the slot is quarantined (probation may lift it
                    # later), the request errors out.
                    self.quarantined.add(s)
                    self._finish(req, "error", shard)
                    self._clear_slot(s)
                elif self._expired(req):
                    # The deadline passed while the slot sat in retry
                    # backoff: terminate as timeout instead of burning
                    # another dispatch on a dead request.
                    self._finish(req, "timeout", shard)
                    self._clear_slot(s)
                elif req.retries < self.max_retries:
                    req.retries += 1
                    self._counters["retries"] += 1
                    # Backoff grows with the retry count; the clean host
                    # image is re-uploaded (heals device corruption).
                    self._backoff_until[s] = (self.ticks + 1
                                              + self.retry_backoff_ticks
                                              * req.retries)
                    self._batch[s] = req.image
                    self._dirty.add(s)
                else:
                    self._finish(req, "error", shard)
                    self._clear_slot(s)
                continue
            self._poison_streak[s] = 0
            req.lengths = row
            req.pred = int(preds[pos[s]])
            self._finish(req, "ok", shard)
            self._clear_slot(s)
            done += 1
        self._end_tick(len(act), poisoned=poisoned_tick)
        return done

    def run(self, max_ticks: int | None = None) -> list[CapsRequest]:
        """Drive ticks until every request is terminal.  ``max_ticks``
        bounds the loop; ``stall_ticks`` consecutive ticks with no
        terminal event while work is pending raise ``EngineStalled``
        (named, with the pending counts) instead of hanging the host."""
        no_progress = 0
        while self.queue or any(a is not None for a in self.active):
            before = len(self.finished)
            self.step()
            no_progress = (0 if len(self.finished) > before
                           else no_progress + 1)
            pending = (len(self.queue)
                       + sum(a is not None for a in self.active))
            if pending and no_progress >= self.stall_ticks:
                raise EngineStalled(
                    f"no request reached a terminal status in "
                    f"{no_progress} consecutive ticks with {pending} "
                    f"pending (tick {self.ticks}); the engine is stalled")
            if max_ticks is not None and self.ticks >= max_ticks and pending:
                raise EngineStalled(
                    f"max_ticks={max_ticks} exhausted with {pending} "
                    f"requests still pending")
        return self.finished

    # -- reporting -------------------------------------------------------
    def stats(self) -> dict:
        n = len(self.finished)
        elapsed = ((self._stopped_s - self._started_s)
                   if self._started_s is not None and self._stopped_s is not None
                   else 0.0)
        lats = [r.latency_s for r in self.finished if r.latency_s is not None]
        sps = self.slots_per_shard
        per_shard = [
            dict(shard=i, slots=sps,
                 occupied=sum(1 for s in range(i * sps, (i + 1) * sps)
                              if self.active[s] is not None),
                 quarantined=sum(1 for s in self.quarantined
                                 if self._shard_of(s) == i),
                 **self._shard_counters[i])
            for i in range(self.n_shards)
        ]
        return dict(
            requests=n,
            ticks=self.ticks,
            elapsed_s=elapsed,
            requests_per_s=n / elapsed if elapsed > 0 else 0.0,
            mean_latency_ms=1e3 * float(np.mean(lats)) if lats else 0.0,
            max_latency_ms=1e3 * float(np.max(lats)) if lats else 0.0,
            occupancy=(self._occupancy / (self.ticks * self.slots)
                       if self.ticks else 0.0),
            degraded=self.degraded,
            quarantined=len(self.quarantined),
            vmem_budget=self._vmem_budget,
            n_shards=self.n_shards,
            slots_per_shard=sps,
            # Slot-resident terminals per shard + the queue bucket sum to
            # the aggregate counters (asserted by the chaos suite).
            per_shard=per_shard,
            queue_bucket=dict(self._queue_counters),
            **self._counters,
        )


class AsyncCapsuleServer:
    """Asyncio host loop over a ``CapsuleEngine``: continuous slot
    recycling with per-request futures.

    ``submit()`` enqueues through the engine (so the bounded-queue
    admission policy applies unchanged -- a shed request's future
    resolves immediately with ``status == "shed"``) and awaits the
    request's terminal status.  A single driver task ticks the engine
    whenever work is pending and yields to the event loop between
    ticks, so freed slots are refilled from whatever has been submitted
    since the last tick -- callers never wait for a "batch" to form.
    The engine is stepped from the event-loop thread only, so no
    engine state needs locking.  Works over sharded and unsharded
    engines alike; ``EngineStalled`` (or any driver failure) is
    propagated to every in-flight future instead of hanging them.
    """

    def __init__(self, engine: CapsuleEngine, *,
                 idle_sleep_s: float = 1e-3):
        self.engine = engine
        self._idle_sleep_s = idle_sleep_s
        self._waiters: dict[int, asyncio.Future] = {}   # id(req) -> future
        self._task: asyncio.Task | None = None
        self._stopping = False
        self._next_rid = 0
        self._seen = len(engine.finished)

    async def __aenter__(self) -> "AsyncCapsuleServer":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def start(self) -> None:
        if self._task is None:
            self._stopping = False
            self._task = asyncio.get_running_loop().create_task(
                self._drive())

    async def stop(self) -> None:
        """Drain: the driver keeps ticking until no work is pending,
        then exits."""
        self._stopping = True
        if self._task is not None:
            await self._task
            self._task = None

    async def submit(self, image, *,
                     deadline_s: float | None = None) -> CapsRequest:
        """Submit one image and await its terminal request."""
        rid = self._next_rid
        self._next_rid += 1
        req = CapsRequest(rid=rid, image=image, deadline_s=deadline_s)
        fut = asyncio.get_running_loop().create_future()
        self._waiters[id(req)] = fut
        self.engine.submit(req)      # may shed synchronously (admission)
        self._resolve_finished()
        self.start()                 # lazily spin the driver up
        return await fut

    def _resolve_finished(self) -> None:
        fin = self.engine.finished
        while self._seen < len(fin):
            req = fin[self._seen]
            self._seen += 1
            fut = self._waiters.pop(id(req), None)
            if fut is not None and not fut.done():
                fut.set_result(req)

    def _pending(self) -> bool:
        eng = self.engine
        return bool(eng.queue) or any(a is not None for a in eng.active)

    async def _drive(self) -> None:
        try:
            while True:
                if self._pending():
                    self.engine.step()
                    self._resolve_finished()
                    await asyncio.sleep(0)   # admit work queued mid-tick
                elif self._stopping:
                    return
                else:
                    await asyncio.sleep(self._idle_sleep_s)
        except BaseException as e:
            for fut in self._waiters.values():
                if not fut.done():
                    fut.set_exception(e)
            self._waiters.clear()
            raise
