"""Slot-based batched CapsuleNet inference engine.

Mirrors ``serve/engine.py``'s admission/refill loop for the paper's own
(non-autoregressive) workload: a fixed number of batch slots share ONE
jit-compiled, plan-driven forward pass.  New requests fill free slots from
the queue each tick; every tick runs the whole batch through the compiled
forward once, so the ExecutionPlan (block shapes, VMEM schedule) is
compiled once and amortized across the request stream.  Inactive slots
carry zero images -- the capsule head is per-sample, so padding never
perturbs active requests.

On the pallas backend the engine compiles the FUSED plan: every routing
layer of the config's graph (the single ClassCaps head, or a deep
ResCaps stack's per-layer instances) is one ``votes_routing`` megakernel
(resident or streamed schedule per the plan's VMEM decision), so no slot
tick ever round-trips a votes tensor through HBM.  The engine is
graph-agnostic -- it serves whatever stack ``compile_plan`` scheduled
for the config.  A caller-supplied plan must be compiled for
``batch >= slots``: the jitted forward always runs all slot rows, so a
smaller plan batch would blow the plan's validated VMEM footprint (or
raise the opaque kernel-level batch error on the first tick) --
``__init__`` rejects it up front, naming both numbers.

Host<->device traffic is tick-size, not batch-size: the slot batch lives
ON DEVICE and only slots dirtied since the last tick (new admissions,
freed slots zeroing out) are uploaded (dirty set padded to the next
power of two so the scatter compiles O(log slots) times, not once per
occupancy); classification finishes on device and the active slots' rows
are gathered INSIDE the jit through a fixed-size padded index, so the
forward traces exactly once no matter how occupancy varies tick to tick
(the old eager ``jnp.take`` compiled a fresh gather per distinct
occupancy count).

Per-request latency (submit -> classified) and engine throughput
(requests/s) are reported by ``stats()``; tests validate slot-batched
outputs against the direct single-request forward.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import capsnet
from repro.core.capsnet import CapsNetConfig
from repro.core.execplan import ExecutionPlan, PlanError, compile_plan


@dataclasses.dataclass
class CapsRequest:
    rid: int
    image: np.ndarray                  # [H, W, C] float in [0, 1]
    submitted_s: float | None = None
    finished_s: float | None = None
    queue_ticks: int = 0               # ticks spent waiting for a slot
    lengths: np.ndarray | None = None  # [num_classes] capsule lengths
    pred: int | None = None

    @property
    def latency_s(self) -> float | None:
        if self.submitted_s is None or self.finished_s is None:
            return None
        return self.finished_s - self.submitted_s


class CapsuleEngine:
    """Continuous-batching CapsNet classifier over a request queue."""

    def __init__(self, params, cfg: CapsNetConfig = CapsNetConfig(), *,
                 slots: int = 8, backend: str = "jnp",
                 interpret: bool = True, plan: ExecutionPlan | None = None):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        if plan is None and backend == "pallas":
            # The engine compiles the PIPELINED plan: the forward runs
            # Conv1 -> one primary_routing megakernel when the combined
            # footprint fits (per-op fallback otherwise).
            plan = compile_plan(cfg, batch=slots, pipeline=True)
        elif plan is not None and plan.batch < slots:
            # The jitted forward runs ALL slot rows every tick; a plan
            # compiled for fewer would either raise the kernel-level
            # votes_routing batch error on the first step() or (jnp path)
            # silently exceed the VMEM footprint the plan validated.
            raise PlanError(
                f"plan compiled for batch {plan.batch} cannot serve "
                f"{slots} slots: every tick runs the full {slots}-row slot "
                f"batch; compile the plan with batch >= slots")
        self.plan = plan          # None on the jnp path unless caller-supplied
        self.active: list[CapsRequest | None] = [None] * slots
        self.queue: deque[CapsRequest] = deque()
        self.finished: list[CapsRequest] = []
        self.ticks = 0
        self._occupancy = 0
        self._started_s: float | None = None
        self._stopped_s: float | None = None
        self._batch = np.zeros(
            (slots, cfg.image_hw, cfg.image_hw, cfg.in_channels), np.float32)
        self._batch_dev = jnp.asarray(self._batch)   # device-resident slots
        self._dirty: set[int] = set()                # slots to re-upload
        self._forward_traces = 0                     # (re)compilations seen

        def fwd(p, images, idx):
            self._forward_traces += 1                # counts traces, not calls
            out = capsnet.forward(p, images, cfg, backend=backend,
                                  plan=self.plan, interpret=interpret)
            # Gather the active slots ON DEVICE through the fixed-size
            # padded index and classify there: one trace for any
            # occupancy, and only slot-count-many result rows ever cross.
            lengths = jnp.take(out["lengths"], idx, axis=0)
            return lengths, jnp.argmax(lengths, axis=-1)

        self._forward = jax.jit(fwd)
        self._scatter = jax.jit(lambda b, i, x: b.at[i].set(x))

    # -- admission -------------------------------------------------------
    def submit(self, req: CapsRequest) -> None:
        """Queue ``req``; rejects images whose layout does not match the
        engine input (a same-size [C, H, W] array would otherwise be
        silently reinterpreted as [H, W, C] garbage)."""
        img = np.asarray(req.image, np.float32)
        want = self._batch.shape[1:]
        if img.shape != want:
            raise ValueError(
                f"request {req.rid}: image shape {img.shape} does not match "
                f"the engine input shape {want} (H, W, C for "
                f"image_hw={self.cfg.image_hw}, "
                f"in_channels={self.cfg.in_channels}); refusing to reshape")
        req.image = img
        req.submitted_s = time.perf_counter()
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self._batch[s] = req.image        # shape-checked in submit()
                self._dirty.add(s)
                self.active[s] = req

    def _upload_dirty(self) -> None:
        """Scatter only the slots dirtied since the last tick into the
        device-resident batch.  The dirty set is padded to the next power
        of two by repeating its last entry (duplicate indices write the
        same row), so the scatter compiles O(log slots) distinct shapes
        instead of one per occupancy delta."""
        dirty = sorted(self._dirty)
        self._dirty.clear()
        n = min(1 << (len(dirty) - 1).bit_length(), self.slots)
        dirty.extend(dirty[-1:] * (n - len(dirty)))
        idx = np.asarray(dirty, np.int32)
        self._batch_dev = self._scatter(self._batch_dev, jnp.asarray(idx),
                                        jnp.asarray(self._batch[idx]))

    # -- main loop -------------------------------------------------------
    def step(self) -> int:
        """One engine tick: admit + classify all active slots.  Returns the
        number of requests completed this tick."""
        if self._started_s is None:
            self._started_s = time.perf_counter()
        self._admit()
        act = [s for s in range(self.slots) if self.active[s] is not None]
        if not act:
            return 0
        if self._dirty:
            self._upload_dirty()
        # Fixed-size index: the active slots, padded by repeating the
        # first (rows past len(act) are ignored positionally below).
        idx = np.full(self.slots, act[0], np.int32)
        idx[:len(act)] = act
        lengths, preds = jax.device_get(
            self._forward(self.params, self._batch_dev, jnp.asarray(idx)))
        now = time.perf_counter()
        for pos, s in enumerate(act):
            req = self.active[s]
            req.lengths = lengths[pos]
            req.pred = int(preds[pos])
            req.finished_s = now
            self.finished.append(req)
            self.active[s] = None
            self._batch[s] = 0.0
            self._dirty.add(s)          # freed slot returns to zero images
        for waiting in self.queue:
            waiting.queue_ticks += 1
        self.ticks += 1
        self._occupancy += len(act)
        self._stopped_s = now
        return len(act)

    def run(self) -> list[CapsRequest]:
        while self.queue or any(a is not None for a in self.active):
            self.step()
        return self.finished

    # -- reporting -------------------------------------------------------
    def stats(self) -> dict:
        n = len(self.finished)
        elapsed = ((self._stopped_s - self._started_s)
                   if self._started_s is not None and self._stopped_s is not None
                   else 0.0)
        lats = [r.latency_s for r in self.finished if r.latency_s is not None]
        return dict(
            requests=n,
            ticks=self.ticks,
            elapsed_s=elapsed,
            requests_per_s=n / elapsed if elapsed > 0 else 0.0,
            mean_latency_ms=1e3 * float(np.mean(lats)) if lats else 0.0,
            max_latency_ms=1e3 * float(np.max(lats)) if lats else 0.0,
            occupancy=(self._occupancy / (self.ticks * self.slots)
                       if self.ticks else 0.0),
        )
