"""Batched serving engine: slot-based continuous batching (vLLM-lite).

A fixed number of batch slots share one batched KV cache.  New requests
prefill into a free slot (a single-slot cache is computed and spliced into
the batch cache); every engine tick decodes one token for ALL active slots
(per-slot cache positions -- ``cache_index`` is a vector).  Finished slots
(EOS / max tokens) free immediately and are refilled from the queue, so
throughput tracks the number of active requests, not the slowest member of
a static batch.

Runs on CPU with smoke-size models in tests; on a mesh the same engine
drives the pjit'd serve_step (slots = global batch).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_model_cache
from repro.models.config import ModelConfig
from repro.models.transformer import forward


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [T] int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    output: list[int] = dataclasses.field(default_factory=list)
    prefill_logits: np.ndarray | None = None


def _merge_cache_slot(full, single, slot):
    """Splice a single-request cache (batch=1) into slot ``slot``."""
    def upd(path, fc, sc):
        names = [getattr(p, "key", None) for p in path]
        ax = 1 if "blocks" in names else 0       # stacked layers lead
        start = [0] * fc.ndim
        start[ax] = slot
        return jax.lax.dynamic_update_slice(fc, sc.astype(fc.dtype),
                                            tuple(start))
    return jax.tree_util.tree_map_with_path(upd, full, single)


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_len: int = 256, cache_dtype=jnp.float32,
                 sampler: Callable | None = None):
        if not cfg.has_decode:
            raise ValueError("encoder-only model has no decode path")
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.cache = init_model_cache(cfg, slots, max_len, cache_dtype)
        self.cache_dtype = cache_dtype
        self.active: list[Request | None] = [None] * slots
        self.lengths = np.zeros(slots, np.int32)
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self.sampler = sampler or (lambda logits: np.argmax(logits, -1))
        self.ticks = 0

        def decode_fn(params, cache, tokens, lengths):
            logits, new_cache, _ = forward(params, tokens, cfg=cfg,
                                           cache=cache, cache_index=lengths)
            return logits[:, -1], new_cache

        def prefill_fn(params, tokens):
            cache = init_model_cache(cfg, 1, max_len, cache_dtype)
            logits, cache, _ = forward(params, tokens, cfg=cfg, cache=cache,
                                       cache_index=jnp.asarray(0, jnp.int32))
            return logits[:, -1], cache

        self._decode = jax.jit(decode_fn)
        self._prefill = jax.jit(prefill_fn)
        self._merge = jax.jit(_merge_cache_slot)

    # -- admission -------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                tokens = jnp.asarray(req.prompt, jnp.int32)[None]
                logits, one_cache = self._prefill(self.params, tokens)
                self.cache = self._merge(self.cache, one_cache,
                                         jnp.asarray(s, jnp.int32))
                req.prefill_logits = np.asarray(logits[0])
                tok = int(self.sampler(np.asarray(logits))[0])
                req.output.append(tok)
                self.active[s] = req
                self.lengths[s] = len(req.prompt)
                self._maybe_finish(s)

    def _maybe_finish(self, s: int) -> None:
        req = self.active[s]
        if req is None:
            return
        last = req.output[-1] if req.output else None
        if (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and last == req.eos_id)
                or self.lengths[s] + 1 >= self.max_len):
            self.finished.append(req)
            self.active[s] = None
            self.lengths[s] = 0

    # -- main loop ---------------------------------------------------------
    def step(self) -> int:
        """One engine tick: admit + decode all active slots.  Returns the
        number of active requests that advanced."""
        self._admit()
        act = [s for s in range(self.slots) if self.active[s] is not None]
        if not act:
            return 0
        tokens = np.zeros((self.slots, 1), np.int32)
        for s in act:
            tokens[s, 0] = self.active[s].output[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.lengths))
        toks = self.sampler(np.asarray(logits))
        for s in act:
            self.lengths[s] += 1
            self.active[s].output.append(int(toks[s]))
            self._maybe_finish(s)
        self.ticks += 1
        return len(act)

    def run(self) -> list[Request]:
        while self.queue or any(a is not None for a in self.active):
            self.step()
        return self.finished
