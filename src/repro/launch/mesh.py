"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; ``make_production_mesh`` is only called from launchers that have
already configured the platform (dryrun sets
``xla_force_host_platform_device_count=512`` before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int | None = None):
    """Small mesh for CPU integration tests (8 forced host devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
