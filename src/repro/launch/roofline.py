"""Roofline analysis from compiled (AOT) artifacts.

Three terms per (arch x shape x mesh), in seconds (deliverable g):

    compute    = HLO_FLOPs_global / (chips * PEAK_FLOPS)
    memory     = HLO_bytes_global / (chips * HBM_BW)
    collective = collective_bytes_per_device / ICI_BW

``cost_analysis()`` reports the per-SPMD-program (= per-device) flops and
bytes, so global = per_device * chips and the chips factor cancels; we
compute directly from per-device numbers and report both.

collective_bytes is NOT in cost_analysis: we parse ``compiled.as_text()``
(post-partitioning HLO) and sum the bytes each collective moves per device
using ring-algorithm accounting:

    all-reduce        2 * B * (S-1)/S        (reduce-scatter + all-gather)
    all-gather        B_out * (S-1)/S        (B_out = gathered shape)
    reduce-scatter    B_out * (S-1)          (input = B_out * S)
    all-to-all        B * (S-1)/S
    collective-permute B

with S = participants per replica group (parsed from the op).
"""

from __future__ import annotations

import dataclasses
import re

# TPU v5e-class hardware constants (per chip).
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_type: dict          # per-device bytes moved, ring accounting
    raw_bytes_by_type: dict      # sum of operand (output) sizes, unscaled

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_type.values())

    @property
    def total_raw_bytes(self) -> float:
        return sum(self.raw_bytes_by_type.values())


def parse_collectives(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    counts = {c: 0 for c in _COLLECTIVES}
    moved = {c: 0.0 for c in _COLLECTIVES}
    raw = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        # ' %name = TYPE op-name(' ; skip -done (paired with -start).
        m = re.search(r"=\s+(.+?)\s+(" + "|".join(_COLLECTIVES)
                      + r")(-start)?\(", line)
        if not m:
            continue
        if re.search(r"(all-reduce|all-gather|all-to-all|collective-permute"
                     r"|reduce-scatter)-done\(", line):
            continue
        type_str, op = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        s = _group_size(line, default_group)
        counts[op] += 1
        raw[op] += b
        if s <= 1 and op != "collective-permute":
            continue
        if op == "all-reduce":
            moved[op] += 2.0 * b * (s - 1) / s
        elif op == "all-gather":
            moved[op] += b * (s - 1) / s
        elif op == "reduce-scatter":
            moved[op] += b * (s - 1)
        elif op == "all-to-all":
            moved[op] += b * (s - 1) / s
        else:  # collective-permute
            moved[op] += b
    return CollectiveStats(counts=counts, bytes_by_type=moved,
                           raw_bytes_by_type=raw)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    model_flops: float           # 6*N*D (train) / 2*N*D (serve), global

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step estimate: max of the three terms (full overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy waste)."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step estimate."""
        denom = self.step_time_s * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
        }


def model_flops_for(cfg, shape, n_params_active: int) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D per generated/scored token."""
    d_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                     else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_params_active * d_tokens


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                                  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:                                  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float))}


def hbm_bytes_estimate(cost: dict, mem: dict) -> float:
    """Prefer cost_analysis 'bytes accessed'; else conservative estimate:
    every argument + output + 2x temp traffic."""
    if "bytes accessed" in cost:
        return cost["bytes accessed"]
    return (mem.get("argument_size_in_bytes", 0)
            + mem.get("output_size_in_bytes", 0)
            + 2.0 * mem.get("temp_size_in_bytes", 0))
