"""Loop-aware analysis of optimized (post-SPMD) HLO text.

XLA:CPU's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, which
undercounts a scanned 40-layer transformer by ~40x.  This module re-derives
the three roofline inputs directly from ``compiled.as_text()`` with loop
trip-count propagation:

  * FLOPs       -- every ``dot`` op: 2 * out_elems * contracted_elems
                   (matmul flops only: the standard MFU convention);
                   ``convolution`` handled best-effort for the CapsNet.
  * HBM bytes   -- post-fusion traffic model: every top-level op reads its
                   operands and writes its output once (fusions already
                   internalize elementwise chains).  In-place ops
                   (dynamic-update-slice) and gathers only count the data
                   actually touched.
  * collectives -- per-type byte counts with ring-algorithm accounting.

Trip counts: a ``while``'s condition computation compares the induction
variable against a constant; we take the max s32 constant found there.
Multipliers propagate through the call graph (while bodies multiply,
fusions/calls don't).
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OPERAND_RE = re.compile(r"(%[\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=(%[\w\.\-]+),\s*body=(%[\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=(%[\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_WINDOW_RE = re.compile(r"window=\{size=([0-9x]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id",
             "while", "conditional", "call", "custom-call", "iota",
             "rng-bit-generator", "opt-barrier"}


def prenorm_types(prenorm_hlo_text: str) -> dict[tuple, set]:
    """Shape-dims -> dtypes present in the post-SPMD, PRE-float-
    normalization HLO (``*.before_float-normalization-bf16.txt`` dump).

    XLA:CPU's float-normalization pass promotes every bf16 computation to
    f32, so the final optimized HLO shows f32 collectives/buffers for
    values that are bf16 in the partitioned program (and stay bf16 on a
    real TPU).  This map lets the analyzer count such tensors at their
    intended width while keeping genuine-f32 tensors (fp32 softmax/norm
    paths, optimizer state) at full width.
    """
    out: dict[tuple, set] = {}
    for dtype, dims in _SHAPE_RE.findall(prenorm_hlo_text):
        if dtype not in _DTYPE_BYTES:
            continue
        key = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.setdefault(key, set()).add(dtype)
    return out


def _elem_bytes(dtype: str, dims: tuple, jt: dict | None) -> int:
    if dtype == "f32" and jt:
        kinds = jt.get(dims) or jt.get(tuple(sorted(dims)))
        if kinds and "bf16" in kinds and "f32" not in kinds:
            return 2          # f32 here is CPU float-normalization artifact
    return _DTYPE_BYTES[dtype]


def _shape_bytes(type_str: str, jt: dict | None = None) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        dims_t = tuple(int(d) for d in dims.split(",")) if dims else ()
        n = 1
        for d in dims_t:
            n *= d
        total += n * _elem_bytes(dtype, dims_t, jt)
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    type_str: str
    line: str
    operands: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    defs: dict[str, str]          # op name -> output type str


def parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    for line in text.splitlines():
        if current is None:
            m = _HEADER_RE.match(line)
            if m:
                name = m.group(1).lstrip("%")
                current = Computation(name=name, ops=[], defs={})
            continue
        if line.startswith("}"):
            comps[current.name] = current
            current = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        paren = line[m.end():]
        # operand names: everything inside the first balanced (...) chunk --
        # approximated by cutting at '), ' attribute boundary.
        cut = paren.split("), ")[0]
        operands = [o for o in _OPERAND_RE.findall(cut) if o != name]
        current.defs[name] = type_str
        current.ops.append(Op(name=name, opcode=opcode, type_str=type_str,
                              line=line, operands=operands))
    return comps


def _entry_name(text: str) -> str:
    m = re.search(r"^ENTRY\s+(%?[\w\.\-]+)", text, re.M)
    return m.group(1).lstrip("%") if m else next(iter(parse_computations(text)))


def _trip_count(cond: Computation) -> int:
    consts = []
    for op in cond.ops:
        consts += [int(c) for c in _CONST_RE.findall(op.line)]
    # also scan raw defs (constants may be non-op lines already captured)
    return max(consts) if consts else 1


def compute_multipliers(comps: dict[str, Computation], entry: str
                        ) -> tuple[dict[str, float], dict[str, str]]:
    """Returns (multiplier, kind) per computation.

    kind: "top" for the entry / while bodies+conds / conditional branches /
    call bodies (their ops touch HBM); "fusion" for fusion bodies (their
    internal ops are register/VMEM-resident -- memory-model excluded, but
    dots inside still count FLOPs).
    """
    mult: dict[str, float] = {name: 0.0 for name in comps}
    kind: dict[str, str] = {}
    if entry not in comps:
        entry = next(iter(comps))
    order: list[tuple[str, float, str]] = [(entry, 1.0, "top")]
    while order:
        name, m, k = order.pop()
        if name not in comps:
            continue
        mult[name] = mult.get(name, 0.0) + m
        # "top" wins if a computation is reachable both ways.
        kind[name] = "top" if kind.get(name) == "top" or k == "top" else k
        comp = comps[name]
        for op in comp.ops:
            wm = _WHILE_RE.search(op.line)
            if wm and op.opcode == "while":
                cond, body = wm.group(1).lstrip("%"), wm.group(2).lstrip("%")
                trip = _trip_count(comps[cond]) if cond in comps else 1
                order.append((body, m * max(trip, 1), "top"))
                order.append((cond, m * max(trip + 1, 1), "top"))
                continue
            cm = _CALLS_RE.search(op.line)
            if cm:
                if op.opcode == "fusion":
                    order.append((cm.group(1).lstrip("%"), m, "fusion"))
                elif op.opcode == "call":
                    order.append((cm.group(1).lstrip("%"), m, "top"))
                # reduce/map/scatter/sort helpers hold no dots/collectives.
                continue
            bm = _BRANCHES_RE.search(op.line)
            if bm:
                for b in _OPERAND_RE.findall(bm.group(1)):
                    order.append((b.lstrip("%"), m, "top"))
    return mult, kind


# ---------------------------------------------------------------------------
# Per-op accounting
# ---------------------------------------------------------------------------

def _dot_flops(op: Op, defs: dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(op.type_str):
        out_elems *= d
    cm = _CONTRACT_RE.search(op.line)
    if not cm or not op.operands:
        return 2.0 * out_elems
    lhs_type = defs.get(op.operands[0], "")
    lhs_dims = _shape_dims(lhs_type)
    contract = 1
    if cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op, defs: dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(op.type_str):
        out_elems *= d
    wm = _WINDOW_RE.search(op.line)
    window = 1
    if wm:
        for d in wm.group(1).split("x"):
            window *= int(d)
    cin = 1
    if len(op.operands) >= 2:
        rhs_dims = _shape_dims(defs.get(op.operands[1], ""))
        if len(rhs_dims) >= 2:
            # kernel elems / output features ~ window * Cin
            total = 1
            for d in rhs_dims:
                total *= d
            out_dims = _shape_dims(op.type_str)
            cout = out_dims[-1] if out_dims else 1
            return 2.0 * out_elems * max(total // max(cout, 1), 1)
    return 2.0 * out_elems * window * cin


def _op_memory_bytes(op: Op, defs: dict[str, str],
                     jt: dict | None = None,
                     comps: dict | None = None) -> float:
    if op.opcode in _SKIP_OPS:
        return 0.0
    out_b = _shape_bytes(op.type_str, jt)
    if op.opcode == "dynamic-update-slice":
        upd = (_shape_bytes(defs.get(op.operands[1], ""), jt)
               if len(op.operands) > 1 else 0.0)
        return 2.0 * upd                      # read-modify-write of the slice
    if op.opcode in ("dynamic-slice", "slice", "gather"):
        return 2.0 * out_b                    # touched data only
    if op.opcode == "fusion" and comps is not None:
        # In-place update fusions (root = dynamic-update-slice) only touch
        # the updated region on real hardware, not the whole buffer --
        # critical for KV caches (scan ys updates of the stacked cache).
        cm = _CALLS_RE.search(op.line)
        body = comps.get(cm.group(1).lstrip("%")) if cm else None
        if body is not None and body.ops:
            root = body.ops[-1]
            if root.opcode == "dynamic-update-slice" \
                    and len(root.operands) > 1:
                upd = _shape_bytes(body.defs.get(root.operands[1], ""), jt)
                # small non-buffer inputs still stream through
                extra = sum(_shape_bytes(defs.get(o, ""), jt)
                            for o in op.operands[1:]
                            if _shape_bytes(defs.get(o, ""), jt) < out_b / 2)
                return 2.0 * max(upd, 1.0) + extra
    in_b = sum(_shape_bytes(defs.get(o, ""), jt) for o in op.operands)
    return out_b + in_b


def _group_size(line: str, default: int = 1) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def _collective_moved(op: Op, s: int, jt: dict | None = None) -> float:
    b = _shape_bytes(op.type_str, jt)
    if s <= 1 and "permute" not in op.opcode:
        return 0.0
    kind = op.opcode.removesuffix("-start")
    if kind == "all-reduce":
        return 2.0 * b * (s - 1) / s
    if kind == "all-gather":
        return b * (s - 1) / s
    if kind == "reduce-scatter":
        return b * (s - 1)
    if kind == "all-to-all":
        return b * (s - 1) / s
    return b                                   # collective-permute


@dataclasses.dataclass
class HLOAnalysis:
    flops: float
    memory_bytes: float
    collective_bytes: float
    collective_counts: dict[str, float]        # weighted by trip count
    collective_bytes_by_type: dict[str, float]
    dot_count: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze_hlo(text: str, prenorm_text: str | None = None) -> HLOAnalysis:
    """``prenorm_text`` (the before_float-normalization-bf16 pass dump)
    enables the bf16 dtype-intent correction for XLA:CPU."""
    jt = prenorm_types(prenorm_text) if prenorm_text else None
    comps = parse_computations(text)
    entry = _entry_name(text)
    mult, kinds = compute_multipliers(comps, entry)

    flops = 0.0
    mem = 0.0
    dot_count = 0.0
    coll_counts = {c: 0.0 for c in COLLECTIVES}
    coll_bytes = {c: 0.0 for c in COLLECTIVES}
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        top_level = kinds.get(name) == "top"
        for op in comp.ops:
            code = op.opcode.removesuffix("-start")
            if op.opcode.endswith("-done"):
                continue
            if code == "dot":
                flops += m * _dot_flops(op, comp.defs)
                dot_count += m
            elif code == "convolution":
                flops += m * _conv_flops(op, comp.defs)
            if code in COLLECTIVES:
                s = _group_size(op.line)
                coll_counts[code] += m
                coll_bytes[code] += m * _collective_moved(op, s, jt)
                mem += m * 2.0 * _shape_bytes(op.type_str, jt)
            elif top_level:
                mem += m * _op_memory_bytes(op, comp.defs, jt, comps)
    return HLOAnalysis(
        flops=flops, memory_bytes=mem,
        collective_bytes=sum(coll_bytes.values()),
        collective_counts=coll_counts,
        collective_bytes_by_type=coll_bytes,
        dot_count=dot_count)
