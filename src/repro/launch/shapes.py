"""Assigned input shapes x skip policy x ShapeDtypeStruct builders.

``train_*`` lowers ``train_step``; ``prefill_*`` lowers ``prefill_step``;
``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len) -- per the assignment.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import init_model, init_model_cache
from repro.models.config import ModelConfig
from repro.train.optimizer import init_opt_state


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str           # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """DESIGN.md Sec. 4 skip policy."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention stack: long_500k requires "
                       "sub-quadratic attention (see DESIGN.md)")
    return True, ""


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders (never allocate)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_specs(cfg: ModelConfig, dtype) -> object:
    shapes = jax.eval_shape(
        lambda k: init_model(k, cfg, dtype=dtype), jax.random.PRNGKey(0))
    return jax.tree_util.tree_map(lambda s: _sds(s.shape, s.dtype), shapes)


def opt_specs(params) -> object:
    shapes = jax.eval_shape(init_opt_state, params)
    return jax.tree_util.tree_map(lambda s: _sds(s.shape, s.dtype), shapes)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, t = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio_frames":
        return {"inputs": _sds((b, t, cfg.frontend_dim), jnp.bfloat16),
                "targets": _sds((b, t), jnp.int32)}
    return {"inputs": _sds((b, t), jnp.int32),
            "targets": _sds((b, t), jnp.int32)}


def cache_specs(cfg: ModelConfig, shape: ShapeSpec,
                dtype=jnp.bfloat16) -> object:
    shapes = jax.eval_shape(
        lambda: init_model_cache(cfg, shape.global_batch, shape.seq_len,
                                 dtype))
    return jax.tree_util.tree_map(lambda s: _sds(s.shape, s.dtype), shapes)


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> tuple:
    """(cache, token, index) for serve_step."""
    token = _sds((shape.global_batch, 1), jnp.int32)
    index = _sds((), jnp.int32)
    return cache_specs(cfg, shape), token, index


def prefill_specs(cfg: ModelConfig, shape: ShapeSpec):
    b, t = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio_frames":
        return _sds((b, t, cfg.frontend_dim), jnp.bfloat16)
    return _sds((b, t), jnp.int32)


def input_specs(cfg: ModelConfig, shape_name: str, *, train_dtype=jnp.float32,
                serve_dtype=jnp.bfloat16) -> dict:
    """All ShapeDtypeStruct stand-ins for one (arch x shape) cell."""
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape_name} skipped: {why}")
    if shape.kind == "train":
        params = param_specs(cfg, train_dtype)
        return {"kind": "train", "params": params,
                "opt_state": opt_specs(params),
                "batch": batch_specs(cfg, shape)}
    params = param_specs(cfg, serve_dtype)
    if shape.kind == "prefill":
        return {"kind": "prefill", "params": params,
                "tokens": prefill_specs(cfg, shape)}
    cache, token, index = decode_specs(cfg, shape)
    return {"kind": "decode", "params": params, "cache": cache,
            "token": token, "index": index}
