"""Step functions lowered by the dry-run and driven by the train/serve
loops: train_step (fwd+bwd+AdamW, mixed precision), prefill_step,
serve_step (single-token decode against a KV cache).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import decode_step, forward, init_model_cache, lm_loss
from repro.models.config import ModelConfig
from repro.train.optimizer import OptConfig, adamw_update


def cast_tree(tree, dtype, min_ndim: int = 1):
    """Cast float leaves (>= min_ndim dims) -- the bf16 compute cast."""
    def c(x):
        if jnp.issubdtype(x.dtype, jnp.floating) and x.ndim >= min_ndim:
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(c, tree)


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, shd=None,
                    compute_dtype=jnp.bfloat16, grad_dtype: str = "fp32",
                    grad_shardings=None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    Params are kept in fp32 (master weights); compute runs in bf16.
    ``grad_dtype="bf16"`` differentiates w.r.t. the bf16-cast params, so
    gradients -- and the data-parallel all-reduce wire format -- are bf16
    (half the collective bytes); the fp32 master update happens in the
    optimizer either way.  ``grad_shardings`` (a NamedSharding tree
    matching params) pins the gradient reduction point BEFORE the
    optimizer's f32 cast, so the partitioner cannot ride the all-reduce
    on the f32 side of the convert.
    """

    def loss_fn(params, batch):
        return lm_loss(params, batch, cfg, shd)

    def train_step(params, opt_state, batch):
        cparams = cast_tree(params, compute_dtype)
        if grad_dtype == "bf16":
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(cparams, batch)
        else:
            def f32_loss(p, batch):
                return loss_fn(cast_tree(p, compute_dtype), batch)
            (_, metrics), grads = jax.value_and_grad(
                f32_loss, has_aux=True)(params, batch)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def make_prefill_step(cfg: ModelConfig, shape_seq: int, shd=None,
                      cache_dtype=jnp.bfloat16):
    """(params, tokens) -> (last_logits, cache) (encoder: (logits, None))."""

    def prefill_step(params, tokens):
        if not cfg.has_decode:
            logits, _, _ = forward(params, tokens, cfg=cfg, shd=shd)
            return logits, None
        cache = init_model_cache(cfg, tokens.shape[0], shape_seq,
                                 cache_dtype)
        logits, cache, _ = forward(params, tokens, cfg=cfg, shd=shd,
                                   cache=cache,
                                   cache_index=jnp.asarray(0, jnp.int32))
        return logits[:, -1], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, shd=None):
    """(params, cache, token, index) -> (next_token, logits, cache)."""

    def serve_step(params, cache, token, index):
        logits, cache = decode_step(params, cache, token, index, cfg,
                                    shd=shd)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], logits[:, -1], cache

    return serve_step
