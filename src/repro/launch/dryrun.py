import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower+compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (sharding
propagates, collectives legal, memory fits) WITHOUT hardware, and extracts
the roofline terms from the compiled artifact:

    python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all --mesh both \
        --out results/dryrun

Results are one JSON per cell consumed by benchmarks/ and EXPERIMENTS.md.
"""  # noqa: E402

import argparse        # noqa: E402
import json            # noqa: E402
import pathlib         # noqa: E402
import shutil          # noqa: E402
import tempfile        # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import LM_ARCHS, get_config  # noqa: E402
from repro.launch import roofline as rf         # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_chips  # noqa: E402
from repro.launch.shapes import (SHAPES, cell_supported,       # noqa: E402
                                 input_specs)
from repro.launch.steps import (make_prefill_step, make_serve_step,  # noqa: E402
                                make_train_step)
from repro.models.config import count_params    # noqa: E402
from repro.parallel.sharding import (ShardingCtx, cache_shardings,  # noqa: E402
                                     make_rules, param_pspecs, zero1_pspecs)
from repro.train.optimizer import OptConfig     # noqa: E402


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _dp_size(mesh, dp_axes) -> int:
    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]
    return n


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               sp: bool = True, kv_mode: str | None = None,
               remat: str | None = None, donate: bool = True,
               fsdp: bool = False, bf16_softmax: bool = False,
               grad_dtype: str = "fp32", bf16_norm: bool = False,
               manual_tp: bool = False):
    """Returns (lowered, compiled, meta) for one cell."""
    import dataclasses

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if bf16_softmax:
        cfg = dataclasses.replace(cfg, attn_fp32_softmax=False)
    if bf16_norm:
        cfg = dataclasses.replace(cfg, norm_fp32=False)
    if manual_tp:
        cfg = dataclasses.replace(cfg, manual_tp=True)
    if kv_mode is None:
        kv_mode = "seq" if shape.name == "long_500k" else "heads"

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(multi_pod=multi_pod, sp=sp, kv_mode=kv_mode)
    shd = ShardingCtx(mesh, rules)
    specs = input_specs(cfg, shape_name)
    dp_axes = rules.dp

    if specs["kind"] == "train":
        if fsdp:
            # FSDP/ZeRO-3-style: params ALSO sharded over dp -> forward
            # all-gathers weights per layer, backward reduce-scatters grads.
            p_shard = _named(mesh, zero1_pspecs(specs["params"], mesh,
                                                dp_axes))
        else:
            p_shard = _named(mesh, param_pspecs(specs["params"]))
        step = make_train_step(cfg, OptConfig(), shd, grad_dtype=grad_dtype,
                               grad_shardings=p_shard
                               if grad_dtype == "bf16" else None)
        o_shard = {"m": _named(mesh, zero1_pspecs(specs["params"], mesh,
                                                  dp_axes)),
                   "v": _named(mesh, zero1_pspecs(specs["params"], mesh,
                                                  dp_axes)),
                   "step": NamedSharding(mesh, P())}
        b_shard = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, P(dp_axes)), specs["batch"])
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1) if donate else ())
        args = (specs["params"], specs["opt_state"], specs["batch"])
    elif specs["kind"] == "prefill":
        step = make_prefill_step(cfg, shape.seq_len, shd)
        p_shard = _named(mesh, param_pspecs(specs["params"]))
        t_shard = NamedSharding(mesh, P(dp_axes))
        jitted = jax.jit(step, in_shardings=(p_shard, t_shard))
        args = (specs["params"], specs["tokens"])
    else:  # decode
        step = make_serve_step(cfg, shd)
        p_shard = _named(mesh, param_pspecs(specs["params"]))
        c_shard = cache_shardings(specs["cache"], rules, mesh)
        b = specs["token"].shape[0]
        tok_shard = NamedSharding(
            mesh, P(dp_axes) if b % _dp_size(mesh, dp_axes) == 0 else P())
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, c_shard, tok_shard,
                          NamedSharding(mesh, P())),
            out_shardings=(tok_shard, None, c_shard),
            donate_argnums=(1,) if donate else ())
        args = (specs["params"], specs["cache"], specs["token"],
                specs["index"])

    t0 = time.time()
    lowered = jitted.lower(*args)
    t1 = time.time()
    # Dump the post-SPMD / pre-float-normalization HLO: the dtype truth
    # source for the roofline (XLA:CPU promotes bf16 compute to f32).
    dump_dir = tempfile.mkdtemp(prefix="xla_prenorm_")
    compiled = lowered.compile(compiler_options={
        "xla_dump_to": dump_dir,
        "xla_dump_hlo_pass_re": "all-reduce-promotion"})
    t2 = time.time()
    # The snapshot BEFORE all-reduce-promotion (a CPU-pipeline pass that
    # wraps bf16 collectives in f32 converts; TPU keeps them bf16) and
    # before float normalization: true program dtypes + real collectives.
    prenorm_text = None
    for f in pathlib.Path(dump_dir).glob("*before_all-reduce-promotion*"):
        prenorm_text = f.read_text()
        break
    shutil.rmtree(dump_dir, ignore_errors=True)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "pod2x16x16" if multi_pod else "pod16x16",
            "chips": mesh_chips(mesh), "sp": sp, "kv_mode": kv_mode,
            "remat": cfg.remat, "fsdp": fsdp, "bf16_softmax": bf16_softmax,
            "grad_dtype": grad_dtype, "bf16_norm": bf16_norm,
            "manual_tp": manual_tp,
            "lower_s": t1 - t0, "compile_s": t2 - t1,
            "dtype_corrected": prenorm_text is not None}
    return lowered, compiled, meta, cfg, shape, prenorm_text


def analyze_cell(compiled, meta, cfg, shape, prenorm_text=None) -> dict:
    from repro.launch.hlo_analysis import analyze_hlo

    mem = rf.memory_analysis_dict(compiled)
    cost = rf.cost_analysis_dict(compiled)
    text = compiled.as_text()
    # Loop-aware flops + HBM bytes from the FINAL (fused) HLO, with the
    # bf16 dtype-intent shape correction; collectives from the post-SPMD
    # PRE-float-normalization dump, whose dtypes are the program's own
    # (XLA:CPU promotes all bf16 compute to f32 -- a real TPU would not).
    hlo = analyze_hlo(text, prenorm_text=prenorm_text)
    if prenorm_text is not None:
        pre = analyze_hlo(prenorm_text)
        hlo.collective_bytes = pre.collective_bytes
        hlo.collective_counts = pre.collective_counts
        hlo.collective_bytes_by_type = pre.collective_bytes_by_type
    n_active = count_params(cfg, active_only=True)
    n_total = count_params(cfg)
    roof = rf.Roofline(
        flops_per_device=hlo.flops,
        hbm_bytes_per_device=hlo.memory_bytes,
        collective_bytes_per_device=hlo.collective_bytes,
        chips=meta["chips"],
        model_flops=rf.model_flops_for(cfg, shape, n_active))
    return {
        **meta,
        "params_total": n_total,
        "params_active": n_active,
        "memory_analysis": mem,
        "cost_analysis_raw": {k: v for k, v in cost.items()
                              if "{" not in k},      # per-op keys dropped
        "collectives": {"counts": hlo.collective_counts,
                        "bytes_by_type": hlo.collective_bytes_by_type,
                        "total_bytes": hlo.collective_bytes},
        "hlo_dot_count": hlo.dot_count,
        "roofline": roof.to_dict(),
        "hlo_bytes": len(text),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir,
             **kw) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    ok, why = cell_supported(cfg, shape)
    base = {"arch": arch, "shape": shape_name, "mesh": mesh_tag}
    if not ok:
        result = {**base, "status": "skipped", "reason": why}
    else:
        try:
            lowered, compiled, meta, cfg2, shp, prenorm = lower_cell(
                arch, shape_name, multi_pod=multi_pod, **kw)
            result = {"status": "ok",
                      **analyze_cell(compiled, meta, cfg2, shp,
                                     prenorm_text=prenorm)}
            del lowered, compiled, prenorm
        except Exception as e:
            result = {**base, "status": "error", "error": repr(e),
                      "traceback": traceback.format_exc()[-4000:]}
    if out_dir is not None:
        out_dir = pathlib.Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        fname = f"{arch}_{shape_name}_{mesh_tag}.json".replace("/", "-")
        (out_dir / fname).write_text(json.dumps(result, indent=1))
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all", choices=["all", *SHAPES])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--sp", dest="sp", action="store_true", default=True)
    ap.add_argument("--no-sp", dest="sp", action="store_false")
    ap.add_argument("--kv-mode", default=None, choices=[None, "heads", "seq"])
    ap.add_argument("--remat", default=None,
                    choices=[None, "full", "dots", "none"])
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--bf16-softmax", action="store_true")
    ap.add_argument("--grad-dtype", default="fp32",
                    choices=["fp32", "bf16"])
    ap.add_argument("--bf16-norm", action="store_true")
    ap.add_argument("--manual-tp", action="store_true")
    args = ap.parse_args()

    archs = LM_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                r = run_cell(arch, shp, mp, args.out, sp=args.sp,
                             kv_mode=args.kv_mode, remat=args.remat,
                             fsdp=args.fsdp,
                             bf16_softmax=args.bf16_softmax,
                             grad_dtype=args.grad_dtype,
                             bf16_norm=args.bf16_norm,
                             manual_tp=args.manual_tp)
                tag = f"{arch:22s} {shp:12s} {'multi' if mp else 'single'}"
                if r["status"] == "ok":
                    roof = r["roofline"]
                    print(f"[ok]   {tag} bottleneck={roof['bottleneck']:10s}"
                          f" step={roof['step_time_s']*1e3:9.3f}ms"
                          f" mfu={roof['mfu']:.3f}"
                          f" compile={r['compile_s']:.1f}s", flush=True)
                elif r["status"] == "skipped":
                    print(f"[skip] {tag} ({r['reason']})", flush=True)
                else:
                    failures += 1
                    print(f"[FAIL] {tag}: {r['error']}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
