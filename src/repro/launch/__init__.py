# Launchers: mesh construction, dry-run (AOT lower+compile), train/serve
# drivers.  NOTE: dryrun must be the process entry point (it pins
# xla_force_host_platform_device_count before jax initializes).
