"""CapStore planner: the TPU adaptation of the paper's DSE (DESIGN.md Sec. 2).

The ASIC paper sizes three on-chip memories (data / weight / accumulator)
to per-operation working sets and gates unused sectors.  On TPU the same
decision is *which Pallas block shape to use*: a kernel's VMEM footprint is

    data tile   : block_m x block_k          (input operand)
    weight tile : block_k x block_n          (stationary operand)
    accum tile  : block_m x block_n @ fp32   (partial sums)

and its HBM traffic (the off-chip accesses of the paper) follows from how
often each operand is re-streamed.  This module runs the paper's
energy-objective DSE over block shapes:

    E = e_hbm * HBM_bytes + e_vmem * VMEM_accesses
        + leak * VMEM_resident_bytes * est_cycles

subject to the footprint fitting the VMEM budget and MXU alignment
(multiples of 128 lanes / 8 sublanes).  ``kernels/ops.py`` uses it to pick
default BlockSpecs; `benchmarks/bench_planner.py` reports the explored
space.  The *unallocated* VMEM is the TPU analogue of a gated-OFF sector.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

# TPU v5e-ish constants (per core).
VMEM_BYTES = 128 * 1024 * 1024 // 8          # 16 MiB VMEM
LANES = 128
SUBLANES = 8
MXU = 128

# Relative energy weights (pJ/byte-ish; only ratios matter for the argmin).
E_HBM = 1.0
E_VMEM = 0.02
E_LEAK = 1e-9      # per resident byte-cycle


@dataclasses.dataclass(frozen=True)
class MatmulWorkload:
    """[M, K] x [K, N] with element sizes in bytes."""

    m: int
    k: int
    n: int
    in_bytes: int = 2        # bf16
    acc_bytes: int = 4       # fp32 accumulation

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.k * self.n


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    block_m: int
    block_k: int
    block_n: int
    vmem_data: int           # bytes: input tile (the paper's data memory)
    vmem_weight: int         # bytes: stationary tile (weight memory)
    vmem_accum: int          # bytes: partials (accumulator memory)
    hbm_bytes: float
    vmem_accesses: float
    energy: float
    est_cycles: float

    @property
    def vmem_total(self) -> int:
        return self.vmem_data + self.vmem_weight + self.vmem_accum

    @property
    def gated_fraction(self) -> float:
        """VMEM left unallocated -- the power-gated-sector analogue."""
        return 1.0 - self.vmem_total / VMEM_BYTES


def _round_up(x: int, to: int) -> int:
    return max(to, math.ceil(x / to) * to)


def _candidates(dim: int, align: int, cap: int = 4096) -> list[int]:
    out = []
    b = align
    while b <= min(_round_up(dim, align), cap):
        out.append(b)
        b *= 2
    return out or [align]


def plan_matmul(w: MatmulWorkload,
                vmem_budget: int = VMEM_BYTES,
                double_buffer: bool = True) -> BlockPlan:
    """Paper-style DSE over block shapes; returns the energy-argmin plan."""
    best: BlockPlan | None = None
    buf = 2 if double_buffer else 1
    for bm in _candidates(w.m, SUBLANES):
        for bk in _candidates(w.k, LANES):
            for bn in _candidates(w.n, LANES):
                tiles_m = math.ceil(w.m / bm)
                tiles_k = math.ceil(w.k / bk)
                tiles_n = math.ceil(w.n / bn)
                data = bm * bk * w.in_bytes * buf
                weight = bk * bn * w.in_bytes * buf
                accum = bm * bn * w.acc_bytes
                total = data + weight + accum
                if total > vmem_budget:
                    continue
                # HBM traffic: LHS streamed once per N-tile column, RHS once
                # per M-tile row, output written once (fp32->bf16 on store).
                # PADDED dims: the lowering zero-pads every operand to the
                # tile grid, and padded rows cross HBM like real ones -- an
                # unpadded model let the DSE pick e.g. block_m=512 over
                # M=576 (1024 padded rows, 78% phantom LHS traffic), drift
                # the static auditor (repro.verify.lowering) flagged.
                # The kernels clamp each block to its axis before padding
                # (bm = min(block_m, m)), so a candidate larger than the
                # whole axis pads to the axis itself, not the candidate.
                m_pad = tiles_m * min(bm, w.m)
                k_pad = tiles_k * min(bk, w.k)
                n_pad = tiles_n * min(bn, w.n)
                hbm = (m_pad * k_pad * w.in_bytes * tiles_n
                       + k_pad * n_pad * w.in_bytes * tiles_m
                       + m_pad * n_pad * w.in_bytes)
                vmem_acc = 2.0 * w.m * w.k * tiles_n + w.m * w.n * tiles_k
                cycles = w.flops / (2 * MXU * MXU)   # MXU-bound estimate
                e = (E_HBM * hbm + E_VMEM * vmem_acc
                     + E_LEAK * total * cycles)
                plan = BlockPlan(bm, bk, bn, data, weight, accum,
                                 hbm, vmem_acc, e, cycles)
                if best is None or plan.energy < best.energy:
                    best = plan
    if best is None:
        raise ValueError(f"no block plan fits VMEM budget for {w}")
    return best


def arithmetic_intensity(plan: BlockPlan, w: MatmulWorkload) -> float:
    return w.flops / max(plan.hbm_bytes, 1.0)


def plan_table(workloads: Sequence[tuple[str, MatmulWorkload]]) -> list[dict]:
    rows = []
    for name, w in workloads:
        p = plan_matmul(w)
        rows.append(dict(
            name=name, m=w.m, k=w.k, n=w.n,
            block=(p.block_m, p.block_k, p.block_n),
            vmem_kib=p.vmem_total / 1024,
            gated_frac=round(p.gated_fraction, 4),
            hbm_mib=p.hbm_bytes / 2**20,
            intensity=round(arithmetic_intensity(p, w), 2),
        ))
    return rows


# Workloads the paper profiles, as TPU matmuls (see analysis.py).
CAPSNET_WORKLOADS: list[tuple[str, MatmulWorkload]] = [
    ("Conv1(im2col)", MatmulWorkload(m=400, k=81, n=256)),
    ("PrimaryCaps(im2col)", MatmulWorkload(m=36, k=20736, n=256)),
    ("ClassCaps-votes", MatmulWorkload(m=1152, k=8, n=160)),
    ("Routing-SumSquash", MatmulWorkload(m=160, k=1152, n=1)),
]
