"""Deterministic, seeded fault injection for chaos-testing the runtime.

The paper's energy story power-gates on-chip memory sectors per
operation, so a production engine must keep serving when the machine
degrades underneath it: VMEM budgets shrink (sectors gated off,
co-tenancy, a conservative PMU policy), kernels emit non-finite outputs,
plans stop compiling, slots corrupt, ticks stall.  This module is the
ONE switchboard those failures are injected through, so the chaos tests
(`tests/test_faults.py`) drive the real recovery paths in
``serve/capsule.py`` (retry/quarantine/circuit-breaker/degraded
replanning), ``train/harness.py`` (NaN rollback, straggler, preemption),
and ``kernels/ops.py`` (poisoned kernel outputs).

Design rules:

* **Zero overhead when disabled.**  Every site guards with
  ``if faults.enabled():`` -- a module-global ``is None`` check -- so
  production code pays one attribute load per site when no injection is
  active, and the fast path allocates nothing.
* **Deterministic.**  A ``FaultSpec`` fires on an index *window*
  (``at <= index < at + times``) against an explicit site index (the
  engine's tick, the training step) or the site's own poll counter --
  never wall clock, never un-seeded randomness.  Choices that need
  randomness (which slot to corrupt) derive from ``spec.seed`` and the
  firing index, so a chaos run replays bit-identically.
* **Scoped.**  ``inject(*specs)`` is a context manager; the registry is
  installed for the ``with`` body and ALWAYS torn down, so a failing
  chaos test cannot leak faults into the rest of the suite.  Nesting is
  refused -- overlapping registries would make ``fired`` logs ambiguous.

Sites currently wired (the string is the ``FaultSpec.site`` key):

=======================  ==================================================
``ops.votes_routing``    fused megakernel wrapper output (eager calls)
``ops.primary_routing``  pipelined pair wrapper output (eager calls)
``ops.conv2d``           conv wrapper output (eager calls)
``ops.caps_votes``       split-path votes wrapper output (eager calls)
``ops.routing``          split-path routing wrapper output (eager calls)
``ops.res_caps_segment`` reversible segment wrapper output (eager calls)
``ops.squash``           squash wrapper output (eager calls)
``ops.rmsnorm``          rmsnorm wrapper output (eager calls)
``ops.flash_attention``  flash-attention wrapper output (eager calls)
``engine.tick``          ``CapsuleEngine`` tick boundary (index = tick)
``engine.forward``       the engine's forward dispatch (index = tick)
``train.step``           ``FaultTolerantLoop`` step boundary (index = step)
=======================  ==================================================

Every public eager kernel wrapper in ``kernels/ops.py`` carries a site:
``repro.verify.lint`` fails the build on a wrapper the chaos suite
cannot reach.

Kinds: ``nan_output`` / ``inf_output`` (poison an output), ``vmem_shrink``
(scale the VMEM budget by ``factor``; the engine replans degraded),
``plan_error`` (raise ``PlanError`` at the site), ``slot_corrupt``
(NaN-fill one seeded active slot's device row), ``stall`` (a tick/step
makes no progress; ``seconds`` inflates the step's measured duration so
straggler detection fires deterministically).

NOTE: the ``ops.*`` sites poison at Python call time.  Inside ``jax.jit``
that means trace time -- the poison would be baked into the compiled
executable -- so chaos tests drive the ops sites eagerly and drive jitted
paths (the engine) through the ``engine.*`` sites instead.
"""

from __future__ import annotations

import contextlib
import dataclasses
from collections import defaultdict
from typing import Iterator

KINDS = ("nan_output", "inf_output", "vmem_shrink", "plan_error",
         "slot_corrupt", "stall")

SITE_VOTES_ROUTING = "ops.votes_routing"
SITE_PRIMARY_ROUTING = "ops.primary_routing"
SITE_CONV2D = "ops.conv2d"
SITE_CAPS_VOTES = "ops.caps_votes"
SITE_ROUTING = "ops.routing"
SITE_RES_CAPS_SEGMENT = "ops.res_caps_segment"
SITE_SQUASH = "ops.squash"
SITE_RMSNORM = "ops.rmsnorm"
SITE_FLASH_ATTENTION = "ops.flash_attention"
SITE_ENGINE_TICK = "engine.tick"
SITE_ENGINE_FORWARD = "engine.forward"
SITE_TRAIN_STEP = "train.step"


class InjectionError(RuntimeError):
    """Misuse of the fault-injection machinery itself (nested ``inject``,
    unknown kind) -- never raised by a *fired* fault."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault: fire ``kind`` at ``site`` for the index
    window ``[at, at + times)``.

    ``at`` / ``times`` index whatever the site polls with -- the engine's
    tick, the training loop's step, or the site's own call counter (for
    the ``ops.*`` kernel-wrapper sites).  ``times=0`` never fires (a
    convenient way to parameterize a storm down to nothing).  ``factor``
    scales the original VMEM budget for ``vmem_shrink``; ``seconds`` is
    the virtual duration a ``stall`` adds to a training step; ``seed``
    drives any random choice the firing makes (e.g. which active slot
    ``slot_corrupt`` poisons).
    """

    site: str
    kind: str
    at: int = 0
    times: int = 1
    factor: float = 0.5
    seconds: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise InjectionError(
                f"unknown fault kind {self.kind!r} (kinds: {KINDS})")
        if self.times < 0:
            raise InjectionError(f"times must be >= 0, got {self.times}")
        if self.kind == "vmem_shrink" and not 0.0 < self.factor <= 1.0:
            raise InjectionError(
                f"vmem_shrink factor must be in (0, 1], got {self.factor}")

    def fires_at(self, index: int) -> bool:
        return self.times > 0 and self.at <= index < self.at + self.times


class FaultRegistry:
    """The active fault set plus a log of every firing.

    ``poll(site)`` is the one read path: returns the specs firing at the
    given index (or the site's own monotonically-advancing poll counter
    when no index is passed) and records each firing in ``fired`` as
    ``(site, kind, index)`` so tests can assert exactly what was
    injected where.
    """

    def __init__(self, specs: tuple[FaultSpec, ...]):
        self.specs = tuple(specs)
        self.fired: list[tuple[str, str, int]] = []
        self._counters: defaultdict[str, int] = defaultdict(int)

    def poll(self, site: str, *, index: int | None = None,
             kinds: tuple[str, ...] | None = None) -> tuple[FaultSpec, ...]:
        if index is None:
            index = self._counters[site]
            self._counters[site] += 1
        hits = tuple(s for s in self.specs
                     if s.site == site and s.fires_at(index)
                     and (kinds is None or s.kind in kinds))
        self.fired.extend((site, s.kind, index) for s in hits)
        return hits

    def count(self, site: str | None = None,
              kind: str | None = None) -> int:
        """Number of recorded firings, optionally filtered."""
        return sum(1 for (s, k, _) in self.fired
                   if (site is None or s == site)
                   and (kind is None or k == kind))


_ACTIVE: FaultRegistry | None = None


def enabled() -> bool:
    """True iff an ``inject`` context is active (the sites' fast-path
    guard: one global load, nothing else)."""
    return _ACTIVE is not None


def registry() -> FaultRegistry | None:
    return _ACTIVE


@contextlib.contextmanager
def inject(*specs: FaultSpec) -> Iterator[FaultRegistry]:
    """Activate ``specs`` for the ``with`` body; always tears down."""
    global _ACTIVE
    if _ACTIVE is not None:
        raise InjectionError(
            "fault injection is already active; nested inject() would make "
            "the fired log ambiguous -- compose specs into one registry")
    reg = FaultRegistry(specs)
    _ACTIVE = reg
    try:
        yield reg
    finally:
        _ACTIVE = None


def poll(site: str, *, index: int | None = None,
         kinds: tuple[str, ...] | None = None) -> tuple[FaultSpec, ...]:
    """Site-level poll: () when injection is disabled."""
    reg = _ACTIVE
    if reg is None:
        return ()
    return reg.poll(site, index=index, kinds=kinds)


def corrupt_array(site: str, x):
    """Kernel-wrapper site: return ``x`` poisoned (all-NaN / all-Inf) when
    a matching output fault fires, raise ``PlanError`` on ``plan_error``,
    and return ``x`` UNTOUCHED (the same object) otherwise.  Advances the
    site's poll counter once per call."""
    reg = _ACTIVE
    if reg is None:
        return x
    hits = reg.poll(site, kinds=("nan_output", "inf_output", "plan_error"))
    for spec in hits:
        if spec.kind == "plan_error":
            from repro.core.execplan import PlanError
            raise PlanError(f"injected plan_error at {site}")
    for spec in hits:
        import jax.numpy as jnp
        fill = jnp.nan if spec.kind == "nan_output" else jnp.inf
        return jnp.full_like(x, fill)
    return x
