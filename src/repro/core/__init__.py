"""CapStore core: the paper's contribution.

- ``capsnet``:  CapsuleNet inference/training in pure JAX (+ Pallas backend).
- ``analysis``: CapsAcc dataflow model -> per-op memory/cycles/accesses (Fig 4).
- ``energy``:   CACTI-P-flavoured SRAM/DRAM energy+area model (32 nm).
- ``dse``:      memory-organization design space exploration (Tables 1/2).
- ``pmu``:      application-aware power management (sector power gating).
- ``planner``:  the TPU adaptation -- CapStore DSE over Pallas block shapes.
- ``execplan``: ONE compiled per-operation plan (blocks + VMEM footprints +
  PMU phases) shared by the kernels, the energy model, and serving.
- ``faults``:   deterministic fault injection (chaos tests drive the
  serving/training graceful-degradation paths through it).
"""

from repro.core import (analysis, capsnet, dse, energy, execplan,  # noqa: F401
                        faults, planner, pmu)
