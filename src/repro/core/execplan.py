"""ExecutionPlan: ONE compiled schedule shared by kernels, PMU, and serving.

CapStore's core contribution is a single per-operation schedule that sizes
each on-chip memory and drives power-gating from it (paper Secs. 4.1-4.3).
Before this module the repo had three parallel models of that schedule:
``kernels/ops.py`` re-ran the block-shape DSE per call, ``core/dse.py``
derived PMU phases from the analysis profiles, and ``core/capsnet.py``
ignored both.  ``compile_plan`` unifies them: it compiles a
``CapsNetConfig`` into per-operation

  * Pallas block shapes (``planner.plan_matmul`` energy-argmin DSE),
  * VMEM footprints (checked against the budget -- the TPU analogue of
    the paper's sized-to-fit SRAMs),
  * estimated cycles, and
  * auto-derived ``PhaseRequirement``s (analysis.py dataflow model)

so the schedule the kernels *execute* is the same schedule the PMU/energy
model *scores* (``pmu.schedule_from_plan``, ``dse.explore(plan=...)``) and
the serving engine *amortizes* (``serve/capsule.py``).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

from repro.core import analysis
from repro.core.analysis import CapsNetDims, OperationProfile
from repro.core.capsnet import CapsNetConfig
from repro.core.planner import (MXU, VMEM_BYTES, BlockPlan, MatmulWorkload,
                                plan_matmul)
from repro.core.pmu import PhaseRequirement

# Kernels run in fp32 (interpret-mode validated; fp32 accumulation on TPU).
ELEM_BYTES = 4
SQUASH_BLOCK_ROWS = 1024

# The fused ClassCaps megakernel: ONE plan op / PMU phase covering the
# dataflow model's ClassCaps-FC + Sum+Squash + Update+Sum operations.
FUSED_NAME = "ClassCaps-Routing"
FUSED_COVERS = ("ClassCaps-FC", "Sum+Squash", "Update+Sum")


class PlanError(ValueError):
    """An ExecutionPlan violates one of its invariants."""


@dataclasses.dataclass(frozen=True)
class OpPlan:
    """The compiled schedule entry for one CapsuleNet operation.

    ``kernel`` names the executor -- all Pallas: ``conv_im2col``
    (optionally ``+squash`` when the primary-capsule activation fuses into
    the epilogue) and the fused ``votes_routing`` megakernel.  Matmul-view
    operations carry the planner's energy-argmin ``block``; its
    ``block_m/k/n`` (conv) and ``block_i`` / ``block_rows`` are the
    concrete grid tiles the kernel wrappers consume.  ``requirement`` is
    the PMU phase (ASIC dataflow-model bytes/cycles) the gating schedule
    is built from; a fused op covers several dataflow-model operations
    (``profiles``) with ONE phase -- the schedule it actually executes.

    ``mode`` is the fused kernel's plan-chosen schedule (``resident`` /
    ``streamed``); ``hbm_bytes`` is the op's modeled HBM traffic per
    forward at the plan batch and ``uhat_hbm_bytes`` the share of it spent
    on the votes intermediate (0 for the fused kernel -- the point).
    """

    name: str
    kernel: str
    workload: MatmulWorkload | None
    block: BlockPlan | None
    vmem_bytes: int
    est_cycles: float
    requirement: PhaseRequirement
    profiles: tuple[OperationProfile, ...]
    block_i: int | None = None
    block_rows: int | None = None
    mode: str | None = None
    hbm_bytes: float | None = None
    uhat_hbm_bytes: float | None = None

    @property
    def profile(self) -> OperationProfile:
        """The primary dataflow profile (first of ``profiles``)."""
        return self.profiles[0]

    @property
    def fuses_squash(self) -> bool:
        """Whether this op's epilogue absorbs the squash activation."""
        return self.kernel.endswith("+squash")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    cfg: CapsNetConfig
    batch: int
    dataflow: str
    vmem_budget: int
    ops: tuple[OpPlan, ...]

    def op(self, name: str) -> OpPlan:
        for op in self.ops:
            if op.name == name:
                return op
        raise KeyError(f"no operation {name!r} in plan "
                       f"({[o.name for o in self.ops]})")

    @property
    def profiles(self) -> tuple[OperationProfile, ...]:
        """The dataflow profiles this plan was compiled from (feeds dse).

        Fused ops contribute every profile they cover, so this is always
        the full five-operation paper model regardless of fusion.
        """
        return tuple(p for op in self.ops for p in op.profiles)

    def phase_requirements(self) -> tuple[PhaseRequirement, ...]:
        """Per-operation PMU phases, in execution order.

        One phase per EXECUTED op: the fused ClassCaps megakernel is a
        single phase, so the gating schedule scores what actually runs.
        """
        return tuple(op.requirement for op in self.ops)

    def phase_groups(self) -> tuple[tuple[str, tuple[str, ...]], ...]:
        """(phase_name, covered profile names) per executed op -- lets the
        organization DSE (``dse.evaluate_plan``) gate over the fused
        phases the kernels execute instead of the raw five-op model."""
        return tuple((op.name, tuple(p.name for p in op.profiles))
                     for op in self.ops)

    @property
    def peak_vmem_bytes(self) -> int:
        return max(op.vmem_bytes for op in self.ops)

    def validate(self) -> None:
        """Check the plan invariants; raises ``PlanError`` on violation."""
        if self.batch < 1:
            raise PlanError(f"batch must be >= 1, got {self.batch}")
        names = [op.name for op in self.ops]
        if len(set(names)) != len(names):
            raise PlanError(f"duplicate operation names: {names}")
        covered = [p.name for op in self.ops for p in op.profiles]
        expected = [p.name for p in
                    analysis.capsnet_profiles(self.dataflow,
                                              analysis.dims_from_config(self.cfg))]
        if covered != expected:
            raise PlanError(
                f"phases {names} cover {covered}, not operations {expected}")
        for op in self.ops:
            if op.mode is not None and op.mode not in ("resident", "streamed"):
                raise PlanError(f"{op.name}: unknown mode {op.mode!r}")
            if op.vmem_bytes > self.vmem_budget:
                raise PlanError(
                    f"{op.name}: VMEM footprint {op.vmem_bytes} exceeds "
                    f"budget {self.vmem_budget}")
            if op.requirement.name != op.name:
                raise PlanError(f"{op.name}: phase named {op.requirement.name!r}")
            if op.requirement.duration_cycles <= 0:
                raise PlanError(f"{op.name}: non-positive phase duration")
            if op.block is not None and op.block.vmem_total > self.vmem_budget:
                raise PlanError(f"{op.name}: block tiles exceed VMEM budget")
            if op.block_i is not None and not (
                    1 <= op.block_i <= max(self.cfg.num_primary, 1)):
                raise PlanError(f"{op.name}: block_i {op.block_i} out of range")

    def summary(self) -> list[dict]:
        rows = []
        for op in self.ops:
            rows.append(dict(
                name=op.name,
                kernel=op.kernel,
                block=((op.block.block_m, op.block.block_k, op.block.block_n)
                       if op.block else None),
                block_i=op.block_i,
                block_rows=op.block_rows,
                mode=op.mode,
                vmem_kib=op.vmem_bytes / 1024,
                est_cycles=op.est_cycles,
                hbm_bytes=op.hbm_bytes,
                uhat_hbm_bytes=op.uhat_hbm_bytes,
                req_kib=op.requirement.required_bytes / 1024,
                duration_cycles=op.requirement.duration_cycles,
            ))
        return rows


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------

def _requirement(profile: OperationProfile) -> PhaseRequirement:
    return PhaseRequirement(name=profile.name,
                            required_bytes=profile.total_mem,
                            duration_cycles=profile.total_cycles)


def _votes_vmem(batch: int, block_i: int, caps_dim: int, out_dim: int) -> int:
    """caps_votes footprint per grid step (double-buffered streams)."""
    data = batch * block_i * caps_dim * ELEM_BYTES
    weight = block_i * out_dim * caps_dim * ELEM_BYTES
    accum = batch * block_i * out_dim * ELEM_BYTES
    return 2 * (data + weight) + accum


def _votes_max_batch(caps_dim: int, out_dim: int, vmem_budget: int) -> int:
    """Largest batch whose block_i=1 caps-votes footprint fits the budget."""
    fixed = 2 * out_dim * caps_dim * ELEM_BYTES          # weight tile
    per_batch = (2 * caps_dim + out_dim) * ELEM_BYTES    # data + accum rows
    return max((vmem_budget - fixed) // per_batch, 0)


def _votes_block_i_raw(num_caps: int, caps_dim: int, out_dim: int,
                       batch: int, vmem_budget: int) -> int:
    """Split-path caps-votes i-tile: planner pick shrunk to the budget at
    the REAL batch (the memoized plan-less wrapper in ``kernels/ops.py``
    shares this, so a batched call can no longer exceed the footprint the
    planner guarantees).  Raises ``PlanError`` when even ``block_i=1``
    exceeds the budget (instead of letting ``validate()`` fail later with
    a generic footprint message)."""
    wl = MatmulWorkload(m=num_caps, k=caps_dim, n=out_dim)
    block = plan_matmul(wl, vmem_budget)
    bi = max(min(block.block_m, num_caps), 1)
    while bi > 1 and _votes_vmem(batch, bi, caps_dim, out_dim) > vmem_budget:
        bi //= 2
    need = _votes_vmem(batch, bi, caps_dim, out_dim)
    if need > vmem_budget:
        raise PlanError(
            f"ClassCaps-FC: no feasible schedule at batch={batch}: even "
            f"block_i=1 needs {need} B of VMEM, over the {vmem_budget} B "
            f"budget; largest feasible batch is "
            f"{_votes_max_batch(caps_dim, out_dim, vmem_budget)}")
    return bi


# ---------------------------------------------------------------------------
# Fused votes+routing schedule (the megakernel's resident-vs-streamed DSE)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VotesRoutingSchedule:
    """Plan decision for the fused ``votes_routing`` megakernel."""

    mode: str                # "resident" | "streamed"
    block_i: int
    vmem_bytes: int          # footprint of the CHOSEN schedule
    n_passes: int            # W streams: 1 resident, 2*iters+1 streamed
    workload: MatmulWorkload


def _i_padded(num_caps: int, block_i: int) -> int:
    return math.ceil(num_caps / block_i) * block_i


def _fused_resident_vmem(batch: int, num_caps: int, block_i: int,
                         caps_dim: int, jd: int, j: int) -> int:
    """Resident schedule: the full votes tensor + routing logits live in
    VMEM scratch while double-buffered u/W i-tiles stream past once; each
    grid step also materializes one [B, block_i, J*D] votes block before
    storing it into the scratch."""
    i_pad = _i_padded(num_caps, block_i)
    votes = batch * i_pad * jd
    logits = batch * i_pad * j
    tiles = 2 * (batch * block_i * caps_dim + block_i * jd * caps_dim)
    uh_block = batch * block_i * jd
    out = batch * jd
    return (votes + logits + tiles + uh_block + out) * ELEM_BYTES


def _fused_streamed_vmem(batch: int, num_caps: int, block_i: int,
                         caps_dim: int, jd: int, j: int) -> int:
    """Streamed schedule: only u (fetched once), the logits, and the s/v
    candidates stay resident; W tiles stream (double-buffered) each pass,
    and every step recomputes one [B, block_i, J*D] votes block."""
    i_pad = _i_padded(num_caps, block_i)
    u_res = batch * i_pad * caps_dim
    logits = batch * i_pad * j
    w_tile = 2 * block_i * jd * caps_dim
    uh_block = batch * block_i * jd
    sv = 2 * batch * jd
    out = batch * jd
    return (u_res + logits + w_tile + uh_block + sv + out) * ELEM_BYTES


def plan_votes_routing(num_caps: int, caps_dim: int, jd: int, j: int, *,
                       batch: int = 1, iters: int = 3,
                       vmem_budget: int = VMEM_BYTES) -> VotesRoutingSchedule:
    """Resident-vs-streamed decision for the fused megakernel.

    Prefer **resident** (votes computed once into scratch, routing
    iterates on-chip -- the split path's behavior minus the u_hat HBM
    round-trip); fall back to **streamed** (votes recomputed from
    re-streamed W tiles each pass) when the votes tensor cannot fit the
    budget at any i-tile.  Raises ``PlanError`` only when even streamed
    ``block_i=1`` exceeds the budget -- the point where no schedule can
    keep the routing state on-chip at this batch.
    """
    wl = MatmulWorkload(m=num_caps, k=caps_dim, n=jd, in_bytes=ELEM_BYTES)
    # Tile-shape pick only (our per-mode footprint model is what is held
    # to the budget, not the generic double-buffered matmul model).
    bi0 = max(min(plan_matmul(wl).block_m, num_caps), 1)

    bi = bi0
    while bi > 1 and _fused_resident_vmem(batch, num_caps, bi, caps_dim,
                                          jd, j) > vmem_budget:
        bi //= 2
    need = _fused_resident_vmem(batch, num_caps, bi, caps_dim, jd, j)
    if need <= vmem_budget:
        return VotesRoutingSchedule(mode="resident", block_i=bi,
                                    vmem_bytes=need, n_passes=1, workload=wl)

    bi = bi0
    while bi > 1 and _fused_streamed_vmem(batch, num_caps, bi, caps_dim,
                                          jd, j) > vmem_budget:
        bi //= 2
    need = _fused_streamed_vmem(batch, num_caps, bi, caps_dim, jd, j)
    if need > vmem_budget:
        raise PlanError(
            f"{FUSED_NAME}: no feasible schedule at batch={batch}: even "
            f"streamed block_i=1 needs {need} B of VMEM, over the "
            f"{vmem_budget} B budget")
    return VotesRoutingSchedule(mode="streamed", block_i=bi, vmem_bytes=need,
                                n_passes=2 * iters + 1, workload=wl)


def votes_routing_hbm_bytes(batch: int, num_caps: int, caps_dim: int,
                            jd: int, n_passes: int) -> float:
    """Modeled HBM traffic of the fused megakernel per forward: u read
    once, W streamed ``n_passes`` times, v written once -- and NO u_hat
    term (the tensor never exists off-chip)."""
    u = batch * num_caps * caps_dim
    w = num_caps * jd * caps_dim * n_passes
    v = batch * jd
    return float((u + w + v) * ELEM_BYTES)


def split_votes_routing_hbm_bytes(batch: int, num_caps: int, caps_dim: int,
                                  jd: int) -> tuple[float, float]:
    """(total, u_hat share) of the split ``caps_votes`` -> ``routing``
    path: the votes tensor is written by one kernel and read back by the
    next -- the produce-once/consume-once round-trip the fusion kills."""
    u = batch * num_caps * caps_dim
    w = num_caps * jd * caps_dim
    v = batch * jd
    uhat = 2 * batch * num_caps * jd                 # write + read back
    return float((u + w + v + uhat) * ELEM_BYTES), float(uhat * ELEM_BYTES)


def _conv_patch_vmem(in_hw: int, cin: int, k: int, out_hw: int) -> int:
    """im2col patch-extraction footprint per grid step (one batch element):
    the resident input feature map plus the emitted patch matrix."""
    image = in_hw * in_hw * cin * ELEM_BYTES
    patches = out_hw * out_hw * k * k * cin * ELEM_BYTES
    return image + patches


def _fused_requirement(dims: CapsNetDims,
                       profs: Sequence[OperationProfile],
                       sched: VotesRoutingSchedule) -> PhaseRequirement:
    """ONE PMU phase for the fused megakernel, honest per mode.

    Resident keeps the ClassCaps votes in the accumulator memory across
    routing, so the phase demand is the peak of the three covered
    dataflow operations.  Streamed never materializes the votes: the
    demand is u + logits/couplings + the W prefetch buffer + the s/v
    candidates (dataflow-model byte widths).
    """
    cc, ss, us = profs
    duration = cc.total_cycles + ss.total_cycles + us.total_cycles
    if sched.mode == "resident":
        req = max(cc.total_mem, ss.total_mem, us.total_mem)
    else:
        bij = dims.num_primary * dims.num_classes
        jd = dims.num_classes * dims.class_dim
        req = (cc.data_mem                                    # u resident
               + bij * (analysis.ACC_BYTES + analysis.ACT_BYTES)  # b + c
               + cc.weight_mem                                # W prefetch
               + 4 * jd * analysis.ACC_BYTES)                 # s/v temps
    return PhaseRequirement(name=FUSED_NAME, required_bytes=req,
                            duration_cycles=duration)


@functools.lru_cache(maxsize=64)
def compile_plan(cfg: CapsNetConfig = CapsNetConfig(), *, batch: int = 1,
                 vmem_budget: int = VMEM_BYTES,
                 dataflow: str = "resident") -> ExecutionPlan:
    """Compile ``cfg`` into the per-operation ExecutionPlan (memoized:
    plans are immutable and the block-shape DSE runs once per shape).

    The five analysis operations map onto executors as follows:

      Conv1, PrimaryCaps -> ``conv_im2col`` kernels (strided Pallas patch
                            extraction + blocked matmul over the planner's
                            block_m/k/n tiles; PrimaryCaps fuses the squash
                            activation into the epilogue when its n-tile is
                            capsule-aligned)
      ClassCaps-FC,
      Sum+Squash,
      Update+Sum         -> ONE fused ``votes_routing`` megakernel (votes
                            from streamed W i-blocks + every routing
                            iteration in VMEM scratch -- u_hat never
                            touches HBM; ``plan_votes_routing`` picks the
                            resident or streamed schedule per config)

    ``requirement``s (PMU phases) keep the paper's per-inference dataflow
    model -- one phase per EXECUTED op, so the fused megakernel is scored
    as the single phase it runs; ``vmem_bytes`` scale with ``batch``
    where the kernel batches.
    """
    dims = analysis.dims_from_config(cfg)
    profiles = analysis.capsnet_profiles(dataflow, dims)
    by_name = {p.name: p for p in profiles}
    ops: list[OpPlan] = []

    # Conv stack: im2col matmuls the kernels EXECUTE with the planned
    # tiles.  Workloads carry the real batched row count and fp32 element
    # size so ``block.vmem_total`` is the honest double-buffered footprint
    # (patch tile + weight tile + accumulator) of the running kernel.
    conv_wls = {
        "Conv1": MatmulWorkload(m=batch * dims.conv1_out ** 2,
                                k=dims.conv1_k ** 2 * dims.conv1_cin,
                                n=dims.conv1_cout, in_bytes=ELEM_BYTES),
        "PrimaryCaps": MatmulWorkload(m=batch * dims.pc_out ** 2,
                                      k=dims.pc_k ** 2 * dims.pc_cin,
                                      n=dims.pc_cout, in_bytes=ELEM_BYTES),
    }
    conv_patch = {
        "Conv1": _conv_patch_vmem(dims.in_hw, dims.conv1_cin, dims.conv1_k,
                                  dims.conv1_out),
        "PrimaryCaps": _conv_patch_vmem(dims.conv1_out, dims.pc_cin,
                                        dims.pc_k, dims.pc_out),
    }
    squash_rows = batch * dims.num_primary
    block_rows = max(min(SQUASH_BLOCK_ROWS, squash_rows), 1)
    for name, wl in conv_wls.items():
        prof = by_name[name]
        block = plan_matmul(wl, vmem_budget)
        bias_tile = 2 * block.block_n * ELEM_BYTES
        op = OpPlan(name=name, kernel="conv_im2col", workload=wl, block=block,
                    vmem_bytes=max(block.vmem_total + bias_tile,
                                   conv_patch[name]),
                    est_cycles=block.est_cycles,
                    requirement=_requirement(prof), profiles=(prof,),
                    hbm_bytes=block.hbm_bytes)
        if name == "PrimaryCaps":
            # The primary-capsule squash activation rides on this op: fused
            # into the matmul epilogue when every n-tile holds whole
            # capsules (the kernel clamps the tile to N), otherwise a
            # standalone blocked squash pass.
            if min(block.block_n, wl.n) % dims.primary_dim == 0:
                op = dataclasses.replace(op, kernel="conv_im2col+squash",
                                         block_rows=block_rows)
            else:
                op = dataclasses.replace(
                    op, block_rows=block_rows,
                    vmem_bytes=max(op.vmem_bytes,
                                   2 * block_rows * dims.primary_dim
                                   * ELEM_BYTES))
        ops.append(op)

    # ClassCaps head: ONE fused votes+routing megakernel.  The resident
    # schedule is the split path minus the u_hat HBM round-trip; streamed
    # recomputes the votes from re-streamed W tiles when they cannot fit.
    fused_profs = tuple(by_name[n] for n in FUSED_COVERS)
    jd = dims.num_classes * dims.class_dim
    sched = plan_votes_routing(dims.num_primary, dims.primary_dim, jd,
                               dims.num_classes, batch=batch,
                               iters=dims.routing_iters,
                               vmem_budget=vmem_budget)
    votes_cycles = sched.workload.flops / (2 * MXU * MXU)
    routing_cycles = sum(p.total_cycles for p in fused_profs[1:])
    ops.append(OpPlan(
        name=FUSED_NAME, kernel="votes_routing", workload=sched.workload,
        block=None, block_i=sched.block_i, mode=sched.mode,
        vmem_bytes=sched.vmem_bytes,
        est_cycles=votes_cycles * sched.n_passes + routing_cycles,
        hbm_bytes=votes_routing_hbm_bytes(batch, dims.num_primary,
                                          dims.primary_dim, jd,
                                          sched.n_passes),
        uhat_hbm_bytes=0.0,
        requirement=_fused_requirement(dims, fused_profs, sched),
        profiles=fused_profs))

    plan = ExecutionPlan(cfg=cfg, batch=batch, dataflow=dataflow,
                         vmem_budget=vmem_budget, ops=tuple(ops))
    plan.validate()
    return plan


def plan_table(plans: Sequence[tuple[str, ExecutionPlan]]) -> list[dict]:
    """Flat summary rows for benchmarks/examples."""
    rows = []
    for tag, plan in plans:
        for r in plan.summary():
            rows.append(dict(plan=tag, **r))
    return rows
