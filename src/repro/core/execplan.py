"""ExecutionPlan: ONE compiled schedule shared by kernels, PMU, and serving.

CapStore's core contribution is a single per-operation schedule that sizes
each on-chip memory and drives power-gating from it (paper Secs. 4.1-4.3).
Before this module the repo had three parallel models of that schedule:
``kernels/ops.py`` re-ran the block-shape DSE per call, ``core/dse.py``
derived PMU phases from the analysis profiles, and ``core/capsnet.py``
ignored both.  ``compile_plan`` unifies them: it compiles a
``CapsNetConfig`` into per-operation

  * Pallas block shapes (``planner.plan_matmul`` energy-argmin DSE),
  * VMEM footprints (checked against the budget -- the TPU analogue of
    the paper's sized-to-fit SRAMs),
  * estimated cycles, and
  * auto-derived ``PhaseRequirement``s (analysis.py dataflow model)

so the schedule the kernels *execute* is the same schedule the PMU/energy
model *scores* (``pmu.schedule_from_plan``, ``dse.explore(plan=...)``) and
the serving engine *amortizes* (``serve/capsule.py``).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

from repro.core import analysis
from repro.core.analysis import OperationProfile
from repro.core.capsnet import CapsNetConfig
from repro.core.planner import (MXU, VMEM_BYTES, BlockPlan, MatmulWorkload,
                                plan_matmul)
from repro.core.pmu import PhaseRequirement

# Kernels run in fp32 (interpret-mode validated; fp32 accumulation on TPU).
ELEM_BYTES = 4
SQUASH_BLOCK_ROWS = 1024

# The fused ClassCaps megakernel: ONE plan op / PMU phase covering the
# dataflow model's ClassCaps-FC + Sum+Squash + Update+Sum operations.
FUSED_NAME = "ClassCaps-Routing"
FUSED_COVERS = ("ClassCaps-FC", "Sum+Squash", "Update+Sum")

# The pipelined producer->consumer pair: PrimaryCaps' squash-epilogue
# output feeds the votes/routing megakernel straight from VMEM scratch,
# so the inter-layer activation u never round-trips HBM (the paper's
# inter-layer on-chip residency -- DESCNet's scratchpad, CapsAcc's
# cross-layer reuse).  ONE plan op / PMU phase covering four dataflow
# operations.
PIPE_NAME = "PrimaryCaps-Routing"
PIPE_COVERS = ("PrimaryCaps",) + FUSED_COVERS

# Training plans append one backward OpPlan per executed kernel, named
# "<op>-bwd" and listed in reverse network order (the order the backward
# actually runs), so dse/pmu gate the backward phases like the forward's.
BWD_SUFFIX = "-bwd"


class PlanError(ValueError):
    """An ExecutionPlan violates one of its invariants."""


@dataclasses.dataclass(frozen=True)
class OpPlan:
    """The compiled schedule entry for one CapsuleNet operation.

    ``kernel`` names the executor -- all Pallas: ``conv_im2col``
    (optionally ``+squash`` when the primary-capsule activation fuses into
    the epilogue) and the fused ``votes_routing`` megakernel.  Matmul-view
    operations carry the planner's energy-argmin ``block``; its
    ``block_m/k/n`` (conv) and ``block_i`` / ``block_rows`` are the
    concrete grid tiles the kernel wrappers consume.  ``requirement`` is
    the PMU phase (ASIC dataflow-model bytes/cycles) the gating schedule
    is built from; a fused op covers several dataflow-model operations
    (``profiles``) with ONE phase -- the schedule it actually executes.

    ``mode`` is the fused kernel's plan-chosen schedule (``resident`` /
    ``streamed``); ``hbm_bytes`` is the op's modeled HBM traffic per
    forward at the plan batch and ``uhat_hbm_bytes`` the share of it spent
    on the votes intermediate (0 for the fused kernel -- the point).
    ``intermediate_hbm_bytes`` is the traffic this op's OUTPUT pays to
    reach its consumer: the write+read round-trip on a per-op plan, 0 on
    a pipelined pair (the consumer reads the producer's VMEM scratch --
    the inter-layer analogue of ``uhat_hbm_bytes``).
    """

    name: str
    kernel: str
    workload: MatmulWorkload | None
    block: BlockPlan | None
    vmem_bytes: int
    est_cycles: float
    requirement: PhaseRequirement
    profiles: tuple[OperationProfile, ...]
    block_i: int | None = None
    block_rows: int | None = None
    mode: str | None = None
    hbm_bytes: float | None = None
    uhat_hbm_bytes: float | None = None
    intermediate_hbm_bytes: float | None = None
    block_k: int | None = None   # pipelined produce-phase K tile
    # im2col extraction row block (conv and pipelined ops): None emits
    # the full patch matrix per batch element; a degraded budget blocks
    # the extraction so VMEM holds image + patch_rows rows only.
    patch_rows: int | None = None
    # Modeled W-stream pass count of the fused/pipelined kernels (1
    # resident / iters+1 streamed forward, 2 / iters+4 backward; None
    # for ops without a W stream).  A first-class plan claim so the
    # static auditor (``repro.verify.lowering``) can diff it against
    # the pass count DERIVED from the lowering's index maps.
    n_passes: int | None = None

    @property
    def profile(self) -> OperationProfile:
        """The primary dataflow profile (first of ``profiles``)."""
        return self.profiles[0]

    @property
    def fuses_squash(self) -> bool:
        """Whether this op's epilogue absorbs the squash activation."""
        return self.kernel.endswith("+squash")


@dataclasses.dataclass(frozen=True)
class AuditContract:
    """Tolerances the static auditor (``repro.verify.lowering``) holds an
    op's DERIVED footprint/traffic to.

    ``vmem_rtol`` bounds how far the derived peak VMEM may exceed the
    modeled ``vmem_bytes`` (the hard direction: an under-modeling plan
    would let ``validate()`` pass a schedule that busts real VMEM).
    ``vmem_over_factor`` bounds the other direction -- the model may
    legitimately count in-register temporaries (the ``uh_block`` votes
    tile, s/v candidates) that the lowering never allocates as scratch,
    but a model more than this factor above the lowering is stale.
    ``hbm_rtol`` is symmetric: derived traffic pays i/K zero-padding and
    side kernels (patch extraction, bias slabs) the byte model rounds
    away, so it is per-kernel calibrated rather than zero.
    """

    vmem_rtol: float
    vmem_over_factor: float
    hbm_rtol: float


# Per-kernel calibrated contracts.  The conv entries absorb the patch-
# extraction call (reads the image, writes the patches tensor) that
# ``BlockPlan.hbm_bytes`` -- a pure matmul model -- does not count; the
# fused entries absorb i-axis zero-padding of u/W at ragged block_i.
_AUDIT_CONTRACTS = {
    # Calibrated against the worst derived-vs-modeled margin over every
    # registered CapsNet arch x {per-op, pipelined} x {fwd, train} (see
    # tests/test_verify_lowering.py): the fused/pipelined models are
    # near-exact; the conv margins absorb the im2col patch-extraction
    # call and the coarse matmul-count backward estimate.
    "conv_im2col": AuditContract(0.15, 1.75, 0.20),
    "conv_im2col+squash": AuditContract(0.10, 1.50, 0.30),
    "conv_im2col_bwd": AuditContract(0.10, 1.50, 0.50),
    "votes_routing": AuditContract(0.05, 1.40, 0.05),
    "votes_routing_bwd": AuditContract(0.05, 1.60, 0.05),
    "primary_routing": AuditContract(0.25, 1.25, 0.15),
}


def audit_contract(op: OpPlan) -> AuditContract:
    """The audit tolerance contract for one plan op (keyed by kernel)."""
    try:
        return _AUDIT_CONTRACTS[op.kernel]
    except KeyError:
        raise PlanError(f"{op.name}: no audit contract for kernel "
                        f"{op.kernel!r}") from None


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    cfg: CapsNetConfig
    batch: int
    dataflow: str
    vmem_budget: int
    ops: tuple[OpPlan, ...]
    train: bool = False          # backward OpPlans appended (reverse order)

    def op(self, name: str) -> OpPlan:
        for op in self.ops:
            if op.name == name:
                return op
        raise KeyError(f"no operation {name!r} in plan "
                       f"({[o.name for o in self.ops]})")

    @property
    def profiles(self) -> tuple[OperationProfile, ...]:
        """The dataflow profiles this plan was compiled from (feeds dse).

        Fused ops contribute every profile they cover, so this is always
        the full five-operation paper model regardless of fusion.
        """
        return tuple(p for op in self.ops for p in op.profiles)

    def phase_requirements(self) -> tuple[PhaseRequirement, ...]:
        """Per-operation PMU phases, in execution order.

        One phase per EXECUTED op: the fused ClassCaps megakernel is a
        single phase, so the gating schedule scores what actually runs.
        """
        return tuple(op.requirement for op in self.ops)

    def phase_groups(self) -> tuple[tuple[str, tuple[str, ...]], ...]:
        """(phase_name, covered profile names) per executed op -- lets the
        organization DSE (``dse.evaluate_plan``) gate over the fused
        phases the kernels execute instead of the raw five-op model."""
        return tuple((op.name, tuple(p.name for p in op.profiles))
                     for op in self.ops)

    def phase_durations(self) -> dict[str, float]:
        """Per-phase cycle estimate keyed by executed-op name.  Pass-count
        aware: a STREAMED fused phase re-streams W (``iters + 1`` forward
        / ``iters + 4`` backward passes recomputing the votes), so its
        leakage window is longer than the one-pass profile sum a
        ``phase_groups()`` consumer would otherwise derive."""
        return {op.name: op.requirement.duration_cycles for op in self.ops}

    @property
    def peak_vmem_bytes(self) -> int:
        return max(op.vmem_bytes for op in self.ops)

    def forward_hbm_bytes(self) -> float:
        """Total modeled HBM traffic of one forward pass (forward ops'
        ``hbm_bytes`` summed) -- the whole-network number the paper
        optimizes.  Each op's ``intermediate_hbm_bytes`` is the share of
        this total spent round-tripping that op's output to its consumer
        (already inside the per-op ``hbm_bytes``: the producer's store and
        the consumer's load), so a pipelined plan beats the per-op plan
        here by at least the eliminated intermediate."""
        return sum(op.hbm_bytes or 0.0 for op in self.ops
                   if not op.name.endswith(BWD_SUFFIX))

    def activation_residency_bytes(self, *, reversible: bool = True) -> int:
        """Routing-stack activation bytes a training step keeps live (see
        the module-level ``activation_residency_bytes``) at this plan's
        batch."""
        return activation_residency_bytes(self.cfg, batch=self.batch,
                                          reversible=reversible)

    def validate(self) -> None:
        """Check the plan invariants; raises ``PlanError`` on violation."""
        if self.batch < 1:
            raise PlanError(f"batch must be >= 1, got {self.batch}")
        names = [op.name for op in self.ops]
        if len(set(names)) != len(names):
            raise PlanError(f"duplicate operation names: {names}")
        stack = self.cfg.routing_stack()
        covered = [p.name for op in self.ops for p in op.profiles]
        expected = [p.name for p in
                    analysis.capsnet_stack_profiles(
                        self.dataflow, analysis.dims_from_config(self.cfg),
                        _layer_descs(stack))]
        if self.train:
            # Backward phases mirror the forward coverage in reverse
            # execution order (the order the backward actually runs).
            expected = expected + [n + BWD_SUFFIX for n in reversed(expected)]
        if covered != expected:
            raise PlanError(
                f"phases {names} cover {covered}, not operations {expected}")
        for op in self.ops:
            if op.mode is not None and op.mode not in ("resident", "streamed"):
                raise PlanError(f"{op.name}: unknown mode {op.mode!r}")
            if op.vmem_bytes > self.vmem_budget:
                raise PlanError(
                    f"{op.name}: VMEM footprint {op.vmem_bytes} exceeds "
                    f"budget {self.vmem_budget}")
            if op.requirement.name != op.name:
                raise PlanError(f"{op.name}: phase named {op.requirement.name!r}")
            if op.requirement.duration_cycles <= 0:
                raise PlanError(f"{op.name}: non-positive phase duration")
            if op.block is not None and op.block.vmem_total > self.vmem_budget:
                raise PlanError(f"{op.name}: block tiles exceed VMEM budget")
            if op.block_i is not None and not (
                    1 <= op.block_i <= max(max(s.in_caps for s in stack),
                                           1)):
                raise PlanError(f"{op.name}: block_i {op.block_i} out of range")

    def summary(self) -> list[dict]:
        rows = []
        for op in self.ops:
            rows.append(dict(
                name=op.name,
                kernel=op.kernel,
                block=((op.block.block_m, op.block.block_k, op.block.block_n)
                       if op.block else None),
                block_i=op.block_i,
                block_rows=op.block_rows,
                mode=op.mode,
                n_passes=op.n_passes,
                vmem_kib=op.vmem_bytes / 1024,
                est_cycles=op.est_cycles,
                hbm_bytes=op.hbm_bytes,
                uhat_hbm_bytes=op.uhat_hbm_bytes,
                intermediate_hbm_bytes=op.intermediate_hbm_bytes,
                req_kib=op.requirement.required_bytes / 1024,
                duration_cycles=op.requirement.duration_cycles,
            ))
        return rows


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------

def _requirement(profile: OperationProfile) -> PhaseRequirement:
    return PhaseRequirement(name=profile.name,
                            required_bytes=profile.total_mem,
                            duration_cycles=profile.total_cycles)


def _layer_descs(stack) -> tuple:
    """``analysis.capsnet_stack_profiles`` layer descriptors for a
    resolved routing stack (the per-layer profile-name suffix is the
    instance name minus the shared ``FUSED_NAME`` base)."""
    return tuple((lay.name[len(FUSED_NAME):], lay.in_caps, lay.in_dim,
                  lay.num_caps, lay.caps_dim, lay.iters) for lay in stack)


def activation_residency_bytes(cfg: CapsNetConfig, *, batch: int = 1,
                               reversible: bool = True) -> int:
    """Modeled bytes of ROUTING-STACK activations a training step must
    keep live for the backward pass.

    ``reversible=False`` is the conventional autodiff accounting: every
    routing-layer instance saves its input capsule tensor
    ``[B, in_caps, in_dim]``, so the total grows linearly in depth.
    ``reversible=True`` is what the plan actually executes: a maximal run
    of residual coupling halves forms ONE reversible segment that saves
    only its OUTPUT (the backward re-derives every interior state by
    inverting the additive couplings), so an all-residual stack costs one
    segment tensor no matter how many blocks are stacked -- activation
    memory flat in depth.  Plain (non-residual) layers still save their
    input either way.
    """
    stack = cfg.routing_stack()
    total, k = 0, 0
    while k < len(stack):
        lay = stack[k]
        if reversible and lay.residual:
            # x = [x1 | x2]: the F half consumes x2 and emits x1's width,
            # so the segment tensor is (in_caps + num_caps) capsules.
            seg_caps = lay.in_caps + lay.num_caps
            total += batch * seg_caps * lay.in_dim * ELEM_BYTES
            while k < len(stack) and stack[k].residual:
                k += 1
        else:
            total += batch * lay.in_caps * lay.in_dim * ELEM_BYTES
            k += 1
    return total


def _votes_vmem(batch: int, block_i: int, caps_dim: int, out_dim: int) -> int:
    """caps_votes footprint per grid step (double-buffered streams)."""
    data = batch * block_i * caps_dim * ELEM_BYTES
    weight = block_i * out_dim * caps_dim * ELEM_BYTES
    accum = batch * block_i * out_dim * ELEM_BYTES
    return 2 * (data + weight) + accum


def _votes_max_batch(caps_dim: int, out_dim: int, vmem_budget: int) -> int:
    """Largest batch whose block_i=1 caps-votes footprint fits the budget."""
    fixed = 2 * out_dim * caps_dim * ELEM_BYTES          # weight tile
    per_batch = (2 * caps_dim + out_dim) * ELEM_BYTES    # data + accum rows
    return max((vmem_budget - fixed) // per_batch, 0)


def _votes_block_i_raw(num_caps: int, caps_dim: int, out_dim: int,
                       batch: int, vmem_budget: int) -> int:
    """Split-path caps-votes i-tile: planner pick shrunk to the budget at
    the REAL batch (the memoized plan-less wrapper in ``kernels/ops.py``
    shares this, so a batched call can no longer exceed the footprint the
    planner guarantees).  Raises ``PlanError`` when even ``block_i=1``
    exceeds the budget (instead of letting ``validate()`` fail later with
    a generic footprint message)."""
    wl = MatmulWorkload(m=num_caps, k=caps_dim, n=out_dim)
    block = plan_matmul(wl, vmem_budget)
    bi = max(min(block.block_m, num_caps), 1)
    while bi > 1 and _votes_vmem(batch, bi, caps_dim, out_dim) > vmem_budget:
        bi //= 2
    need = _votes_vmem(batch, bi, caps_dim, out_dim)
    if need > vmem_budget:
        raise PlanError(
            f"ClassCaps-FC: no feasible schedule at batch={batch}: even "
            f"block_i=1 needs {need} B of VMEM, over the {vmem_budget} B "
            f"budget; largest feasible batch is "
            f"{_votes_max_batch(caps_dim, out_dim, vmem_budget)}")
    return bi


# ---------------------------------------------------------------------------
# Fused votes+routing schedule (the megakernel's resident-vs-streamed DSE)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VotesRoutingSchedule:
    """Plan decision for the fused ``votes_routing`` megakernel."""

    mode: str                # "resident" | "streamed"
    block_i: int
    vmem_bytes: int          # footprint of the CHOSEN schedule
    n_passes: int            # W streams: 1 resident, iters+1 streamed
    workload: MatmulWorkload


def _i_padded(num_caps: int, block_i: int) -> int:
    return math.ceil(num_caps / block_i) * block_i


def _pad_min_block_i(num_caps: int, bi0: int) -> int:
    """Shrink a generic matmul ``block_m`` pick to the halving candidate
    with the least i-padding (ties keep the largest tile), floored at the
    MXU-aligned 128 rows.

    The fused/pipelined kernels zero-pad u/W/scratch to
    ``ceil(I/block_i) * block_i`` rows, so the generic pick can be
    catastrophically wasteful: block_i=1024 over MNIST's I=1152 pads to
    2048 rows -- 78% phantom W traffic on every stream and ~5 MB of dead
    votes scratch -- where block_i=128 divides 1152 exactly.  The static
    auditor (repro.verify.lowering) found exactly this drift between the
    modeled traffic and the lowering's index maps.
    """
    floor = min(bi0, 128)
    best, bi = bi0, bi0
    while bi >= floor and bi >= 1:
        if _i_padded(num_caps, bi) < _i_padded(num_caps, best):
            best = bi
        bi //= 2
    return best
def _i_buf(num_caps: int, block_i: int) -> int:
    """Tile buffer count: 2 (double-buffered) when the i-axis spans more
    than one block, 1 when a single block covers it -- a block whose
    index never changes is fetched once and never swapped, so the
    lowering holds exactly one copy (the static auditor measured the
    2x model against single-block lowerings at twice the real tiles)."""
    return 2 if _i_padded(num_caps, block_i) > block_i else 1


def _fused_resident_vmem(batch: int, num_caps: int, block_i: int,
                         caps_dim: int, jd: int, j: int) -> int:
    """Resident schedule: the full votes tensor + routing logits live in
    VMEM scratch while double-buffered u/W i-tiles stream past once; each
    grid step also materializes one [B, block_i, J*D] votes block before
    storing it into the scratch."""
    i_pad = _i_padded(num_caps, block_i)
    votes = batch * i_pad * jd
    logits = batch * i_pad * j
    tiles = _i_buf(num_caps, block_i) * (batch * block_i * caps_dim
                                        + block_i * jd * caps_dim)
    uh_block = batch * block_i * jd
    out = batch * jd
    return (votes + logits + tiles + uh_block + out) * ELEM_BYTES


def _fused_streamed_vmem(batch: int, num_caps: int, block_i: int,
                         caps_dim: int, jd: int, j: int) -> int:
    """Streamed schedule: only u (fetched once), the logits, and the s/v
    candidates stay resident; W tiles stream (double-buffered) each pass,
    and every step recomputes one [B, block_i, J*D] votes block."""
    i_pad = _i_padded(num_caps, block_i)
    u_res = batch * i_pad * caps_dim
    logits = batch * i_pad * j
    w_tile = _i_buf(num_caps, block_i) * block_i * jd * caps_dim
    uh_block = batch * block_i * jd
    sv = 2 * batch * jd
    out = batch * jd
    return (u_res + logits + w_tile + uh_block + sv + out) * ELEM_BYTES


def _fused_max_batch(num_caps: int, caps_dim: int, jd: int, j: int,
                     vmem_budget: int, extra_per_batch: int = 0) -> int:
    """Largest batch whose streamed block_i=1 forward footprint fits (the
    footprint is affine in batch at fixed block_i; ``extra_per_batch``
    carries a residual-epilogue operand's per-element bytes)."""
    fixed = _fused_streamed_vmem(0, num_caps, 1, caps_dim, jd, j)
    per = (_fused_streamed_vmem(1, num_caps, 1, caps_dim, jd, j) - fixed
           + extra_per_batch)
    return max((vmem_budget - fixed) // per, 0)


def plan_votes_routing(num_caps: int, caps_dim: int, jd: int, j: int, *,
                       batch: int = 1, iters: int = 3,
                       vmem_budget: int = VMEM_BYTES,
                       name: str = FUSED_NAME,
                       residual: bool = False) -> VotesRoutingSchedule:
    """Resident-vs-streamed decision for the fused megakernel.

    Prefer **resident** (votes computed once into scratch, routing
    iterates on-chip -- the split path's behavior minus the u_hat HBM
    round-trip); fall back to **streamed** (votes recomputed from
    re-streamed W tiles each pass) when the votes tensor cannot fit the
    budget at any i-tile.  The streamed schedule fuses each iteration's
    s-accumulation with its logits update into ONE W stream (the b-update
    runs against the previous pass's ``v`` held in scratch), so ``W``
    moves ``iters + 1`` times per forward -- half the old separate
    s-pass/b-pass schedule's ``2*iters + 1``.  Raises ``PlanError`` only
    when even streamed ``block_i=1`` exceeds the budget -- the point
    where no schedule can keep the routing state on-chip at this batch.

    ``name`` labels the layer instance in the error (deep stacks plan one
    schedule per routing layer); ``residual`` adds the [B, J*D] residual
    operand a coupling half's epilogue holds alongside the output.
    """
    wl = MatmulWorkload(m=num_caps, k=caps_dim, n=jd, in_bytes=ELEM_BYTES)
    # Tile-shape pick only (our per-mode footprint model is what is held
    # to the budget, not the generic double-buffered matmul model),
    # refined to the i-padding-minimal halving candidate.
    bi0 = _pad_min_block_i(
        num_caps, max(min(plan_matmul(wl).block_m, num_caps), 1))
    extra = batch * jd * ELEM_BYTES if residual else 0

    bi = bi0
    while bi > 1 and _fused_resident_vmem(batch, num_caps, bi, caps_dim,
                                          jd, j) + extra > vmem_budget:
        bi //= 2
    need = _fused_resident_vmem(batch, num_caps, bi, caps_dim, jd, j) + extra
    if need <= vmem_budget:
        return VotesRoutingSchedule(mode="resident", block_i=bi,
                                    vmem_bytes=need, n_passes=1, workload=wl)

    bi = bi0
    while bi > 1 and _fused_streamed_vmem(batch, num_caps, bi, caps_dim,
                                          jd, j) + extra > vmem_budget:
        bi //= 2
    need = _fused_streamed_vmem(batch, num_caps, bi, caps_dim, jd, j) + extra
    if need > vmem_budget:
        raise PlanError(
            f"{name}: no feasible schedule at batch={batch}: even "
            f"streamed block_i=1 needs {need} B of VMEM, over the "
            f"{vmem_budget} B budget; largest feasible batch is "
            f"{_fused_max_batch(num_caps, caps_dim, jd, j, vmem_budget, jd * ELEM_BYTES if residual else 0)}")
    return VotesRoutingSchedule(mode="streamed", block_i=bi, vmem_bytes=need,
                                n_passes=iters + 1, workload=wl)


def votes_routing_hbm_bytes(batch: int, num_caps: int, caps_dim: int,
                            jd: int, n_passes: int,
                            block_i: int | None = None) -> float:
    """Modeled HBM traffic of the fused megakernel per forward: u read
    once, W streamed ``n_passes`` times, v written once -- and NO u_hat
    term (the tensor never exists off-chip).

    With ``block_i`` the model counts the i-rows the lowering actually
    moves: the wrapper zero-pads u/W to ``ceil(I/block_i) * block_i``
    rows, so padded rows cross HBM like real ones -- and when ONE block
    covers the whole i-axis the W block index never changes, so W is
    fetched once no matter how many passes the grid makes (Pallas keeps
    the unchanged block in VMEM).  ``None`` keeps the unpadded
    idealization (what a perfectly divisible tile achieves)."""
    i_eff = _i_padded(num_caps, block_i) if block_i else num_caps
    w_sweeps = 1 if block_i is not None and i_eff <= block_i else n_passes
    u = batch * i_eff * caps_dim
    w = i_eff * jd * caps_dim * w_sweeps
    v = batch * jd
    return float((u + w + v) * ELEM_BYTES)


def split_votes_routing_hbm_bytes(batch: int, num_caps: int, caps_dim: int,
                                  jd: int) -> tuple[float, float]:
    """(total, u_hat share) of the split ``caps_votes`` -> ``routing``
    path: the votes tensor is written by one kernel and read back by the
    next -- the produce-once/consume-once round-trip the fusion kills."""
    u = batch * num_caps * caps_dim
    w = num_caps * jd * caps_dim
    v = batch * jd
    uhat = 2 * batch * num_caps * jd                 # write + read back
    return float((u + w + v + uhat) * ELEM_BYTES), float(uhat * ELEM_BYTES)


# ---------------------------------------------------------------------------
# Pipelined PrimaryCaps->ClassCaps pair (inter-op residency DSE)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PrimaryRoutingSchedule:
    """Plan decision for the pipelined producer->consumer megakernel.

    The producer output u ([B, I, C] -- the inter-layer activation) is
    SMALL, so the whole tensor lives in VMEM scratch: a K-blocked produce
    phase accumulates the im2col matmul into it and applies the
    bias+squash epilogue in place, then the votes/routing phases read its
    i-blocks exactly the way the fused megakernel reads u from HBM.
    Patches and the conv weight are fetched ONCE (vs once per re-stream
    on a per-i-block recompute), and u itself never exists off-chip.
    """

    mode: str                # votes/routing schedule: "resident"|"streamed"
    block_i: int             # votes/routing i-tile
    block_k: int             # produce-phase K tile (im2col reduction)
    k_steps: int             # ceil(K / block_k) produce grid steps
    vmem_bytes: int          # footprint of the CHOSEN schedule
    n_passes: int            # ClassCaps W streams: 1 resident, iters+1 str.
    workload: MatmulWorkload # the producer's im2col matmul
    block: BlockPlan         # producer tiling (VJP replay matmuls)


def _pipe_produce_vmem(batch: int, p_pos: int, n_ch: int, block_k: int,
                       i_pad: int, caps_dim: int) -> int:
    """Produce-phase residency shared by both pipelined schedules: the
    full producer output scratch (pre-activation, squashed in place) plus
    double-buffered patch / conv-weight K tiles and the bias row."""
    u_scr = batch * i_pad * caps_dim
    tiles = 2 * (batch * p_pos * block_k + block_k * n_ch)
    return u_scr + tiles + n_ch


def _pipe_resident_vmem(batch: int, p_pos: int, n_ch: int, block_k: int,
                        num_caps: int, block_i: int, caps_dim: int,
                        jd: int, j: int) -> int:
    """Resident consumer on top of the produce-phase residency: the full
    votes tensor + routing logits in scratch, double-buffered W i-tiles,
    one [B, block_i, J*D] votes block per step."""
    i_pad = _i_padded(num_caps, block_i)
    votes = batch * i_pad * jd
    logits = batch * i_pad * j
    w_tile = _i_buf(num_caps, block_i) * block_i * jd * caps_dim
    uh_block = batch * block_i * jd
    out = batch * jd
    return (_pipe_produce_vmem(batch, p_pos, n_ch, block_k, i_pad, caps_dim)
            + votes + logits + w_tile + uh_block + out) * ELEM_BYTES


def _pipe_streamed_vmem(batch: int, p_pos: int, n_ch: int, block_k: int,
                        num_caps: int, block_i: int, caps_dim: int,
                        jd: int, j: int) -> int:
    """Streamed consumer on top of the produce-phase residency: logits +
    s/v candidates resident, W tiles re-streamed each pass, one votes
    block recomputed per step (u is the produce scratch itself -- the
    streamed megakernel's constant-index u fetch becomes free)."""
    i_pad = _i_padded(num_caps, block_i)
    logits = batch * i_pad * j
    w_tile = _i_buf(num_caps, block_i) * block_i * jd * caps_dim
    uh_block = batch * block_i * jd
    sv = 2 * batch * jd
    out = batch * jd
    return (_pipe_produce_vmem(batch, p_pos, n_ch, block_k, i_pad, caps_dim)
            + logits + w_tile + uh_block + sv + out) * ELEM_BYTES


def plan_primary_routing(p_pos: int, k_in: int, n_ch: int, num_caps: int,
                         caps_dim: int, jd: int, j: int, *,
                         batch: int = 1, iters: int = 3,
                         vmem_budget: int = VMEM_BYTES
                         ) -> PrimaryRoutingSchedule:
    """Schedule DSE for the pipelined PrimaryCaps->ClassCaps pair.

    Prefer the resident consumer (votes computed once into scratch);
    fall back to streamed (votes recomputed from re-streamed W, the
    fused s+b pass -- ``iters + 1`` W streams).  Both shrink the votes
    i-tile first, then halve the produce K tile, before giving up.
    Raises ``PlanError`` when even streamed ``block_i=1, block_k=1``
    exceeds the budget -- ``compile_plan`` then falls back to the
    per-op pair (which may itself still fit: its phases never coexist).
    """
    wl = MatmulWorkload(m=batch * p_pos, k=k_in, n=n_ch,
                        in_bytes=ELEM_BYTES)
    try:
        blk = plan_matmul(wl, vmem_budget)
    except ValueError as err:
        raise PlanError(f"{PIPE_NAME}: no feasible producer tiling at "
                        f"batch={batch}: {err}")
    bk0 = max(min(blk.block_k, k_in), 1)
    vr_wl = MatmulWorkload(m=num_caps, k=caps_dim, n=jd,
                           in_bytes=ELEM_BYTES)
    bi0 = _pad_min_block_i(
        num_caps, max(min(plan_matmul(vr_wl).block_m, num_caps), 1))

    def _fit(vmem_of):
        bk = bk0
        while True:
            bi = bi0
            while bi > 1 and vmem_of(bi, bk) > vmem_budget:
                bi //= 2
            need = vmem_of(bi, bk)
            if need <= vmem_budget:
                return bi, bk, need
            if bk == 1:
                return None
            bk = max(bk // 2, 1)

    fit = _fit(lambda bi, bk: _pipe_resident_vmem(
        batch, p_pos, n_ch, bk, num_caps, bi, caps_dim, jd, j))
    if fit is not None:
        bi, bk, need = fit
        return PrimaryRoutingSchedule(
            mode="resident", block_i=bi, block_k=bk,
            k_steps=math.ceil(k_in / bk), vmem_bytes=need, n_passes=1,
            workload=wl, block=blk)
    fit = _fit(lambda bi, bk: _pipe_streamed_vmem(
        batch, p_pos, n_ch, bk, num_caps, bi, caps_dim, jd, j))
    if fit is None:
        need = _pipe_streamed_vmem(batch, p_pos, n_ch, 1, num_caps, 1,
                                   caps_dim, jd, j)
        raise PlanError(
            f"{PIPE_NAME}: no feasible pipelined schedule at batch={batch}: "
            f"even streamed block_i=1, block_k=1 needs {need} B of VMEM, "
            f"over the {vmem_budget} B budget")
    bi, bk, need = fit
    return PrimaryRoutingSchedule(
        mode="streamed", block_i=bi, block_k=bk,
        k_steps=math.ceil(k_in / bk), vmem_bytes=need, n_passes=iters + 1,
        workload=wl, block=blk)


def primary_routing_hbm_bytes(batch: int, p_pos: int, k_in: int, n_ch: int,
                              num_caps: int, caps_dim: int, jd: int,
                              n_passes: int,
                              block_i: int | None = None,
                              block_k: int | None = None) -> float:
    """Modeled HBM traffic of the pipelined pair per forward: patches and
    the conv weight+bias each read ONCE (the produce phase streams K
    tiles past the resident output scratch), the routing W streamed
    ``n_passes`` times, v written once -- and NO u term at all (the
    inter-layer activation never exists off-chip).

    ``block_i`` pads the routing W rows to the i-tile grid, ``block_k``
    pads the im2col reduction (patch columns / conv-weight rows) to the
    K-tile grid -- the rows/columns the lowering actually streams;
    ``None`` keeps the unpadded idealization."""
    i_eff = _i_padded(num_caps, block_i) if block_i else num_caps
    k_eff = _i_padded(k_in, block_k) if block_k else k_in
    w_sweeps = 1 if block_i is not None and i_eff <= block_i else n_passes
    patches = batch * p_pos * k_eff
    wpc = k_eff * n_ch + n_ch
    w_cc = i_eff * jd * caps_dim * w_sweeps
    v = batch * jd
    return float((patches + wpc + w_cc + v) * ELEM_BYTES)


def primary_intermediate_hbm_bytes(batch: int, num_caps: int,
                                   caps_dim: int) -> float:
    """The u round-trip a per-op plan pays between PrimaryCaps and the
    votes/routing megakernel: written by the conv epilogue, read back by
    the u-load -- the traffic the pipelined pair eliminates."""
    return float(2 * batch * num_caps * caps_dim * ELEM_BYTES)


def _pipe_requirement(in_caps: int, j: int, jd: int,
                      profs: Sequence[OperationProfile],
                      sched: PrimaryRoutingSchedule) -> PhaseRequirement:
    """ONE PMU phase for the pipelined pair, honest per mode: the produce
    phase's demand is the PrimaryCaps profile's; the consumer phases match
    ``_fused_requirement`` (with u's residency already counted -- it IS
    the produce scratch).  Duration is the four covered operations' sum
    with the votes computation scaled by the W-pass count.
    ``in_caps``/``j``/``jd`` are the consumed routing layer's dimensions
    (the FIRST layer of a deep stack)."""
    pc, cc, ss, us = profs
    duration = (pc.total_cycles + cc.total_cycles * sched.n_passes
                + ss.total_cycles + us.total_cycles)
    if sched.mode == "resident":
        req = max(p.total_mem for p in profs)
    else:
        bij = in_caps * j
        req = max(pc.total_mem,
                  cc.data_mem
                  + bij * (analysis.ACC_BYTES + analysis.ACT_BYTES)
                  + cc.weight_mem
                  + 4 * jd * analysis.ACC_BYTES)
    return PhaseRequirement(name=PIPE_NAME, required_bytes=req,
                            duration_cycles=duration)


# ---------------------------------------------------------------------------
# Fused votes+routing BACKWARD schedule (the custom-VJP kernels' DSE)
# ---------------------------------------------------------------------------

def _fused_resident_bwd_vmem(batch: int, num_caps: int, block_i: int,
                             caps_dim: int, jd: int, j: int,
                             iters: int) -> int:
    """Resident backward: the rebuilt votes scratch (overwritten by
    ``d u_hat`` in place) plus the routing replay's vjp residuals -- the
    logits trajectory and couplings per iteration -- with double-buffered
    u/W tiles streaming past twice and one du/dW block emitted per step."""
    i_pad = _i_padded(num_caps, block_i)
    votes = batch * i_pad * jd                     # u_hat -> d u_hat in place
    traj = 2 * (iters + 1) * batch * i_pad * j     # replay: b trajectory + c
    tiles = _i_buf(num_caps, block_i) * (batch * block_i * caps_dim
                                        + block_i * jd * caps_dim)
    uh_block = batch * block_i * jd
    grads = batch * block_i * caps_dim + block_i * jd * caps_dim
    sv = 4 * batch * jd                            # s/v/ds/dv temporaries
    cot = batch * jd                               # output cotangent
    return (votes + traj + tiles + uh_block + grads + sv + cot) * ELEM_BYTES


def _fused_streamed_bwd_vmem(batch: int, num_caps: int, block_i: int,
                             caps_dim: int, jd: int, j: int,
                             iters: int) -> int:
    """Streamed backward: u, a ROLLING PAIR of logits slabs (only
    ``b_{T-1}``/``b_T`` are ever consumed again under the stop-gradient
    convention), ``db_T``, and the small s/ds pairs stay resident; W
    tiles stream (double-buffered) on every pass and each step recomputes
    one votes block -- ``d u_hat`` exists only one i-block at a time.
    Independent of ``iters``: the replay reuses the two slots."""
    del iters
    i_pad = _i_padded(num_caps, block_i)
    u_res = batch * i_pad * caps_dim
    b_pair = 2 * batch * i_pad * j
    db = batch * i_pad * j
    w_tile = _i_buf(num_caps, block_i) * block_i * jd * caps_dim
    uh_block = batch * block_i * jd
    s_ds = 4 * batch * jd                          # s pair + ds pair
    accv = 2 * batch * jd                          # accumulator + v
    grads = batch * block_i * caps_dim + block_i * jd * caps_dim
    cot = batch * jd
    return (u_res + b_pair + db + w_tile + uh_block + s_ds + accv + grads
            + cot) * ELEM_BYTES


def _fused_bwd_max_batch(num_caps: int, caps_dim: int, jd: int, j: int,
                         iters: int, vmem_budget: int) -> int:
    """Largest batch whose streamed-backward block_i=1 footprint fits
    (the footprint is affine in batch at fixed block_i)."""
    fixed = _fused_streamed_bwd_vmem(0, num_caps, 1, caps_dim, jd, j, iters)
    per = (_fused_streamed_bwd_vmem(1, num_caps, 1, caps_dim, jd, j, iters)
           - fixed)
    return max((vmem_budget - fixed) // per, 0)


def plan_votes_routing_bwd(num_caps: int, caps_dim: int, jd: int, j: int, *,
                           batch: int = 1, iters: int = 3,
                           vmem_budget: int = VMEM_BYTES,
                           name: str = FUSED_NAME) -> VotesRoutingSchedule:
    """Resident-vs-streamed decision for the fused megakernel's BACKWARD.

    Chosen independently of the forward: the backward's scratch is larger
    (the logits trajectory rides along, and resident additionally holds
    the in-place ``d u_hat``), so a budget can plan the forward resident
    -- or plan the forward at all -- and still be unable to run the
    backward.  That boundary raises a ``PlanError`` naming the backward
    op and the largest feasible batch, instead of failing opaquely in
    ``validate()``.

    ``n_passes`` counts W streams: 2 resident (votes rebuild + du/dW
    emit), ``iters + 4`` streamed (fused forward replay ``T+1`` -- one W
    stream per replayed iteration, the logits update folded into the
    s-pass like the forward kernel -- then db seed, ONE dv/ds reverse
    pass, emit; the stop-gradient convention means ``d u_hat`` only ever
    needs ``ds_T`` and ``ds_{T-1}``, so there is no deep reverse
    recurrence to stream W for).
    """
    wl = MatmulWorkload(m=num_caps, k=caps_dim, n=jd, in_bytes=ELEM_BYTES)
    bi0 = _pad_min_block_i(
        num_caps, max(min(plan_matmul(wl).block_m, num_caps), 1))

    bi = bi0
    while bi > 1 and _fused_resident_bwd_vmem(batch, num_caps, bi, caps_dim,
                                              jd, j, iters) > vmem_budget:
        bi //= 2
    need = _fused_resident_bwd_vmem(batch, num_caps, bi, caps_dim, jd, j,
                                    iters)
    if need <= vmem_budget:
        return VotesRoutingSchedule(mode="resident", block_i=bi,
                                    vmem_bytes=need, n_passes=2, workload=wl)

    bi = bi0
    while bi > 1 and _fused_streamed_bwd_vmem(batch, num_caps, bi, caps_dim,
                                              jd, j, iters) > vmem_budget:
        bi //= 2
    need = _fused_streamed_bwd_vmem(batch, num_caps, bi, caps_dim, jd, j,
                                    iters)
    if need > vmem_budget:
        raise PlanError(
            f"{name}{BWD_SUFFIX}: no feasible backward schedule at "
            f"batch={batch}: even streamed block_i=1 needs {need} B of "
            f"VMEM, over the {vmem_budget} B budget; largest feasible "
            f"batch is "
            f"{_fused_bwd_max_batch(num_caps, caps_dim, jd, j, iters, vmem_budget)}")
    return VotesRoutingSchedule(mode="streamed", block_i=bi, vmem_bytes=need,
                                n_passes=iters + 4, workload=wl)


def votes_routing_bwd_hbm_bytes(batch: int, num_caps: int, caps_dim: int,
                                jd: int, *, mode: str, iters: int,
                                block_i: int | None = None) -> float:
    """Modeled HBM traffic of the fused backward per step: W streamed once
    per pass, u read per pass (resident) or once (streamed: constant index
    map), the output cotangent read once, du/dW written once -- and NO
    ``u_hat`` or ``d u_hat`` term (neither ever exists off-chip).

    ``block_i`` makes the i-terms padding-aware (u/W/du/dW are all padded
    to the i-tile grid by the wrapper; the kernel emits padded du/dW that
    the wrapper slices) -- and when one block covers the i-axis, u/W are
    fetched once however many passes the grid makes (the block index
    never changes, so Pallas keeps them in VMEM).  ``None`` is the
    unpadded idealization."""
    i_eff = _i_padded(num_caps, block_i) if block_i else num_caps
    single = block_i is not None and i_eff <= block_i
    w_passes = (2 if mode == "resident" else iters + 4) if not single else 1
    u_passes = (2 if mode == "resident" else 1) if not single else 1
    u = batch * i_eff * caps_dim * u_passes
    w = i_eff * jd * caps_dim * w_passes
    cot = batch * jd
    du = batch * i_eff * caps_dim
    dw = i_eff * jd * caps_dim
    return float((u + w + cot + du + dw) * ELEM_BYTES)


def spilled_votes_routing_bwd_hbm_bytes(batch: int, num_caps: int,
                                        caps_dim: int, jd: int
                                        ) -> tuple[float, float]:
    """(total, u_hat share) of a recompute-from-HBM backward: the forward
    spills ``u_hat``, the backward reads it back, writes ``d u_hat`` and
    reads it again for the du/dW contractions -- four votes-sized HBM
    trips the fused backward never makes."""
    uhat = 4 * batch * num_caps * jd
    u = batch * num_caps * caps_dim
    w = num_caps * jd * caps_dim
    cot = batch * jd
    du = batch * num_caps * caps_dim
    dw = num_caps * jd * caps_dim
    return (float((uhat + u + w + cot + du + dw) * ELEM_BYTES),
            float(uhat * ELEM_BYTES))


def _conv_patch_vmem(in_hw: int, cin: int, k: int, out_hw: int, *,
                     batch: int = 1, block_p: int | None = None) -> int:
    """im2col patch-extraction footprint per grid step: the resident
    input feature map (double-buffered when the grid walks more than one
    batch element -- its block index changes, so the pipeline prefetches)
    plus the emitted patch rows (``block_p`` of them when the extraction
    is row-blocked, the whole matrix when ``block_p`` is None)."""
    image = in_hw * in_hw * cin * ELEM_BYTES * (2 if batch > 1 else 1)
    rows = out_hw * out_hw if block_p is None else block_p
    return image + rows * k * k * cin * ELEM_BYTES


def _conv_patch_bwd_vmem(in_hw: int, cin: int, k: int, out_hw: int, *,
                         batch: int = 1,
                         block_p: int | None = None) -> int:
    """col2im scatter footprint (the conv backward's dx stage): the
    resident dx image accumulator plus the dpatches cotangent stream,
    double-buffered whenever its block index varies over the grid --
    across the row blocks when the scatter is blocked, across batch
    elements when it is not."""
    image = in_hw * in_hw * cin * ELEM_BYTES
    p_pos = out_hw * out_hw
    rows = p_pos if block_p is None else block_p
    streams = 2 if (batch > 1 or (block_p is not None
                                  and block_p < p_pos)) else 1
    return image + streams * rows * k * k * cin * ELEM_BYTES


def conv_extract_hbm_bytes(in_hw: int, cin: int, k: int, out_hw: int, *,
                           batch: int = 1) -> float:
    """HBM traffic of the im2col extraction call per forward: the input
    feature map read once, the patch matrix written once.  The matmul
    model (``BlockPlan.hbm_bytes``) then counts the patch read-back; the
    static auditor measured the extraction side missing from both the
    per-op and the pipelined conv models (34.8% under at batch=4)."""
    return float(batch * (in_hw * in_hw * cin
                          + out_hw * out_hw * k * k * cin) * ELEM_BYTES)


def _conv_bwd_matmul_vmem(block, m: int, kcol: int, n: int) -> int:
    """Peak VMEM of the conv backward's blocked matmuls, which reuse the
    FORWARD tile choice (``kernels.conv_im2col._conv_core_bwd`` passes
    ``st.block_*`` through):

    * dW = patches^T @ dy (``matmul_at_b``): A tiled (bm, bk<=kcol),
      B tiled (bm, bn<=n), both double-buffered once their block index
      varies over the grid, plus the (bk, bn) accumulator;
    * dpatches = dy @ W^T (``matmul_bias_act`` with block_k/block_n
      SWAPPED): A (bm, bk<=n), W (bk, bn<=kcol), bias row, (bm, bn) out.

    The forward peak does not bound these -- at_b streams TWO bm-tall
    operands, so a multi-step m grid exceeds the forward model (the
    auditor caught Conv1-bwd 11.5% over at batch=2)."""
    def steps(total, blk):
        return math.ceil(total / blk)

    def dbuf(distinct):
        return 2 if distinct > 1 else 1

    bm = max(1, min(block.block_m, m))
    bk = max(1, min(block.block_k, kcol))
    bn = max(1, min(block.block_n, n))
    m_steps = steps(m, bm)
    at_b = (dbuf(m_steps * steps(kcol, bk)) * bm * bk
            + dbuf(m_steps * steps(n, bn)) * bm * bn
            + bk * bn) * ELEM_BYTES
    bm2 = max(1, min(block.block_m, m))
    bk2 = max(1, min(block.block_n, n))
    bn2 = max(1, min(block.block_k, kcol))
    m2, k2, n2 = steps(m, bm2), steps(n, bk2), steps(kcol, bn2)
    dpatches = (dbuf(m2 * k2) * bm2 * bk2 + dbuf(k2 * n2) * bk2 * bn2
                + dbuf(n2) * bn2 + bm2 * bn2) * ELEM_BYTES
    return max(at_b, dpatches)


def _plan_patch_rows(in_hw: int, cin: int, k: int, out_hw: int, *,
                     batch: int, budget: int,
                     train: bool = False) -> int | None:
    """Pick the im2col extraction row block under ``budget``.

    ``None`` (emit the whole patch matrix per batch element) whenever it
    fits -- fewest grid steps, and the schedule every contract was
    calibrated against.  Otherwise the largest ``block_p`` that tiles
    the output grid (whole output rows, then within-row windows -- the
    shapes ``kernels.conv_im2col.im2col_patches`` accepts) and fits; the
    static auditor found the unblocked extraction claiming budgets it
    could not honor (MNIST PrimaryCaps: 3.4 MB patch matrix under a
    600 kB plan).  A train plan also pays the col2im scatter
    (``_conv_patch_bwd_vmem`` -- its dpatches stream double-buffers, so
    it binds tighter) with the same ``block_p``.  Falls to
    ``block_p=1`` when nothing fits -- ``validate()`` then rejects the
    plan, which is the honest answer."""
    p_pos = out_hw * out_hw

    def fits(bp):
        need = _conv_patch_vmem(in_hw, cin, k, out_hw, batch=batch,
                                block_p=bp)
        if train:
            need = max(need, _conv_patch_bwd_vmem(in_hw, cin, k, out_hw,
                                                  batch=batch, block_p=bp))
        return need <= budget

    if fits(None):
        return None
    rows = [d * out_hw for d in range(out_hw, 0, -1) if out_hw % d == 0]
    cols = [d for d in range(out_hw, 0, -1) if out_hw % d == 0]
    for bp in sorted(set(rows + cols), reverse=True):
        if bp >= p_pos:
            continue
        if fits(bp):
            return bp
    return 1


def _fused_requirement(in_caps: int, j: int, jd: int,
                       profs: Sequence[OperationProfile],
                       sched: VotesRoutingSchedule,
                       name: str = FUSED_NAME) -> PhaseRequirement:
    """ONE PMU phase for one fused megakernel instance, honest per mode.

    Resident keeps the layer's votes in the accumulator memory across
    routing, so the phase demand is the peak of the three covered
    dataflow operations.  Streamed never materializes the votes: the
    demand is u + logits/couplings + the W prefetch buffer + the s/v
    candidates (dataflow-model byte widths).  The streamed duration
    scales the votes computation by the schedule's W-pass count
    (``iters + 1`` fused passes recompute the votes each stream); the
    resident duration is the plain three-operation sum (one pass).
    ``in_caps``/``j``/``jd`` are THIS layer instance's dimensions (a deep
    stack plans one phase per layer), ``name`` its plan-op name.
    """
    cc, ss, us = profs
    duration = (cc.total_cycles * sched.n_passes
                + ss.total_cycles + us.total_cycles)
    if sched.mode == "resident":
        req = max(cc.total_mem, ss.total_mem, us.total_mem)
    else:
        bij = in_caps * j
        req = (cc.data_mem                                    # u resident
               + bij * (analysis.ACC_BYTES + analysis.ACT_BYTES)  # b + c
               + cc.weight_mem                                # W prefetch
               + 4 * jd * analysis.ACC_BYTES)                 # s/v temps
    return PhaseRequirement(name=name, required_bytes=req,
                            duration_cycles=duration)


def _backward_profile(p: OperationProfile) -> OperationProfile:
    """Dataflow profile of one operation's backward pass.

    Reverse-mode doubles the MAC work (the d-input and d-weight products
    are each a forward-sized contraction) and the on-chip access counts
    with it; the per-component footprints stay the forward's -- the
    backward kernels reuse the same residencies, swapping ``u_hat`` /
    activations for their cotangents.
    """
    return dataclasses.replace(
        p, name=p.name + BWD_SUFFIX, macs=2 * p.macs, cycles=2 * p.cycles,
        data_reads=2 * p.data_reads, data_writes=2 * p.data_writes,
        weight_reads=2 * p.weight_reads,
        accum_reads=2 * p.accum_reads, accum_writes=2 * p.accum_writes,
        offchip_reads=2 * p.offchip_reads,
        offchip_writes=2 * p.offchip_writes)


def _fused_bwd_requirement(in_caps: int, j: int, jd: int, iters: int,
                           profs_bwd: Sequence[OperationProfile],
                           sched: VotesRoutingSchedule,
                           name: str = FUSED_NAME) -> PhaseRequirement:
    """ONE PMU phase for one fused backward instance, honest per mode
    (mirrors ``_fused_requirement``: resident holds votes-sized state
    across the replay, streamed holds u + the logits trajectory + small
    temps).  The votes-recompute cycles (the ClassCaps-FC-bwd profile,
    whose 2x-forward work matches resident's 2 W streams) scale with the
    schedule's W-pass count: ``iters + 4`` streamed passes each rebuild
    one votes block.  Dimensions are per layer instance, like
    ``_fused_requirement``'s."""
    duration = (sum(p.total_cycles for p in profs_bwd[:-1])
                + profs_bwd[-1].total_cycles * sched.n_passes / 2)
    if sched.mode == "resident":
        req = max(p.total_mem for p in profs_bwd)
    else:
        cc = profs_bwd[-1]                       # ClassCaps-FC-bwd
        bij = in_caps * j
        req = (cc.data_mem                                   # u resident
               + (iters + 2) * bij * analysis.ACC_BYTES      # b_t, db
               + cc.weight_mem                               # W prefetch
               + 8 * jd * analysis.ACC_BYTES)                # s/ds/dv temps
    return PhaseRequirement(name=name + BWD_SUFFIX,
                            required_bytes=req, duration_cycles=duration)


@functools.lru_cache(maxsize=64)
def compile_plan(cfg: CapsNetConfig = CapsNetConfig(), *, batch: int = 1,
                 vmem_budget: int = VMEM_BYTES,
                 dataflow: str = "resident",
                 train: bool = False,
                 pipeline: bool = False) -> ExecutionPlan:
    """Compile ``cfg`` into the per-operation ExecutionPlan (memoized:
    plans are immutable and the block-shape DSE runs once per shape).

    The five analysis operations map onto executors as follows:

      Conv1, PrimaryCaps -> ``conv_im2col`` kernels (strided Pallas patch
                            extraction + blocked matmul over the planner's
                            block_m/k/n tiles; PrimaryCaps fuses the squash
                            activation into the epilogue when its n-tile is
                            capsule-aligned)
      ClassCaps-FC,
      Sum+Squash,
      Update+Sum         -> ONE fused ``votes_routing`` megakernel (votes
                            from streamed W i-blocks + every routing
                            iteration in VMEM scratch -- u_hat never
                            touches HBM; ``plan_votes_routing`` picks the
                            resident or streamed schedule per config)

    ``requirement``s (PMU phases) keep the paper's per-inference dataflow
    model -- one phase per EXECUTED op, so the fused megakernel is scored
    as the single phase it runs; ``vmem_bytes`` scale with ``batch``
    where the kernel batches.

    ``pipeline=True`` additionally tries the producer->consumer PAIR:
    PrimaryCaps and the megakernel collapse into ONE ``primary_routing``
    OpPlan (combined VMEM footprint, combined PMU phase,
    ``intermediate_hbm_bytes=0`` -- the inter-layer u never off-chip)
    whenever the combined footprint fits the budget, silently keeping the
    per-op pair otherwise.  The backward OpPlans are unchanged: the
    pipelined VJP replays the producer from patches and runs exactly the
    per-op backward kernels.

    ``train=True`` appends one backward OpPlan per executed kernel, in
    reverse network order (the order the backward runs): the fused
    backward gets its own resident/streamed schedule
    (``plan_votes_routing_bwd`` -- its scratch is larger than the
    forward's, so the mode can differ), and each conv backward reuses the
    forward block tiles for its dW / dpatches matmuls and col2im scatter.
    Backward phases join ``phase_groups()`` so dse/pmu gate them too.
    """
    dims = analysis.dims_from_config(cfg)
    stack = cfg.routing_stack()
    profiles = analysis.capsnet_stack_profiles(dataflow, dims,
                                               _layer_descs(stack))
    by_name = {p.name: p for p in profiles}
    ops: list[OpPlan] = []

    # Conv stack: im2col matmuls the kernels EXECUTE with the planned
    # tiles.  Workloads carry the real batched row count and fp32 element
    # size so ``block.vmem_total`` is the honest double-buffered footprint
    # (patch tile + weight tile + accumulator) of the running kernel.
    conv_wls = {
        "Conv1": MatmulWorkload(m=batch * dims.conv1_out ** 2,
                                k=dims.conv1_k ** 2 * dims.conv1_cin,
                                n=dims.conv1_cout, in_bytes=ELEM_BYTES),
        "PrimaryCaps": MatmulWorkload(m=batch * dims.pc_out ** 2,
                                      k=dims.pc_k ** 2 * dims.pc_cin,
                                      n=dims.pc_cout, in_bytes=ELEM_BYTES),
    }
    conv_geom = {
        "Conv1": (dims.in_hw, dims.conv1_cin, dims.conv1_k, dims.conv1_out),
        "PrimaryCaps": (dims.conv1_out, dims.pc_cin, dims.pc_k, dims.pc_out),
    }
    conv_patch_rows = {
        name: _plan_patch_rows(*geom, batch=batch, budget=vmem_budget,
                               train=train)
        for name, geom in conv_geom.items()
    }
    conv_patch = {
        name: _conv_patch_vmem(*geom, batch=batch,
                               block_p=conv_patch_rows[name])
        for name, geom in conv_geom.items()
    }
    squash_rows = batch * dims.num_primary
    block_rows = max(min(SQUASH_BLOCK_ROWS, squash_rows), 1)
    for name, wl in conv_wls.items():
        prof = by_name[name]
        block = plan_matmul(wl, vmem_budget)
        if train:
            # The backward's three matmuls reuse this tile choice, and
            # matmul_at_b streams TWO bm-tall operands -- shrink the
            # forward pick until the backward peak also honors the
            # budget (plan_matmul raises when nothing fits).
            eff = vmem_budget
            while (_conv_bwd_matmul_vmem(block, wl.m, wl.k, wl.n)
                   > vmem_budget and eff > 1):
                eff = eff * 3 // 4
                block = plan_matmul(wl, eff)
        bias_tile = 2 * block.block_n * ELEM_BYTES
        op = OpPlan(name=name, kernel="conv_im2col", workload=wl, block=block,
                    vmem_bytes=max(block.vmem_total + bias_tile,
                                   conv_patch[name]),
                    est_cycles=block.est_cycles,
                    requirement=_requirement(prof), profiles=(prof,),
                    hbm_bytes=(block.hbm_bytes
                               + conv_extract_hbm_bytes(*conv_geom[name],
                                                        batch=batch)),
                    patch_rows=conv_patch_rows[name])
        if name == "PrimaryCaps":
            # The primary-capsule squash activation rides on this op: fused
            # into the matmul epilogue when every n-tile holds whole
            # capsules (the kernel clamps the tile to N), otherwise a
            # standalone blocked squash pass.
            if min(block.block_n, wl.n) % dims.primary_dim == 0:
                op = dataclasses.replace(op, kernel="conv_im2col+squash",
                                         block_rows=block_rows)
            else:
                op = dataclasses.replace(
                    op, block_rows=block_rows,
                    vmem_bytes=max(op.vmem_bytes,
                                   2 * block_rows * dims.primary_dim
                                   * ELEM_BYTES))
            # On a per-op plan this op's output u round-trips HBM to
            # reach the votes/routing megakernel (share of the plan's
            # forward_hbm_bytes; the pipelined pair reports 0 here).
            op = dataclasses.replace(
                op, intermediate_hbm_bytes=primary_intermediate_hbm_bytes(
                    batch, dims.num_primary, dims.primary_dim))
        ops.append(op)

    # Routing stack: ONE fused votes+routing megakernel per layer
    # instance (the historical single-op ClassCaps head is the one-layer
    # case).  Each layer runs its own resident-vs-streamed DSE at ITS
    # dimensions -- a PlanError names the offending layer -- and residual
    # coupling halves carry the [B, J*D] skip operand in their footprint
    # and an extra skip read in their traffic.
    layer_plans: list[tuple] = []
    for pos, lay in enumerate(stack):
        suffix = lay.name[len(FUSED_NAME):]
        lay_profs = tuple(by_name[n + suffix] for n in FUSED_COVERS)
        sched = plan_votes_routing(lay.in_caps, lay.in_dim, lay.jd,
                                   lay.num_caps, batch=batch,
                                   iters=lay.iters,
                                   vmem_budget=vmem_budget,
                                   name=lay.name, residual=lay.residual)
        votes_cycles = sched.workload.flops / (2 * MXU * MXU)
        routing_cycles = sum(p.total_cycles for p in lay_profs[1:])
        hbm = votes_routing_hbm_bytes(batch, lay.in_caps, lay.in_dim,
                                      lay.jd, sched.n_passes,
                                      block_i=sched.block_i)
        if lay.residual:
            hbm += batch * lay.jd * ELEM_BYTES     # skip operand read
        # An intermediate layer's output round-trips HBM to the next
        # layer's kernel call; the FINAL layer's v is the network output.
        inter = (primary_intermediate_hbm_bytes(batch, lay.num_caps,
                                                lay.caps_dim)
                 if pos + 1 < len(stack) else None)
        ops.append(OpPlan(
            name=lay.name, kernel="votes_routing", workload=sched.workload,
            block=None, block_i=sched.block_i, mode=sched.mode,
            n_passes=sched.n_passes,
            vmem_bytes=sched.vmem_bytes,
            est_cycles=votes_cycles * sched.n_passes + routing_cycles,
            hbm_bytes=hbm,
            uhat_hbm_bytes=0.0,
            intermediate_hbm_bytes=inter,
            requirement=_fused_requirement(lay.in_caps, lay.num_caps,
                                           lay.jd, lay_profs, sched,
                                           name=lay.name),
            profiles=lay_profs))
        layer_plans.append((lay, lay_profs, sched, votes_cycles,
                            routing_cycles))

    # Pipelined producer->consumer pair: replace [PrimaryCaps, fused
    # megakernel] with ONE OpPlan whose kernel streams the conv's
    # squash-epilogue output straight from VMEM scratch into the
    # votes/routing accumulation.  Falls back to the per-op pair above
    # when the combined footprint exceeds the budget (PlanError only
    # when neither fits -- the per-op planning already raised then).
    conv1_op, pc_op = ops[0], ops[1]
    first, first_profs, _, first_votes, first_routing = layer_plans[0]
    pipe_sched = None
    if pipeline and not first.residual:
        # The pipelined pair fuses PrimaryCaps with the FIRST routing
        # layer (whatever its width); a residual first half cannot
        # pipeline -- its kernel consumes a skip operand that does not
        # exist until the producer has run.
        try:
            pipe_sched = plan_primary_routing(
                dims.pc_out ** 2, dims.pc_k ** 2 * dims.pc_cin,
                dims.pc_cout, first.in_caps, first.in_dim, first.jd,
                first.num_caps, batch=batch, iters=first.iters,
                vmem_budget=vmem_budget)
            # The pipelined pair still runs the im2col patch extraction
            # as its own call; its (row-blocked) footprint caps the
            # pair's real peak.  A schedule that fits the budget while
            # that call does not is a claim the lowering cannot honor
            # (the static auditor measured the patch call as the peak on
            # degraded budgets), so the pair's footprint is the max of
            # the two, and when even a one-row extraction block is over
            # budget the pair falls back to the per-op path.
            if conv_patch["PrimaryCaps"] > vmem_budget:
                pipe_sched = None
        except PlanError:
            pipe_sched = None            # per-op pair is the fallback
    if pipe_sched is not None:
        pipe_profs = (by_name["PrimaryCaps"],) + first_profs
        prod_cycles = pipe_sched.workload.flops / (2 * MXU * MXU)
        ops = [conv1_op, OpPlan(
            name=PIPE_NAME, kernel="primary_routing",
            workload=pipe_sched.workload, block=pipe_sched.block,
            block_i=pipe_sched.block_i, block_k=pipe_sched.block_k,
            mode=pipe_sched.mode, n_passes=pipe_sched.n_passes,
            patch_rows=conv_patch_rows["PrimaryCaps"],
            vmem_bytes=max(pipe_sched.vmem_bytes,
                           conv_patch["PrimaryCaps"]),
            est_cycles=(prod_cycles + first_votes * pipe_sched.n_passes
                        + first_routing),
            hbm_bytes=(primary_routing_hbm_bytes(
                batch, dims.pc_out ** 2, dims.pc_k ** 2 * dims.pc_cin,
                dims.pc_cout, first.in_caps, first.in_dim, first.jd,
                pipe_sched.n_passes, block_i=pipe_sched.block_i,
                block_k=pipe_sched.block_k)
                # ...plus the im2col extraction feeding the produce
                # phase (image read + patch store), which the routing
                # model deliberately excludes.
                + conv_extract_hbm_bytes(*conv_geom["PrimaryCaps"],
                                         batch=batch)),
            uhat_hbm_bytes=0.0,
            intermediate_hbm_bytes=(
                0.0 if len(stack) == 1 else
                primary_intermediate_hbm_bytes(batch, first.num_caps,
                                               first.caps_dim)),
            requirement=_pipe_requirement(first.in_caps, first.num_caps,
                                          first.jd, pipe_profs, pipe_sched),
            profiles=pipe_profs)] + ops[3:]

    if train:
        # Backward OpPlans, reverse network order.  The fused backward
        # gets its own schedule DSE (larger scratch than the forward:
        # a budget can plan forward-only); the conv backwards reuse the
        # forward tiles for their two (three with the squash recompute)
        # blocked matmuls plus the col2im scatter, whose peak footprint
        # matches the forward's (the stages run sequentially).
        for lay, lay_profs, fwd_sched, votes_cycles, routing_cycles \
                in reversed(layer_plans):
            bwd_sched = plan_votes_routing_bwd(
                lay.in_caps, lay.in_dim, lay.jd, lay.num_caps,
                batch=batch, iters=lay.iters, vmem_budget=vmem_budget,
                name=lay.name)
            bwd_profs = tuple(_backward_profile(p)
                              for p in reversed(lay_profs))
            est = votes_cycles * bwd_sched.n_passes + 2 * routing_cycles
            hbm = votes_routing_bwd_hbm_bytes(
                batch, lay.in_caps, lay.in_dim, lay.jd,
                mode=bwd_sched.mode, iters=lay.iters,
                block_i=bwd_sched.block_i)
            vmem = bwd_sched.vmem_bytes
            if lay.residual:
                # Reversible inversion (MoCapsNet-style): the backward
                # first replays this coupling half FORWARD from the
                # reconstructed segment state to invert the residual add,
                # then runs the ordinary fused VJP -- the recompute cost
                # of never saving the stack's activations.
                est += votes_cycles * fwd_sched.n_passes + routing_cycles
                hbm += votes_routing_hbm_bytes(
                    batch, lay.in_caps, lay.in_dim, lay.jd,
                    fwd_sched.n_passes, block_i=fwd_sched.block_i)
                vmem = max(vmem, fwd_sched.vmem_bytes)
            ops.append(OpPlan(
                name=lay.name + BWD_SUFFIX, kernel="votes_routing_bwd",
                workload=bwd_sched.workload, block=None,
                block_i=bwd_sched.block_i, mode=bwd_sched.mode,
                n_passes=bwd_sched.n_passes,
                vmem_bytes=vmem,
                est_cycles=est,
                hbm_bytes=hbm,
                uhat_hbm_bytes=0.0,
                requirement=_fused_bwd_requirement(
                    lay.in_caps, lay.num_caps, lay.jd, lay.iters,
                    bwd_profs, bwd_sched, name=lay.name),
                profiles=bwd_profs))
        for fwd in (pc_op, conv1_op):           # PrimaryCaps, then Conv1
            wl = fwd.workload
            # + pre-act recompute: the squash backward replays the conv
            # output (always, on a pipelined plan -- its VJP recomputes
            # pre-activation from patches regardless of n-tile alignment).
            matmuls = 3 if (fwd.fuses_squash
                            or (pipe_sched is not None
                                and fwd is pc_op)) else 2
            patches = wl.m * wl.k * ELEM_BYTES       # dpatches write + read
            prof = _backward_profile(fwd.profile)
            ops.append(OpPlan(
                name=fwd.name + BWD_SUFFIX, kernel="conv_im2col_bwd",
                workload=wl, block=fwd.block, block_rows=fwd.block_rows,
                patch_rows=fwd.patch_rows,
                # The backward's peak adds the col2im scatter (dx image
                # resident, the dpatches stream double-buffered) and the
                # at_b/dpatches matmuls, whose two bm-tall streams can
                # exceed the forward tiles' peak (both measured by the
                # static auditor).
                vmem_bytes=max(fwd.vmem_bytes,
                               _conv_patch_bwd_vmem(
                                   *conv_geom[fwd.name], batch=batch,
                                   block_p=fwd.patch_rows),
                               _conv_bwd_matmul_vmem(fwd.block, wl.m,
                                                     wl.k, wl.n)),
                est_cycles=matmuls * fwd.est_cycles,
                hbm_bytes=matmuls * fwd.block.hbm_bytes + 2 * patches,
                requirement=_requirement(prof), profiles=(prof,)))

    plan = ExecutionPlan(cfg=cfg, batch=batch, dataflow=dataflow,
                         vmem_budget=vmem_budget, ops=tuple(ops),
                         train=train)
    plan.validate()
    return plan


# ---------------------------------------------------------------------------
# Graceful degradation: replanning under a REDUCED VMEM budget
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DegradeReport:
    """What ``degrade_plan`` gave up to fit the reduced budget.

    ``concessions`` is human-readable, one entry per fallback rung taken
    relative to the full-budget plan: the pipelined pair dissolving to
    per-op, a layer flipping resident -> streamed, a shrunk ``block_i`` /
    ``block_k`` / conv tile, and finally a reduced batch.  Empty means
    the degraded budget still admits the exact full-budget schedule.
    """

    vmem_budget: int
    requested_batch: int
    batch: int
    concessions: tuple[str, ...]

    @property
    def degraded(self) -> bool:
        return bool(self.concessions)


def _feasible_batch(cfg: CapsNetConfig, vmem_budget: int,
                    train: bool) -> int:
    """Largest batch the fused-schedule footprint models admit under
    ``vmem_budget`` (the binding constraint in practice; the conv ops'
    tiles shrink independently).  Train plans also bound by the backward
    footprint -- it is larger, so it usually decides."""
    best = None
    for lay in cfg.routing_stack():
        extra = lay.jd * ELEM_BYTES if lay.residual else 0
        b = _fused_max_batch(lay.in_caps, lay.in_dim, lay.jd, lay.num_caps,
                             vmem_budget, extra)
        if train:
            b = min(b, _fused_bwd_max_batch(lay.in_caps, lay.in_dim, lay.jd,
                                            lay.num_caps, lay.iters,
                                            vmem_budget))
        best = b if best is None else min(best, b)
    return best or 0


def _plan_concessions(baseline: ExecutionPlan,
                      plan: ExecutionPlan) -> tuple[str, ...]:
    """Human-readable diff of what ``plan`` gave up vs ``baseline``."""
    notes: list[str] = []
    if plan.batch < baseline.batch:
        notes.append(f"batch {baseline.batch} -> {plan.batch}")
    base_names = {op.name for op in baseline.ops}
    plan_names = {op.name for op in plan.ops}
    if PIPE_NAME in base_names and PIPE_NAME not in plan_names:
        notes.append(f"pipelined {PIPE_NAME} pair -> per-op "
                     f"(inter-layer u round-trips HBM again)")
    base_ops = {op.name: op for op in baseline.ops}
    for op in plan.ops:
        base = base_ops.get(op.name)
        if base is None:
            continue
        if base.mode != op.mode and op.mode is not None:
            notes.append(f"{op.name}: {base.mode} -> {op.mode}")
        if (base.block_i is not None and op.block_i is not None
                and op.block_i < base.block_i):
            notes.append(f"{op.name}: block_i {base.block_i} "
                         f"-> {op.block_i}")
        if (base.block_k is not None and op.block_k is not None
                and op.block_k < base.block_k):
            notes.append(f"{op.name}: block_k {base.block_k} "
                         f"-> {op.block_k}")
        if (base.block is not None and op.block is not None
                and (op.block.block_m, op.block.block_k, op.block.block_n)
                != (base.block.block_m, base.block.block_k,
                    base.block.block_n)):
            notes.append(
                f"{op.name}: conv tiles "
                f"({base.block.block_m},{base.block.block_k},"
                f"{base.block.block_n}) -> ({op.block.block_m},"
                f"{op.block.block_k},{op.block.block_n})")
    return tuple(notes)


def degrade_plan(cfg: CapsNetConfig = CapsNetConfig(),
                 vmem_budget: int = VMEM_BYTES, *, batch: int = 1,
                 train: bool = False, pipeline: bool = False,
                 min_batch: int = 1
                 ) -> tuple[ExecutionPlan, DegradeReport]:
    """Replan ``cfg`` under a (possibly reduced) ``vmem_budget``,
    reporting what was given up relative to the full-budget plan.

    This is the runtime's graceful-degradation chain -- the DESCNet-style
    degraded-scratchpad operating points taken online.  ``compile_plan``
    already embodies most of the ladder (pipelined pair -> per-op pair,
    resident -> streamed, shrinking ``block_i``/``block_k``/conv tiles),
    so the walk here is: recompile at the reduced budget, and when even
    streamed ``block_i=1`` cannot fit the batch, drop to the largest
    feasible batch (``_fused_max_batch`` bound, halving as a safety net
    when a non-routing constraint binds instead) down to ``min_batch``.

    At the FULL budget the returned plan is bit-identical to
    ``compile_plan(cfg, batch=batch, ...)`` -- the memoized plan object
    itself -- and the report carries zero concessions: with no fault
    there is no behavior change.  Raises ``PlanError`` when no batch
    ``>= min_batch`` fits (callers with a fixed slot batch pass
    ``min_batch=slots`` and treat the raise as "fall back to the
    reference backend").
    """
    if min_batch < 1 or min_batch > batch:
        raise PlanError(f"min_batch must be in [1, batch={batch}], "
                        f"got {min_batch}")
    baseline = compile_plan(cfg, batch=batch, train=train,
                            pipeline=pipeline)
    b, last_err = batch, None
    while b >= min_batch:
        try:
            plan = compile_plan(cfg, batch=b, vmem_budget=vmem_budget,
                                train=train, pipeline=pipeline)
            return plan, DegradeReport(
                vmem_budget=vmem_budget, requested_batch=batch, batch=b,
                concessions=_plan_concessions(baseline, plan))
        except ValueError as err:        # PlanError, or the conv planner's
            last_err = err               # bare no-block-fits ValueError
            feas = _feasible_batch(cfg, vmem_budget, train)
            # Jump straight to the model's feasible batch when it is the
            # binding constraint; halve as the safety net when it is not
            # (a conv tiling bound, say).  Always strictly decrease.
            nxt = max(min(feas, b - 1), b // 2)
            b = nxt if nxt < b else b - 1
    raise PlanError(
        f"degrade_plan: no feasible plan for batch >= {min_batch} under "
        f"the degraded {vmem_budget} B VMEM budget "
        f"(requested batch {batch}): {last_err}")


def plan_table(plans: Sequence[tuple[str, ExecutionPlan]]) -> list[dict]:
    """Flat summary rows for benchmarks/examples."""
    rows = []
    for tag, plan in plans:
        for r in plan.summary():
            rows.append(dict(plan=tag, **r))
    return rows
