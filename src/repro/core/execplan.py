"""ExecutionPlan: ONE compiled schedule shared by kernels, PMU, and serving.

CapStore's core contribution is a single per-operation schedule that sizes
each on-chip memory and drives power-gating from it (paper Secs. 4.1-4.3).
Before this module the repo had three parallel models of that schedule:
``kernels/ops.py`` re-ran the block-shape DSE per call, ``core/dse.py``
derived PMU phases from the analysis profiles, and ``core/capsnet.py``
ignored both.  ``compile_plan`` unifies them: it compiles a
``CapsNetConfig`` into per-operation

  * Pallas block shapes (``planner.plan_matmul`` energy-argmin DSE),
  * VMEM footprints (checked against the budget -- the TPU analogue of
    the paper's sized-to-fit SRAMs),
  * estimated cycles, and
  * auto-derived ``PhaseRequirement``s (analysis.py dataflow model)

so the schedule the kernels *execute* is the same schedule the PMU/energy
model *scores* (``pmu.schedule_from_plan``, ``dse.explore(plan=...)``) and
the serving engine *amortizes* (``serve/capsule.py``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

from repro.core import analysis
from repro.core.analysis import CapsNetDims, OperationProfile
from repro.core.capsnet import CapsNetConfig
from repro.core.planner import (VMEM_BYTES, BlockPlan, MatmulWorkload,
                                plan_matmul)
from repro.core.pmu import PhaseRequirement

# Kernels run in fp32 (interpret-mode validated; fp32 accumulation on TPU).
ELEM_BYTES = 4
SQUASH_BLOCK_ROWS = 1024


class PlanError(ValueError):
    """An ExecutionPlan violates one of its invariants."""


@dataclasses.dataclass(frozen=True)
class OpPlan:
    """The compiled schedule entry for one CapsuleNet operation.

    ``kernel`` names the executor -- all Pallas: ``conv_im2col``
    (optionally ``+squash`` when the primary-capsule activation fuses into
    the epilogue), ``caps_votes``, and ``routing``.  Matmul-view operations
    carry the planner's energy-argmin ``block``; its ``block_m/k/n`` (conv)
    and ``block_i`` / ``block_rows`` are the concrete grid tiles the kernel
    wrappers consume.  ``requirement`` is the PMU phase (ASIC dataflow-model
    bytes/cycles) the gating schedule is built from.
    """

    name: str
    kernel: str
    workload: MatmulWorkload | None
    block: BlockPlan | None
    vmem_bytes: int
    est_cycles: float
    requirement: PhaseRequirement
    profile: OperationProfile
    block_i: int | None = None
    block_rows: int | None = None

    @property
    def fuses_squash(self) -> bool:
        """Whether this op's epilogue absorbs the squash activation."""
        return self.kernel.endswith("+squash")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    cfg: CapsNetConfig
    batch: int
    dataflow: str
    vmem_budget: int
    ops: tuple[OpPlan, ...]

    def op(self, name: str) -> OpPlan:
        for op in self.ops:
            if op.name == name:
                return op
        raise KeyError(f"no operation {name!r} in plan "
                       f"({[o.name for o in self.ops]})")

    @property
    def profiles(self) -> tuple[OperationProfile, ...]:
        """The dataflow profiles this plan was compiled from (feeds dse)."""
        return tuple(op.profile for op in self.ops)

    def phase_requirements(self) -> tuple[PhaseRequirement, ...]:
        """Per-operation PMU phases, in execution order."""
        return tuple(op.requirement for op in self.ops)

    @property
    def peak_vmem_bytes(self) -> int:
        return max(op.vmem_bytes for op in self.ops)

    def validate(self) -> None:
        """Check the plan invariants; raises ``PlanError`` on violation."""
        if self.batch < 1:
            raise PlanError(f"batch must be >= 1, got {self.batch}")
        names = [op.name for op in self.ops]
        if len(set(names)) != len(names):
            raise PlanError(f"duplicate operation names: {names}")
        expected = [p.name for p in
                    analysis.capsnet_profiles(self.dataflow,
                                              analysis.dims_from_config(self.cfg))]
        if names != expected:
            raise PlanError(f"phases {names} do not cover operations {expected}")
        for op in self.ops:
            if op.vmem_bytes > self.vmem_budget:
                raise PlanError(
                    f"{op.name}: VMEM footprint {op.vmem_bytes} exceeds "
                    f"budget {self.vmem_budget}")
            if op.requirement.name != op.name:
                raise PlanError(f"{op.name}: phase named {op.requirement.name!r}")
            if op.requirement.duration_cycles <= 0:
                raise PlanError(f"{op.name}: non-positive phase duration")
            if op.block is not None and op.block.vmem_total > self.vmem_budget:
                raise PlanError(f"{op.name}: block tiles exceed VMEM budget")
            if op.block_i is not None and not (
                    1 <= op.block_i <= max(self.cfg.num_primary, 1)):
                raise PlanError(f"{op.name}: block_i {op.block_i} out of range")

    def summary(self) -> list[dict]:
        rows = []
        for op in self.ops:
            rows.append(dict(
                name=op.name,
                kernel=op.kernel,
                block=((op.block.block_m, op.block.block_k, op.block.block_n)
                       if op.block else None),
                block_i=op.block_i,
                block_rows=op.block_rows,
                vmem_kib=op.vmem_bytes / 1024,
                est_cycles=op.est_cycles,
                req_kib=op.requirement.required_bytes / 1024,
                duration_cycles=op.requirement.duration_cycles,
            ))
        return rows


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------

def _requirement(profile: OperationProfile) -> PhaseRequirement:
    return PhaseRequirement(name=profile.name,
                            required_bytes=profile.total_mem,
                            duration_cycles=profile.total_cycles)


def _votes_vmem(batch: int, block_i: int, caps_dim: int, out_dim: int) -> int:
    """caps_votes footprint per grid step (double-buffered streams)."""
    data = batch * block_i * caps_dim * ELEM_BYTES
    weight = block_i * out_dim * caps_dim * ELEM_BYTES
    accum = batch * block_i * out_dim * ELEM_BYTES
    return 2 * (data + weight) + accum


def _votes_max_batch(caps_dim: int, out_dim: int, vmem_budget: int) -> int:
    """Largest batch whose block_i=1 caps-votes footprint fits the budget."""
    fixed = 2 * out_dim * caps_dim * ELEM_BYTES          # weight tile
    per_batch = (2 * caps_dim + out_dim) * ELEM_BYTES    # data + accum rows
    return max((vmem_budget - fixed) // per_batch, 0)


def _votes_block_i(dims: CapsNetDims, batch: int, vmem_budget: int
                   ) -> tuple[MatmulWorkload, BlockPlan, int]:
    """Planner pick for the caps-votes i-tile, shrunk to fit the budget.

    The kernel supports ragged final i-blocks (grid = cdiv), so the planned
    block is only clamped to the capsule count -- never collapsed to 1 for
    non-power-of-two counts.  Raises ``PlanError`` when even ``block_i=1``
    exceeds the budget (instead of letting ``validate()`` fail later with a
    generic footprint message).
    """
    out_dim = dims.num_classes * dims.class_dim
    wl = MatmulWorkload(m=dims.num_primary, k=dims.primary_dim, n=out_dim)
    block = plan_matmul(wl, vmem_budget)
    bi = max(min(block.block_m, dims.num_primary), 1)
    while bi > 1 and _votes_vmem(batch, bi, dims.primary_dim,
                                 out_dim) > vmem_budget:
        bi //= 2
    need = _votes_vmem(batch, bi, dims.primary_dim, out_dim)
    if need > vmem_budget:
        raise PlanError(
            f"ClassCaps-FC: no feasible schedule at batch={batch}: even "
            f"block_i=1 needs {need} B of VMEM, over the {vmem_budget} B "
            f"budget; largest feasible batch is "
            f"{_votes_max_batch(dims.primary_dim, out_dim, vmem_budget)}")
    return wl, block, bi


def _conv_patch_vmem(in_hw: int, cin: int, k: int, out_hw: int) -> int:
    """im2col patch-extraction footprint per grid step (one batch element):
    the resident input feature map plus the emitted patch matrix."""
    image = in_hw * in_hw * cin * ELEM_BYTES
    patches = out_hw * out_hw * k * k * cin * ELEM_BYTES
    return image + patches


def _routing_vmem(dims: CapsNetDims) -> int:
    """Fused routing footprint per grid step (one batch element)."""
    jd = dims.num_classes * dims.class_dim
    votes = dims.num_primary * jd * ELEM_BYTES
    logits = dims.num_primary * dims.num_classes * ELEM_BYTES
    out = jd * ELEM_BYTES
    return votes + logits + out


@functools.lru_cache(maxsize=64)
def compile_plan(cfg: CapsNetConfig = CapsNetConfig(), *, batch: int = 1,
                 vmem_budget: int = VMEM_BYTES,
                 dataflow: str = "resident") -> ExecutionPlan:
    """Compile ``cfg`` into the per-operation ExecutionPlan (memoized:
    plans are immutable and the block-shape DSE runs once per shape).

    The five analysis operations map onto executors as follows:

      Conv1, PrimaryCaps -> ``conv_im2col`` kernels (strided Pallas patch
                            extraction + blocked matmul over the planner's
                            block_m/k/n tiles; PrimaryCaps fuses the squash
                            activation into the epilogue when its n-tile is
                            capsule-aligned)
      ClassCaps-FC       -> ``caps_votes`` kernel (plan-chosen i-tile)
      Sum+Squash,
      Update+Sum         -> ONE fused ``routing`` kernel (all iterations
                            in VMEM -- the paper's on-chip-resident loop)

    ``requirement``s (PMU phases) keep the paper's per-inference dataflow
    model; ``vmem_bytes`` scale with ``batch`` where the kernel batches.
    """
    dims = analysis.dims_from_config(cfg)
    profiles = analysis.capsnet_profiles(dataflow, dims)
    by_name = {p.name: p for p in profiles}
    ops: list[OpPlan] = []

    # Conv stack: im2col matmuls the kernels EXECUTE with the planned
    # tiles.  Workloads carry the real batched row count and fp32 element
    # size so ``block.vmem_total`` is the honest double-buffered footprint
    # (patch tile + weight tile + accumulator) of the running kernel.
    conv_wls = {
        "Conv1": MatmulWorkload(m=batch * dims.conv1_out ** 2,
                                k=dims.conv1_k ** 2 * dims.conv1_cin,
                                n=dims.conv1_cout, in_bytes=ELEM_BYTES),
        "PrimaryCaps": MatmulWorkload(m=batch * dims.pc_out ** 2,
                                      k=dims.pc_k ** 2 * dims.pc_cin,
                                      n=dims.pc_cout, in_bytes=ELEM_BYTES),
    }
    conv_patch = {
        "Conv1": _conv_patch_vmem(dims.in_hw, dims.conv1_cin, dims.conv1_k,
                                  dims.conv1_out),
        "PrimaryCaps": _conv_patch_vmem(dims.conv1_out, dims.pc_cin,
                                        dims.pc_k, dims.pc_out),
    }
    squash_rows = batch * dims.num_primary
    block_rows = max(min(SQUASH_BLOCK_ROWS, squash_rows), 1)
    for name, wl in conv_wls.items():
        prof = by_name[name]
        block = plan_matmul(wl, vmem_budget)
        bias_tile = 2 * block.block_n * ELEM_BYTES
        op = OpPlan(name=name, kernel="conv_im2col", workload=wl, block=block,
                    vmem_bytes=max(block.vmem_total + bias_tile,
                                   conv_patch[name]),
                    est_cycles=block.est_cycles,
                    requirement=_requirement(prof), profile=prof)
        if name == "PrimaryCaps":
            # The primary-capsule squash activation rides on this op: fused
            # into the matmul epilogue when every n-tile holds whole
            # capsules (the kernel clamps the tile to N), otherwise a
            # standalone blocked squash pass.
            if min(block.block_n, wl.n) % dims.primary_dim == 0:
                op = dataclasses.replace(op, kernel="conv_im2col+squash",
                                         block_rows=block_rows)
            else:
                op = dataclasses.replace(
                    op, block_rows=block_rows,
                    vmem_bytes=max(op.vmem_bytes,
                                   2 * block_rows * dims.primary_dim
                                   * ELEM_BYTES))
        ops.append(op)

    prof = by_name["ClassCaps-FC"]
    wl, block, block_i = _votes_block_i(dims, batch, vmem_budget)
    ops.append(OpPlan(
        name="ClassCaps-FC", kernel="caps_votes", workload=wl, block=block,
        block_i=block_i,
        vmem_bytes=_votes_vmem(batch, block_i, dims.primary_dim, wl.n),
        est_cycles=block.est_cycles, requirement=_requirement(prof),
        profile=prof))

    routing_bytes = _routing_vmem(dims)
    if routing_bytes > vmem_budget:
        raise PlanError(
            f"fused routing state ({routing_bytes} B) exceeds the VMEM "
            f"budget ({vmem_budget} B); no resident schedule exists")
    for name in ("Sum+Squash", "Update+Sum"):
        prof = by_name[name]
        ops.append(OpPlan(
            name=name, kernel="routing", workload=None, block=None,
            vmem_bytes=routing_bytes, est_cycles=prof.total_cycles,
            requirement=_requirement(prof), profile=prof))

    plan = ExecutionPlan(cfg=cfg, batch=batch, dataflow=dataflow,
                         vmem_budget=vmem_budget, ops=tuple(ops))
    plan.validate()
    return plan


def plan_table(plans: Sequence[tuple[str, ExecutionPlan]]) -> list[dict]:
    """Flat summary rows for benchmarks/examples."""
    rows = []
    for tag, plan in plans:
        for r in plan.summary():
            rows.append(dict(plan=tag, **r))
    return rows
