"""CACTI-P-flavoured analytical energy/area model (32 nm) for CapStore.

The paper evaluates SRAM organizations with CACTI-P [9] and synthesizes the
CapsAcc accelerator in a 32 nm CMOS library.  Neither tool is available
offline, so this module implements an analytical model with the same
structure CACTI-P exposes (per-access dynamic energy, leakage power, area,
all scaling with capacity / ports / banks) and constants calibrated so the
paper's published headline results reproduce (see EXPERIMENTS.md
§Paper-validation).  Every constant is a named module-level value so the
calibration is explicit and auditable.

Units: energy pJ, power mW, time s, area mm^2, capacity bytes.
"""

from __future__ import annotations

import dataclasses
import math

# --------------------------------------------------------------------------
# Technology constants (32 nm, calibrated against CapStore Table 2 / Fig 5).
# --------------------------------------------------------------------------

CLOCK_HZ: float = 250e6          # CapsAcc operating frequency
REFERENCE_CAP_BYTES: float = 64 * 1024

# SRAM dynamic energy per access of one word (we charge per element access;
# element width is folded into the access counts produced by analysis.py).
SRAM_E0_PJ: float = 6.0          # per access at 64 KiB, single port
SRAM_CAP_EXP: float = 0.5        # E ~ sqrt(capacity): longer bit/word-lines
# Multi-port SRAM access energy grows super-linearly (every port adds
# bitline/wordline capacitance to every access): (1 + f*(p-1))^2, as CACTI's
# multiported models do.
SRAM_PORT_DYN_FACTOR: float = 0.7
SRAM_WRITE_FACTOR: float = 1.10      # writes slightly costlier than reads

# SRAM leakage power (dominant at multi-MB sizes -> drives the 8 MB result).
SRAM_LEAK_MW_PER_64K: float = 18.0
SRAM_PORT_LEAK_FACTOR: float = 1.0   # extra leakage per extra port (linear)
SRAM_PG_RESIDUAL: float = 0.03       # fraction of leakage left when gated OFF

# SRAM area.
SRAM_A0_MM2: float = 0.145           # 64 KiB single-port bank @32 nm
SRAM_PORT_AREA_FACTOR: float = 0.85  # per extra port (interconnect overhead)
SRAM_BANK_AREA_OVERHEAD: float = 0.035   # per extra bank (decoders, routing)
# Sleep-transistor area is charged per gated byte; the paper's PG variants
# pay a large area premium (PG-SMP is ~3x SMP in Table 2).
PG_AREA_FACTOR: float = 1.9          # sleep transistors + PMU wiring
PG_WAKEUP_PJ_PER_BYTE: float = 0.012  # energy to recharge a gated sector
PG_WAKEUP_CYCLES_PER_KB: float = 0.9  # latency of the 2-way handshake

# Off-chip DRAM (LPDDR-class), per element access as counted by analysis.py.
DRAM_E_PJ: float = 150.0
DRAM_STATIC_MW: float = 20.0         # background + refresh power
DRAM_BYTES_PER_CYCLE: float = 16.0   # interface bandwidth at CLOCK_HZ

# Accelerator (16x16 PE array + activation + control), from "synthesis":
# ~0.7 pJ/MAC at 32 nm plus a fixed idle power; area from the CapsAcc paper.
PE_MAC_PJ: float = 0.7
ACCEL_STATIC_MW: float = 24.0
ACCEL_AREA_MM2: float = 28.0         # 256 PEs + activation/control, 32 nm
# Small pipeline buffers between array and memories (Fig 3 "buffers").
BUFFER_E_PJ: float = 0.9             # per element access
BUFFER_AREA_MM2: float = 3.0


@dataclasses.dataclass(frozen=True)
class SRAMConfig:
    """One physical SRAM: capacity, ports, banking and power-gating."""

    name: str
    capacity_bytes: int
    ports: int = 1
    banks: int = 16
    sectors_per_bank: int = 1
    power_gated: bool = False

    @property
    def sector_bytes(self) -> float:
        return self.capacity_bytes / (self.banks * self.sectors_per_bank)

    # -- dynamic ----------------------------------------------------------
    def access_energy_pj(self, write: bool = False) -> float:
        if self.capacity_bytes == 0:
            return 0.0
        # Banking shortens word/bit lines: the accessed bank is what matters.
        bank_bytes = self.capacity_bytes / self.banks
        scale = (max(bank_bytes, 256.0) / REFERENCE_CAP_BYTES) ** SRAM_CAP_EXP
        port = (1.0 + SRAM_PORT_DYN_FACTOR * (self.ports - 1)) ** 2
        e = SRAM_E0_PJ * scale * port
        if write:
            e *= SRAM_WRITE_FACTOR
        return e

    # -- static -----------------------------------------------------------
    def leakage_mw(self, on_fraction: float = 1.0) -> float:
        """Leakage with `on_fraction` of the capacity powered.

        Without power gating the whole array leaks regardless of use.
        """
        if self.capacity_bytes == 0:
            return 0.0
        full = (
            SRAM_LEAK_MW_PER_64K
            * (self.capacity_bytes / REFERENCE_CAP_BYTES)
            * (1.0 + SRAM_PORT_LEAK_FACTOR * (self.ports - 1))
        )
        if not self.power_gated:
            return full
        on_fraction = min(max(on_fraction, 0.0), 1.0)
        # Gated-OFF sectors retain a small residual leakage.
        return full * (on_fraction + SRAM_PG_RESIDUAL * (1.0 - on_fraction))

    def quantize_on_fraction(self, wanted: float) -> float:
        """Round the wanted ON fraction up to whole sectors (granularity)."""
        total = self.banks * self.sectors_per_bank
        if self.capacity_bytes == 0 or total <= 0:
            return 0.0
        # Sector-index gating spans all banks (one sleep transistor per
        # sector index, paper Sec. 4.1) -> granularity is 1/sectors_per_bank.
        steps = self.sectors_per_bank
        return min(1.0, math.ceil(max(wanted, 0.0) * steps) / steps)

    # -- power gating transitions ------------------------------------------
    def wakeup_energy_pj(self, sectors_woken: int) -> float:
        if not self.power_gated or sectors_woken <= 0:
            return 0.0
        # One sleep transistor wakes `banks` sectors (one per bank).
        return PG_WAKEUP_PJ_PER_BYTE * self.sector_bytes * self.banks * sectors_woken

    def wakeup_latency_cycles(self, sectors_woken: int) -> float:
        if not self.power_gated or sectors_woken <= 0:
            return 0.0
        kb = self.sector_bytes * self.banks / 1024.0
        return PG_WAKEUP_CYCLES_PER_KB * kb  # sectors wake in parallel

    # -- area ---------------------------------------------------------------
    def area_mm2(self) -> float:
        if self.capacity_bytes == 0:
            return 0.0
        base = SRAM_A0_MM2 * (self.capacity_bytes / REFERENCE_CAP_BYTES)
        base *= (1.0 + SRAM_PORT_AREA_FACTOR * (self.ports - 1)) ** 2
        base *= 1.0 + SRAM_BANK_AREA_OVERHEAD * max(self.banks - 1, 0)
        if self.power_gated:
            base *= 1.0 + PG_AREA_FACTOR
        return base


def dram_energy_pj(accesses: float) -> float:
    return DRAM_E_PJ * accesses


def dram_static_mj(duration_s: float) -> float:
    return DRAM_STATIC_MW * duration_s  # mW * s = mJ


def accelerator_dynamic_mj(macs: float) -> float:
    return PE_MAC_PJ * macs * 1e-9


def accelerator_static_mj(duration_s: float) -> float:
    return ACCEL_STATIC_MW * duration_s  # mW * s = mJ


def buffer_energy_mj(accesses: float) -> float:
    return BUFFER_E_PJ * accesses * 1e-9


def pj_to_mj(pj: float) -> float:
    return pj * 1e-9


def cycles_to_s(cycles: float) -> float:
    return cycles / CLOCK_HZ
