"""CapsAcc dataflow model: per-operation memory requirements and accesses.

Reproduces the structure of CapStore Fig. 4: for every operation of the
CapsuleNet (Sabour et al. 2017) MNIST inference on a 16x16 systolic array we
derive

  * cycles                       (Fig. 4b)
  * on-chip size per component   (Fig. 4a/4c: data / weight / accumulator)
  * reads+writes per component   (Fig. 4d/4e)
  * off-chip accesses            (paper Eq. (1)/(2))

The paper's exact byte values are figure-bound and not recoverable from the
text, so the numbers here are re-derived from first principles with the
following documented dataflow assumptions (chosen to be consistent with all
of the paper's qualitative statements -- see DESIGN.md Sec. 1):

  * activations/weights are 16-bit fixed point, accumulators 32-bit
    (CapsAcc uses 25-bit internal accumulation; we round up to 32);
  * convolutions run output-stationary over *all* output channels
    (partial sums for the whole dense output live in the accumulator
    memory, strided selection happens on write-back) -> accumulator is the
    largest component of every operation and PrimaryCaps is the peak op;
  * conv weights stream through a double-buffered 16x16 tile
    (weight reuse across output positions -> tiny weight memory);
  * ClassCaps weights have no reuse at all and stream through a larger
    prefetch buffer;
  * all routing state (u_hat, b, c, s, v) stays on-chip across the routing
    iterations: u_hat lives in the accumulator memory where CC-FC produced
    it, coupling coefficients play the role of "weights".

A matmul-view of each operation drives the access counts: an [M,K] x [K,N]
product on the 16x16 array reads each weight once (weight-stationary
streaming), re-reads each input element once per 16-wide output-column
group, and performs one accumulator read-modify-write per 16-deep K tile.

The model is parametric over the network shape (``CapsNetDims``) so an
``ExecutionPlan`` can be compiled for any ``CapsNetConfig``; the module
constants below are the paper's MNIST instance and remain the defaults.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

ARRAY_DIM = 16          # 16x16 processing elements
ACT_BYTES = 2           # 16-bit activations / weights
ACC_BYTES = 4           # 32-bit partial sums

# CapsuleNet (MNIST) shape constants [Sabour et al. 2017]
IN_H = IN_W = 28
CONV1_K, CONV1_CIN, CONV1_COUT = 9, 1, 256
CONV1_OUT = 20                           # 28 - 9 + 1
PC_K, PC_CIN, PC_COUT, PC_STRIDE = 9, 256, 256, 2
PC_OUT = 6                               # floor((20 - 9)/2) + 1
NUM_PRIMARY = PC_OUT * PC_OUT * 32       # 1152 capsules
PRIMARY_DIM = 8
NUM_CLASSES = 10
CLASS_DIM = 16
ROUTING_ITERS = 3


@dataclasses.dataclass(frozen=True)
class CapsNetDims:
    """Shape of one CapsuleNet instance, as the dataflow model sees it.

    Defaults are the paper's MNIST network; ``dims_from_config`` derives an
    instance from a ``repro.core.capsnet.CapsNetConfig``.
    """

    in_hw: int = IN_H
    conv1_k: int = CONV1_K
    conv1_cin: int = CONV1_CIN
    conv1_cout: int = CONV1_COUT
    pc_k: int = PC_K
    pc_stride: int = PC_STRIDE
    pc_cout: int = PC_COUT
    num_primary_groups: int = 32
    primary_dim: int = PRIMARY_DIM
    num_classes: int = NUM_CLASSES
    class_dim: int = CLASS_DIM
    routing_iters: int = ROUTING_ITERS

    @property
    def conv1_out(self) -> int:
        return self.in_hw - self.conv1_k + 1

    @property
    def pc_cin(self) -> int:
        return self.conv1_cout

    @property
    def pc_out(self) -> int:
        return (self.conv1_out - self.pc_k) // self.pc_stride + 1

    @property
    def num_primary(self) -> int:
        return self.pc_out * self.pc_out * self.num_primary_groups


MNIST_DIMS = CapsNetDims()


@dataclasses.dataclass(frozen=True)
class RoutingLayerDims:
    """Duck-typed dims view of ONE routing layer of a deep capsule stack.

    The three routing profile builders (``classcaps_fc_profile``,
    ``sum_squash_profile``, ``update_sum_profile``) only read these five
    fields, so any layer of a ``caps_layers`` chain -- including the
    coupling halves of a ResCapsBlock -- profiles through the SAME
    builders the paper's single ClassCaps layer uses: ``num_primary`` is
    the layer's in-capsule count, ``num_classes``/``class_dim`` its
    output capsules.
    """

    num_primary: int
    primary_dim: int
    num_classes: int
    class_dim: int
    routing_iters: int


def dims_from_config(cfg) -> CapsNetDims:
    """Derive the dataflow dims from a ``CapsNetConfig`` (duck-typed)."""
    return CapsNetDims(
        in_hw=cfg.image_hw,
        conv1_k=cfg.conv1_kernel,
        conv1_cin=cfg.in_channels,
        conv1_cout=cfg.conv1_channels,
        pc_k=cfg.pc_kernel,
        pc_stride=cfg.pc_stride,
        pc_cout=cfg.pc_channels,
        num_primary_groups=cfg.num_primary_groups,
        primary_dim=cfg.primary_dim,
        num_classes=cfg.num_classes,
        class_dim=cfg.class_dim,
        routing_iters=cfg.routing_iters,
    )


@dataclasses.dataclass(frozen=True)
class OperationProfile:
    """Resource profile of one CapsuleNet inference operation."""

    name: str
    macs: float
    cycles: float
    # on-chip requirement (bytes) per component
    data_mem: float
    weight_mem: float
    accum_mem: float
    # on-chip accesses (element granularity)
    data_reads: float
    data_writes: float
    weight_reads: float
    weight_writes: float
    accum_reads: float
    accum_writes: float
    # off-chip accesses
    offchip_reads: float = 0.0
    offchip_writes: float = 0.0
    repeats: int = 1  # routing ops execute once per routing iteration

    @property
    def total_mem(self) -> float:
        return self.data_mem + self.weight_mem + self.accum_mem

    @property
    def total_cycles(self) -> float:
        return self.cycles * self.repeats

    def component(self, name: str) -> float:
        return {"data": self.data_mem, "weight": self.weight_mem,
                "accum": self.accum_mem}[name]

    def accesses(self, name: str) -> float:
        r = {"data": self.data_reads, "weight": self.weight_reads,
             "accum": self.accum_reads}[name]
        w = {"data": self.data_writes, "weight": self.weight_writes,
             "accum": self.accum_writes}[name]
        return (r + w) * self.repeats


def _tiles(n: int, t: int = ARRAY_DIM) -> int:
    return math.ceil(n / t)


def _matmul_accesses(m: int, k: int, n: int) -> dict:
    """Access counts for [M,K]x[K,N] on the 16x16 weight-stationary array."""
    kt = _tiles(k)
    nt = _tiles(n)
    return dict(
        weight_reads=float(k * n),                 # each weight read once
        data_reads=float(m * k * nt),              # re-stream per col-group
        accum_writes=float(m * n * kt),            # partial per K tile
        accum_reads=float(m * n * max(kt - 1, 0)),  # read-modify-write
        cycles=float(_tiles(m) * k * nt),
        macs=float(m) * k * n,
    )


# ---------------------------------------------------------------------------
# Per-operation profiles
# ---------------------------------------------------------------------------

def conv1_profile(dims: CapsNetDims = MNIST_DIMS) -> OperationProfile:
    m = dims.conv1_out * dims.conv1_out        # output positions
    k = dims.conv1_k * dims.conv1_k * dims.conv1_cin
    n = dims.conv1_cout
    a = _matmul_accesses(m, k, n)
    in_elems = dims.in_hw * dims.in_hw * dims.conv1_cin
    w_elems = k * n
    return OperationProfile(
        name="Conv1",
        macs=a["macs"],
        cycles=a["cycles"],
        data_mem=in_elems * ACT_BYTES,                       # full (tiny) input
        weight_mem=2 * k * ARRAY_DIM * ACT_BYTES,
        accum_mem=m * n * ACC_BYTES,                         # dense output @32b
        data_reads=a["data_reads"],
        data_writes=float(in_elems),
        weight_reads=a["weight_reads"],
        weight_writes=float(w_elems),
        accum_reads=a["accum_reads"],
        accum_writes=a["accum_writes"] + m * n,              # final writeback
    )


def primarycaps_profile(dims: CapsNetDims = MNIST_DIMS) -> OperationProfile:
    # Dense conv over the conv1 grid; strided selection on write-back.
    m = dims.pc_out * dims.pc_out                             # kept positions
    k = dims.pc_k * dims.pc_k * dims.pc_cin
    n = dims.pc_cout
    a = _matmul_accesses(m, k, n)
    in_elems = dims.conv1_out * dims.conv1_out * dims.pc_cin
    w_elems = k * n                                           # streamed
    return OperationProfile(
        name="PrimaryCaps",
        macs=a["macs"],
        cycles=a["cycles"],
        data_mem=in_elems * ACT_BYTES,                        # full input fmap
        weight_mem=2 * ARRAY_DIM * ARRAY_DIM * ACT_BYTES,     # streaming tile
        accum_mem=dims.conv1_out * dims.conv1_out * n * ACC_BYTES,
        data_reads=a["data_reads"],
        data_writes=float(in_elems),
        weight_reads=a["weight_reads"],
        weight_writes=float(w_elems),
        accum_reads=a["accum_reads"],
        accum_writes=a["accum_writes"] + m * n,
        # PrimaryCaps peak: full input residency + dense accumulation makes
        # this the largest-footprint operation (paper Fig. 4a).
    )


def classcaps_fc_profile(dims: CapsNetDims = MNIST_DIMS) -> OperationProfile:
    # Votes u_hat[i, j, d] = sum_c W[i, j, d, c] * u[i, c]
    m = dims.num_primary                     # input capsules
    k = dims.primary_dim
    n = dims.num_classes * dims.class_dim    # outputs per capsule
    a = _matmul_accesses(m, k, n)
    u_elems = m * k
    w_elems = m * k * n            # weights unique per (i, j): no reuse
    votes = m * n
    stream_group = 16              # i-capsules prefetched per group
    return OperationProfile(
        name="ClassCaps-FC",
        macs=a["macs"],
        cycles=a["cycles"],
        data_mem=u_elems * ACT_BYTES,
        weight_mem=2 * stream_group * k * n * ACT_BYTES,      # prefetch buffer
        accum_mem=votes * ACT_BYTES + ARRAY_DIM * n * ACC_BYTES,
        data_reads=a["data_reads"],
        data_writes=float(u_elems),
        weight_reads=float(w_elems),
        weight_writes=float(w_elems),                          # streamed in
        accum_reads=a["accum_reads"],
        accum_writes=a["accum_writes"] + votes,
    )


def _routing_state_mem(dims: CapsNetDims) -> tuple[float, float]:
    """(accumulator-resident routing state, coupling-coefficient bytes)."""
    votes = dims.num_primary * dims.num_classes * dims.class_dim * ACT_BYTES
    logits = dims.num_primary * dims.num_classes * ACC_BYTES
    s = dims.num_classes * dims.class_dim * ACC_BYTES
    return votes + logits + s, dims.num_primary * dims.num_classes * ACT_BYTES


def sum_squash_profile(dims: CapsNetDims = MNIST_DIMS) -> OperationProfile:
    # s_j = sum_i c_ij * u_hat_ij ; v_j = squash(s_j); executed per iteration.
    votes = dims.num_primary * dims.num_classes * dims.class_dim
    macs = float(votes)                       # one MAC per vote element
    m, k = dims.num_classes * dims.class_dim, dims.num_primary
    cycles = float(_tiles(m) * k)             # reduction over i, 16 cols wide
    acc_state, c_bytes = _routing_state_mem(dims)
    v_elems = dims.num_classes * dims.class_dim
    return OperationProfile(
        name="Sum+Squash",
        macs=macs,
        cycles=cycles + v_elems * 4,          # squash pipeline tail
        data_mem=v_elems * ACT_BYTES * 4,     # v + squash temporaries
        weight_mem=c_bytes,                   # c_ij act as weights
        accum_mem=acc_state,
        data_reads=float(v_elems * 2),
        data_writes=float(v_elems),
        weight_reads=float(dims.num_primary * dims.num_classes),
        weight_writes=0.0,
        accum_reads=float(votes),             # u_hat streamed from accum mem
        accum_writes=float(m * _tiles(k)),
        repeats=dims.routing_iters,
    )


def update_sum_profile(dims: CapsNetDims = MNIST_DIMS) -> OperationProfile:
    # b_ij += u_hat_ij . v_j ; c = softmax_j(b): executed per iteration.
    votes = dims.num_primary * dims.num_classes * dims.class_dim
    macs = float(votes)
    m, k = dims.num_primary * dims.num_classes, dims.class_dim
    cycles = float(_tiles(m) * k)
    acc_state, c_bytes = _routing_state_mem(dims)
    v_elems = dims.num_classes * dims.class_dim
    bij = dims.num_primary * dims.num_classes
    return OperationProfile(
        name="Update+Sum",
        macs=macs,
        cycles=cycles + bij / ARRAY_DIM,      # softmax pass
        data_mem=v_elems * ACT_BYTES * 4,
        weight_mem=c_bytes + v_elems * ACT_BYTES,
        accum_mem=acc_state,
        data_reads=float(v_elems),
        data_writes=0.0,
        weight_reads=float(v_elems + bij),    # v + c refresh
        weight_writes=float(bij),             # softmax result -> c
        accum_reads=float(votes + bij),
        accum_writes=float(bij),
        repeats=dims.routing_iters,
    )


def _linebuf_convs(c1: OperationProfile, pc: OperationProfile,
                   dims: CapsNetDims) -> tuple[OperationProfile,
                                               OperationProfile]:
    """'linebuf' conv variants: kernel-height line buffer of the input
    plus a 3-row accumulator strip instead of full-fmap residency."""
    c1 = dataclasses.replace(
        c1, accum_mem=3 * dims.conv1_out * dims.conv1_cout * ACC_BYTES)
    pc = dataclasses.replace(
        pc,
        data_mem=dims.pc_k * dims.conv1_out * dims.pc_cin * ACT_BYTES,
        accum_mem=3 * dims.pc_out * dims.pc_cout * ACC_BYTES,
        # input streamed from off-chip once per 16-channel output group
        data_writes=pc.data_writes * max(dims.pc_cout // ARRAY_DIM, 1),
    )
    return c1, pc


def _linebuf_routing(cc: OperationProfile, ss: OperationProfile,
                     us: OperationProfile, ldims) -> tuple[OperationProfile,
                                                           OperationProfile,
                                                           OperationProfile]:
    """'linebuf' routing variants for ONE layer: the votes live in the
    DATA memory during routing (``ldims``: the layer's own shape)."""
    votes_b = (ldims.num_primary * ldims.num_classes * ldims.class_dim
               * ACT_BYTES)
    logits_b = ldims.num_primary * ldims.num_classes * ACC_BYTES
    # s/v accumulator state: 4 fp32 temporaries per class-capsule element
    # (2560 B for the default MNIST network).
    sv_b = 4 * ldims.num_classes * ldims.class_dim * ACC_BYTES
    cc = dataclasses.replace(
        cc, data_mem=cc.data_mem + votes_b,                    # votes in data
        accum_mem=ARRAY_DIM * ldims.num_classes * ldims.class_dim * ACC_BYTES)
    ss = dataclasses.replace(ss, data_mem=votes_b + ss.data_mem,
                             accum_mem=logits_b + sv_b)
    us = dataclasses.replace(us, data_mem=votes_b + us.data_mem,
                             accum_mem=logits_b + sv_b)
    return cc, ss, us


def _linebuf_variant(ops: list[OperationProfile],
                     dims: CapsNetDims) -> list[OperationProfile]:
    """Alternative dataflow ('linebuf') of the fixed five-op model.  The
    paper's Fig. 4 bar values are not recoverable from the text, so both
    dataflows are exposed and compared in ``benchmarks/bench_dataflow.py``:
    'resident' (default) satisfies all of the paper's qualitative claims;
    'linebuf' trades PrimaryCaps footprint for higher power-gating
    headroom (closer to the paper's published PG savings)."""
    c1, pc, cc, ss, us = ops
    c1, pc = _linebuf_convs(c1, pc, dims)
    cc, ss, us = _linebuf_routing(cc, ss, us, dims)
    return [c1, pc, cc, ss, us]


def capsnet_stack_profiles(dataflow: str = "resident",
                           dims: CapsNetDims = MNIST_DIMS,
                           layers=None) -> list[OperationProfile]:
    """Per-operation profiles for a CHAIN of routing-capsule layers.

    ``layers`` describes the routing stack as ``(suffix, in_caps, in_dim,
    num_caps, caps_dim, iters)`` tuples (``None``: the single ClassCaps
    layer of ``dims`` -- exactly ``capsnet_profiles``).  Each layer
    contributes the three routing operations via ``RoutingLayerDims``
    with ``suffix`` appended to the names (repeated layers must not
    collide on a profile/phase name), so a deep stack is
    ``[Conv1, PrimaryCaps, FC[0], SS[0], US[0], ..., FC, SS, US]``.

    Off-chip accesses generalize paper Eq. (1)/(2): the DRAM-LOADING ops
    (the convs and each layer's FC, which stream weights/activations in)
    get reads = their on-chip fills; a loader's produced feature map is
    spilled (writes) when its consumer is the NEXT loader -- Conv1 ->
    PrimaryCaps -> FC[0] -> ... -> FC -- while the final FC's output and
    every routing phase stay on-chip.  DRAM-stall cycles apply uniformly.
    """
    from repro.core.energy import DRAM_BYTES_PER_CYCLE

    if layers is None:
        layers = (("", dims.num_primary, dims.primary_dim,
                   dims.num_classes, dims.class_dim, dims.routing_iters),)
    ops = [conv1_profile(dims), primarycaps_profile(dims)]
    loaders = [0, 1]                     # indices of DRAM-loading ops
    for suffix, in_caps, in_dim, num_caps, caps_dim, iters in layers:
        ld = RoutingLayerDims(num_primary=in_caps, primary_dim=in_dim,
                              num_classes=num_caps, class_dim=caps_dim,
                              routing_iters=iters)
        cc, ss, us = (classcaps_fc_profile(ld), sum_squash_profile(ld),
                      update_sum_profile(ld))
        if dataflow == "linebuf":
            cc, ss, us = _linebuf_routing(cc, ss, us, ld)
        if suffix:
            cc, ss, us = (dataclasses.replace(p, name=p.name + suffix)
                          for p in (cc, ss, us))
        loaders.append(len(ops))
        ops.extend([cc, ss, us])
    if dataflow == "linebuf":
        ops[0], ops[1] = _linebuf_convs(ops[0], ops[1], dims)
    elif dataflow != "resident":
        raise ValueError(f"unknown dataflow {dataflow!r}")
    loader_pos = {idx: n for n, idx in enumerate(loaders)}
    out = []
    for i, op in enumerate(ops):
        if i in loader_pos:
            n = loader_pos[i]
            reads = op.weight_writes + op.data_writes          # Eq. (1)
            nxt = loaders[n + 1] if n + 1 < len(loaders) else None
            writes = ops[nxt].data_writes if nxt is not None else 0.0  # Eq. (2)
        else:
            reads = writes = 0.0                               # routing: on-chip
        # Operations stall when the DRAM interface cannot keep up with the
        # streamed weights (ClassCaps-FC is memory-bound: its 2.8 MiB of
        # reuse-free weights dominate its runtime).
        stream_cycles = (reads + writes) * ACT_BYTES / DRAM_BYTES_PER_CYCLE
        out.append(dataclasses.replace(
            op, offchip_reads=reads, offchip_writes=writes,
            cycles=max(op.cycles, stream_cycles / max(op.repeats, 1))))
    return out


def capsnet_profiles(dataflow: str = "resident",
                     dims: CapsNetDims = MNIST_DIMS) -> list[OperationProfile]:
    """The five operations of CapsuleNet inference, with off-chip traffic.

    Off-chip accesses follow paper Eq. (1)/(2): reads_i = on-chip fills
    (weight_writes + data_writes) of op i; writes_i = data fills of op i+1
    (the produced feature map is spilled and re-read).  The last two ops
    (routing) never touch off-chip memory.

    ``dataflow``: "resident" (default, full-fmap residency) or "linebuf"
    (see ``_linebuf_variant``).  ``dims`` selects the network shape
    (default: the paper's MNIST CapsuleNet).  The single-layer special
    case of ``capsnet_stack_profiles``.
    """
    return capsnet_stack_profiles(dataflow, dims)


# ---------------------------------------------------------------------------
# Aggregates used by the DSE and benchmarks
# ---------------------------------------------------------------------------

COMPONENTS = ("data", "weight", "accum")


def peak_total_mem(profiles: Sequence[OperationProfile]) -> float:
    return max(p.total_mem for p in profiles)


def peak_component_mem(profiles: Sequence[OperationProfile], comp: str) -> float:
    return max(p.component(comp) for p in profiles)


def min_component_mem(profiles: Sequence[OperationProfile], comp: str) -> float:
    return min(p.component(comp) for p in profiles)


def total_cycles(profiles: Sequence[OperationProfile]) -> float:
    return sum(p.total_cycles for p in profiles)


def total_macs(profiles: Sequence[OperationProfile]) -> float:
    return sum(p.macs * p.repeats for p in profiles)


def total_offchip_accesses(profiles: Sequence[OperationProfile]) -> float:
    return sum((p.offchip_reads + p.offchip_writes) * p.repeats for p in profiles)
