"""CapsuleNet (Sabour et al. 2017) in pure JAX.

The network the paper profiles: Conv1 (9x9, 1->256, ReLU) -> PrimaryCaps
(9x9 conv, 256->32 capsules x 8D, stride 2) -> ClassCaps (routing-by-
agreement to 10 capsules x 16D), plus the optional reconstruction decoder
and margin loss, so the end-to-end example can actually train.

Routing-by-agreement is the feedback loop the paper highlights (Fig. 2);
it is expressed with ``jax.lax.fori_loop`` so it lowers to a single compact
HLO loop, mirroring the on-chip-resident routing state of CapStore.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

# Plan-op / PMU-phase name of one fused votes+routing layer.  The FINAL
# (classification) layer keeps the bare name -- the historical fixed-3-op
# plan -- while every intermediate layer of a deep stack gets an index
# suffix ("ClassCaps-Routing[0]", ...) so repeated layers never collide
# on a phase name.  ``execplan.FUSED_NAME`` aliases this constant.
ROUTING_NAME = "ClassCaps-Routing"


@dataclasses.dataclass(frozen=True)
class CapsLayerSpec:
    """One PLAIN routing-capsule layer of a deep stack: votes + routing
    from however many capsules flow in to ``num_caps`` capsules of
    ``caps_dim`` dimensions."""

    num_caps: int
    caps_dim: int
    routing_iters: int = 3


@dataclasses.dataclass(frozen=True)
class ResCapsBlock:
    """One REVERSIBLE residual capsule block (MoCapsNet-style).

    The incoming capsule tensor ``[B, I, C]`` is split along the capsule
    axis into ``x1 [B, I1, C]`` / ``x2 [B, I2, C]`` (``I1 = I // 2``) and
    run through an additive coupling of two routing-capsule halves::

        y1 = x1 + F(x2)        # F: routing layer  I2 caps -> I1 x C
        y2 = x2 + G(y1)        # G: routing layer  I1 caps -> I2 x C

    Shape-preserving AND invertible: ``x2 = y2 - G(y1)``, ``x1 = y1 -
    F(x2)``, so the backward pass recomputes each block's input from its
    output instead of saving activations -- activation memory stays flat
    in depth no matter how many blocks are stacked.
    """

    routing_iters: int = 3


@dataclasses.dataclass(frozen=True)
class RoutingLayer:
    """One RESOLVED votes+routing instance of the layer graph.

    ``CapsNetConfig.routing_stack()`` flattens the ``caps_layers`` entries
    (a ``ResCapsBlock`` contributes its two coupling halves) plus the
    implicit final ClassCaps layer into a chain of these; the plan
    compiler, both forwards, ``init_params``, and the analysis profiles
    all walk the same chain.  ``name`` is the plan-op / PMU-phase name
    (unique per instance), ``param`` the ``params`` dict key.  ``half``
    marks residual coupling halves (``"f"`` / ``"g"``); consecutive
    residual blocks form one reversible segment in the backward pass.
    """

    name: str
    param: str
    in_caps: int
    in_dim: int
    num_caps: int
    caps_dim: int
    iters: int
    block: int | None = None     # caps_layers entry index (residual only)
    half: str | None = None      # "f" | "g" coupling half

    @property
    def jd(self) -> int:
        return self.num_caps * self.caps_dim

    @property
    def residual(self) -> bool:
        return self.half is not None


@dataclasses.dataclass(frozen=True)
class CapsNetConfig:
    image_hw: int = 28
    in_channels: int = 1
    conv1_channels: int = 256
    conv1_kernel: int = 9
    pc_kernel: int = 9
    pc_stride: int = 2
    num_primary_groups: int = 32     # capsule groups (channels / primary_dim)
    primary_dim: int = 8
    num_classes: int = 10
    class_dim: int = 16
    routing_iters: int = 3
    decoder_hidden: tuple[int, int] = (512, 1024)
    use_decoder: bool = True
    # Intermediate routing layers between PrimaryCaps and the final
    # ClassCaps layer: a chain of ``CapsLayerSpec`` / ``ResCapsBlock``
    # entries.  Empty (the default) is the paper's fixed 3-op topology --
    # plans, params, and outputs are bit-identical to the pre-graph code.
    caps_layers: tuple = ()

    @property
    def conv1_out(self) -> int:
        return self.image_hw - self.conv1_kernel + 1

    @property
    def pc_out(self) -> int:
        return (self.conv1_out - self.pc_kernel) // self.pc_stride + 1

    @property
    def num_primary(self) -> int:
        return self.pc_out * self.pc_out * self.num_primary_groups

    @property
    def pc_channels(self) -> int:
        return self.num_primary_groups * self.primary_dim

    def routing_stack(self) -> tuple[RoutingLayer, ...]:
        """Flatten ``caps_layers`` + the final ClassCaps layer into the
        resolved routing-layer chain (see ``RoutingLayer``)."""
        layers: list[RoutingLayer] = []
        i, c = self.num_primary, self.primary_dim
        idx = 0
        for k, entry in enumerate(self.caps_layers):
            if isinstance(entry, ResCapsBlock):
                if i < 2:
                    raise ValueError(
                        f"caps_layers[{k}]: ResCapsBlock needs >= 2 incoming "
                        f"capsules to split the coupling halves, got {i}")
                i1, i2 = i // 2, i - i // 2
                layers.append(RoutingLayer(
                    name=f"{ROUTING_NAME}[{idx}]", param=f"cc{idx}_w",
                    in_caps=i2, in_dim=c, num_caps=i1, caps_dim=c,
                    iters=entry.routing_iters, block=k, half="f"))
                idx += 1
                layers.append(RoutingLayer(
                    name=f"{ROUTING_NAME}[{idx}]", param=f"cc{idx}_w",
                    in_caps=i1, in_dim=c, num_caps=i2, caps_dim=c,
                    iters=entry.routing_iters, block=k, half="g"))
                idx += 1
            elif isinstance(entry, CapsLayerSpec):
                if entry.num_caps < 1 or entry.caps_dim < 1:
                    raise ValueError(
                        f"caps_layers[{k}]: num_caps/caps_dim must be >= 1, "
                        f"got {entry.num_caps}x{entry.caps_dim}")
                layers.append(RoutingLayer(
                    name=f"{ROUTING_NAME}[{idx}]", param=f"cc{idx}_w",
                    in_caps=i, in_dim=c, num_caps=entry.num_caps,
                    caps_dim=entry.caps_dim, iters=entry.routing_iters))
                idx += 1
                i, c = entry.num_caps, entry.caps_dim
            else:
                raise TypeError(
                    f"caps_layers[{k}]: expected CapsLayerSpec or "
                    f"ResCapsBlock, got {type(entry).__name__}")
        layers.append(RoutingLayer(
            name=ROUTING_NAME, param="cc_w", in_caps=i, in_dim=c,
            num_caps=self.num_classes, caps_dim=self.class_dim,
            iters=self.routing_iters))
        return tuple(layers)


Params = dict[str, Any]


def init_params(key: jax.Array, cfg: CapsNetConfig = CapsNetConfig(),
                dtype=jnp.float32) -> Params:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    he = jax.nn.initializers.he_normal()
    stack = cfg.routing_stack()
    final = stack[-1]
    params: Params = {
        "conv1_w": he(k1, (cfg.conv1_kernel, cfg.conv1_kernel,
                           cfg.in_channels, cfg.conv1_channels), dtype),
        "conv1_b": jnp.zeros((cfg.conv1_channels,), dtype),
        "pc_w": he(k2, (cfg.pc_kernel, cfg.pc_kernel,
                        cfg.conv1_channels, cfg.pc_channels), dtype),
        "pc_b": jnp.zeros((cfg.pc_channels,), dtype),
        # W[i, j, class_dim, in_dim]: the final layer consumes whatever
        # the stack flows into it (= num_primary x primary_dim when
        # caps_layers is empty -- same shape, same key, same bits).
        "cc_w": 0.1 * jax.random.normal(
            k3, (final.in_caps, final.num_caps, final.caps_dim,
                 final.in_dim), dtype),
    }
    # Intermediate routing layers of a deep stack.  Keys derive from k3
    # via fold_in so the base 6-way split (and every existing param) stays
    # bit-identical when caps_layers is empty.
    for lay in stack[:-1]:
        params[lay.param] = 0.1 * jax.random.normal(
            jax.random.fold_in(k3, 1 + int(lay.param[2:-2])),
            (lay.in_caps, lay.num_caps, lay.caps_dim, lay.in_dim), dtype)
    if cfg.use_decoder:
        d_in = cfg.num_classes * cfg.class_dim
        h1, h2 = cfg.decoder_hidden
        d_out = cfg.image_hw * cfg.image_hw * cfg.in_channels
        params["dec_w1"] = he(k4, (d_in, h1), dtype)
        params["dec_b1"] = jnp.zeros((h1,), dtype)
        params["dec_w2"] = he(k5, (h1, h2), dtype)
        params["dec_b2"] = jnp.zeros((h2,), dtype)
        params["dec_w3"] = he(k6, (h2, d_out), dtype)
        params["dec_b3"] = jnp.zeros((d_out,), dtype)
    return params


def squash(s: jax.Array, axis: int = -1, eps: float = 1e-7) -> jax.Array:
    """v = ||s||^2 / (1 + ||s||^2) * s / ||s|| (paper Sec. 2.1)."""
    sq = jnp.sum(jnp.square(s), axis=axis, keepdims=True)
    return (sq / (1.0 + sq)) * s * jax.lax.rsqrt(sq + eps)


def compute_votes(u: jax.Array, cc_w: jax.Array) -> jax.Array:
    """u_hat[b, i, j, d] = W[i, j, d, c] u[b, i, c]  (the CC-FC operation)."""
    return jnp.einsum("bic,ijdc->bijd", u, cc_w)


def routing_by_agreement(u_hat: jax.Array, iters: int) -> jax.Array:
    """Dynamic routing (paper Fig. 2 feedback loop).  u_hat: [B, I, J, D]."""
    b0 = jnp.zeros(u_hat.shape[:3], u_hat.dtype)          # logits b[b, i, j]
    u_hat_ng = jax.lax.stop_gradient(u_hat)

    def body(it, b):
        c = jax.nn.softmax(b, axis=2)                     # over classes j
        # Sum+Squash: s[b, j, d] = sum_i c * u_hat
        uh = jnp.where(it < iters - 1, 0.0, 1.0)          # scalar gate
        u_used = u_hat_ng + uh * (u_hat - u_hat_ng)       # grads last iter only
        s = jnp.einsum("bij,bijd->bjd", c, u_used)
        v = squash(s)
        # Update+Sum: b += <u_hat, v>
        return b + jnp.einsum("bijd,bjd->bij", u_hat_ng, v)

    b = jax.lax.fori_loop(0, iters, body, b0)
    c = jax.nn.softmax(b, axis=2)
    return squash(jnp.einsum("bij,bijd->bjd", c, u_hat))  # v[b, j, d]


def routing_stack_ref(params: Params, u: jax.Array,
                      cfg: CapsNetConfig) -> jax.Array:
    """Reference (jnp) walk of the routing-layer graph: squashed primary
    capsules ``u [B, I, C]`` -> class capsules ``[B, J, D]``.

    Residual blocks apply the additive coupling ``y1 = x1 + F(x2)``,
    ``y2 = x2 + G(y1)`` (see ``ResCapsBlock``); plain layers replace the
    capsule tensor.  The default (empty-stack) config reduces to exactly
    ``routing_by_agreement(compute_votes(u, cc_w), iters)``.
    """
    stack = cfg.routing_stack()
    h, k = u, 0
    while k < len(stack):
        lay = stack[k]
        if lay.half == "f":
            g_lay = stack[k + 1]
            x1, x2 = h[:, :lay.num_caps], h[:, lay.num_caps:]
            y1 = x1 + routing_by_agreement(
                compute_votes(x2, params[lay.param]), lay.iters)
            y2 = x2 + routing_by_agreement(
                compute_votes(y1, params[g_lay.param]), g_lay.iters)
            h, k = jnp.concatenate([y1, y2], axis=1), k + 2
        else:
            h = routing_by_agreement(
                compute_votes(h, params[lay.param]), lay.iters)
            k += 1
    return h


def decode(params: Params, v: jax.Array,
           cfg: CapsNetConfig = CapsNetConfig(), *,
           labels: jax.Array | None = None,
           lengths: jax.Array | None = None) -> jax.Array:
    """Reconstruction decoder over the masked class capsules.

    Sabour et al. mask with the TRUE label during training (so the recon
    loss gradient flows through the labeled capsule) and with the predicted
    class at inference: pass ``labels`` when training, omit for argmax.
    """
    if labels is None:
        if lengths is None:
            lengths = jnp.linalg.norm(v, axis=-1)
        labels = jnp.argmax(lengths, -1)
    mask = jax.nn.one_hot(labels, cfg.num_classes, dtype=v.dtype)
    masked = (v * mask[..., None]).reshape(v.shape[0], -1)
    h = jax.nn.relu(masked @ params["dec_w1"] + params["dec_b1"])
    h = jax.nn.relu(h @ params["dec_w2"] + params["dec_b2"])
    return jax.nn.sigmoid(h @ params["dec_w3"] + params["dec_b3"])


def forward(params: Params, images: jax.Array,
            cfg: CapsNetConfig = CapsNetConfig(), *,
            labels: jax.Array | None = None,
            backend: str = "jnp", plan=None,
            interpret: bool = True) -> dict[str, jax.Array]:
    """images: [B, H, W, C] in [0, 1] -> class capsules + reconstruction.

    ``backend="jnp"`` (default) is the pure-JAX reference.
    ``backend="pallas"`` runs the WHOLE network through the Pallas kernels
    with block shapes and the resident/streamed routing schedule chosen
    by an ``ExecutionPlan`` (compiled on the fly from ``cfg`` unless
    ``plan`` is passed).  A pipelined plan (``compile_plan(...,
    pipeline=True)``, the on-the-fly default) runs Conv1 -> ONE
    ``primary_routing`` megakernel (PrimaryCaps conv + squash + votes +
    routing, the inter-layer activation u resident in VMEM); a per-op
    plan runs the three-call path (conv_im2col PrimaryCaps with fused
    squash -> fused votes_routing megakernel) -- the pipelined plan's
    fallback and parity oracle.  ``interpret=True`` validates on CPU,
    pass False on real TPU.

    ``labels`` masks the reconstruction decoder with the true class
    (training semantics); when omitted the decoder masks with argmax.
    """
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")
    b = images.shape[0]
    if backend == "pallas":
        from repro.core import execplan as _execplan
        from repro.kernels import ops as _kops
        if plan is None:
            plan = _execplan.compile_plan(cfg, batch=b, pipeline=True)
        x = _kops.conv2d(images, params["conv1_w"], params["conv1_b"],
                         stride=1, plan_op=plan.op("Conv1"),
                         epilogue="relu", interpret=interpret)
        stack = cfg.routing_stack()

        def w_of(lay):
            return params[lay.param].reshape(lay.in_caps, lay.jd, lay.in_dim)

        pipelined = any(op.kernel == "primary_routing" for op in plan.ops)
        if pipelined:
            # ONE pipelined megakernel: PrimaryCaps conv + squash + votes
            # + routing of the FIRST routing layer, with the inter-layer u
            # in VMEM scratch (neither u nor u_hat ever round-trips
            # through HBM).
            first = stack[0]
            h = _kops.primary_routing(
                x, params["pc_w"], params["pc_b"], w_of(first), plan=plan,
                iters=first.iters, num_classes=first.num_caps,
                routing_op_name=first.name,
                interpret=interpret).reshape(b, first.num_caps,
                                             first.caps_dim)
            k = 1
        else:
            pc = plan.op("PrimaryCaps")
            x = _kops.conv2d(x, params["pc_w"], params["pc_b"],
                             stride=cfg.pc_stride, plan_op=pc,
                             squash_dim=cfg.primary_dim, interpret=interpret)
            u = x.reshape(b, cfg.num_primary, cfg.primary_dim)
            if not pc.fuses_squash:
                u = _kops.squash(u, plan=plan, interpret=interpret)
            h, k = u, 0
        # Walk the remaining routing-layer graph: one fused votes+routing
        # megakernel per plain layer (u_hat never round-trips through
        # HBM), and one REVERSIBLE segment call per maximal run of
        # residual blocks (backward reconstructs each block's input from
        # its output -- no activations saved; see res_caps_segment).
        while k < len(stack):
            lay = stack[k]
            if lay.half == "f":
                pairs = []
                while k < len(stack) and stack[k].half == "f":
                    pairs.append((stack[k], stack[k + 1]))
                    k += 2
                ws = tuple(w_of(lyr) for pair in pairs for lyr in pair)
                h = _kops.res_caps_segment(h, ws, tuple(pairs), plan=plan,
                                           interpret=interpret)
            else:
                h = _kops.votes_routing(
                    h, w_of(lay), plan=plan, op_name=lay.name,
                    iters=lay.iters, num_classes=lay.num_caps,
                    interpret=interpret).reshape(b, lay.num_caps,
                                                 lay.caps_dim)
                k += 1
        v = h
    else:
        x = jax.lax.conv_general_dilated(
            images, params["conv1_w"], window_strides=(1, 1), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + params["conv1_b"])
        x = jax.lax.conv_general_dilated(
            x, params["pc_w"], window_strides=(cfg.pc_stride, cfg.pc_stride),
            padding="VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = x + params["pc_b"]
        u = squash(x.reshape(b, cfg.num_primary, cfg.primary_dim))
        v = routing_stack_ref(params, u, cfg)              # [B, J, D]
    lengths = jnp.linalg.norm(v, axis=-1)                  # class scores
    out = {"class_caps": v, "lengths": lengths}
    if cfg.use_decoder and "dec_w1" in params:
        out["reconstruction"] = decode(params, v, cfg, labels=labels,
                                       lengths=lengths)
    return out


def margin_loss(lengths: jax.Array, labels: jax.Array,
                m_pos: float = 0.9, m_neg: float = 0.1,
                lam: float = 0.5) -> jax.Array:
    """L_k = T_k max(0, m+ - ||v||)^2 + lam (1-T_k) max(0, ||v|| - m-)^2."""
    t = jax.nn.one_hot(labels, lengths.shape[-1], dtype=lengths.dtype)
    pos = jnp.square(jnp.maximum(0.0, m_pos - lengths))
    neg = jnp.square(jnp.maximum(0.0, lengths - m_neg))
    return jnp.mean(jnp.sum(t * pos + lam * (1.0 - t) * neg, axis=-1))


def total_loss(params: Params, images: jax.Array, labels: jax.Array,
               cfg: CapsNetConfig = CapsNetConfig(),
               recon_weight: float = 0.0005, *, backend: str = "jnp",
               plan=None, interpret: bool = True) -> tuple[jax.Array, dict]:
    """Margin loss + masked reconstruction, differentiable on BOTH backends.

    The decoder reconstructs the LABELED capsule (training semantics), so
    the reconstruction term backpropagates only through that capsule's
    pose -- on the Pallas path the gradient flows through the kernels'
    custom VJPs (compile the plan with ``train=True`` to pin the backward
    schedule; otherwise the memoized backward plan decision applies).
    """
    out = forward(params, images, cfg, labels=labels, backend=backend,
                  plan=plan, interpret=interpret)
    loss = margin_loss(out["lengths"], labels)
    metrics = {"margin_loss": loss}
    if "reconstruction" in out:
        flat = images.reshape(images.shape[0], -1)
        rec = jnp.mean(jnp.sum(jnp.square(out["reconstruction"] - flat), -1))
        loss = loss + recon_weight * rec
        metrics["recon_loss"] = rec
    metrics["accuracy"] = jnp.mean(
        (jnp.argmax(out["lengths"], -1) == labels).astype(jnp.float32))
    metrics["loss"] = loss
    return loss, metrics


@functools.partial(jax.jit, static_argnames=("cfg", "lr", "backend",
                                             "interpret"))
def train_step(params: Params, images: jax.Array, labels: jax.Array,
               cfg: CapsNetConfig = CapsNetConfig(),
               lr: float = 1e-3, *, backend: str = "jnp",
               interpret: bool = True) -> tuple[Params, dict]:
    (_, metrics), grads = jax.value_and_grad(total_loss, has_aux=True)(
        params, images, labels, cfg, backend=backend, interpret=interpret)
    params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return params, metrics
