"""CapStore design space exploration: SMP / SEP / HY on-chip organizations,
with and without power gating, plus the all-on-chip [11] and hierarchy
baselines (paper Secs. 3.2, 4.2, 5; Tables 1/2; Figs. 5, 10, 11).

Sizing rules (paper Sec. 4.2, "Application-Aware Design Space Exploration"):

  * banks          = 16            (matches the 16x16 systolic array)
  * SMP capacity   = worst-case per-operation TOTAL requirement (Fig. 4a)
  * SEP capacities = worst-case per-COMPONENT requirement (Fig. 4c)
  * HY separated   = per-component MINIMUM across operations;
    HY shared      = worst-case total minus the sum of the separated sizes
  * sector count   = chosen by the DSE (the paper picks 64/128); the PG
    granularity must resolve the utilization deltas of Fig. 4a/4c.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

from repro.core import analysis
from repro.core.analysis import COMPONENTS, OperationProfile
from repro.core import energy as E
from repro.core.energy import SRAMConfig
from repro.core.pmu import PhaseRequirement, PMUSchedule, build_schedule

BANKS = 16
ALL_ONCHIP_BYTES = 8 * 1024 * 1024      # CapsAcc [11]: 8 MB fully on-chip


# ---------------------------------------------------------------------------
# Organization definitions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MemoryOrg:
    """A full on-chip organization: named SRAMs + component->SRAM routing.

    ``routing`` maps each access component ("data"/"weight"/"accum") to a
    list of (sram_name, fraction) pairs; fractions may depend on the op via
    the HY overflow rule, so they are resolved per-op in ``evaluate``.
    """

    name: str
    srams: tuple[SRAMConfig, ...]
    power_gated: bool

    def sram(self, name: str) -> SRAMConfig:
        for s in self.srams:
            if s.name == name:
                return s
        raise KeyError(name)

    @property
    def total_bytes(self) -> float:
        return sum(s.capacity_bytes for s in self.srams)

    @property
    def area_mm2(self) -> float:
        return sum(s.area_mm2() for s in self.srams)


def _mk(name: str, cap: float, ports: int, pg: bool, sectors: int) -> SRAMConfig:
    return SRAMConfig(name=name, capacity_bytes=int(cap), ports=ports,
                      banks=BANKS, sectors_per_bank=sectors if pg else 1,
                      power_gated=pg)


def design_organizations(profiles: Sequence[OperationProfile],
                         sectors: int = 128) -> dict[str, MemoryOrg]:
    """Build the six CapStore organizations of Table 1 (+ derived sizes)."""
    peak_total = analysis.peak_total_mem(profiles)
    comp_max = {c: analysis.peak_component_mem(profiles, c) for c in COMPONENTS}
    comp_min = {c: analysis.min_component_mem(profiles, c) for c in COMPONENTS}
    hy_shared = max(peak_total - sum(comp_min.values()), 0.0)

    orgs: dict[str, MemoryOrg] = {}
    for pg in (False, True):
        tag = "PG-" if pg else ""
        orgs[f"{tag}SMP"] = MemoryOrg(
            name=f"{tag}SMP", power_gated=pg,
            srams=(_mk("shared", peak_total, ports=3, pg=pg, sectors=sectors),),
        )
        orgs[f"{tag}SEP"] = MemoryOrg(
            name=f"{tag}SEP", power_gated=pg,
            srams=tuple(_mk(c, comp_max[c], ports=1, pg=pg, sectors=sectors)
                        for c in COMPONENTS),
        )
        orgs[f"{tag}HY"] = MemoryOrg(
            name=f"{tag}HY", power_gated=pg,
            srams=(_mk("shared", hy_shared, ports=3, pg=pg, sectors=sectors),)
            + tuple(_mk(c, comp_min[c], ports=1, pg=False, sectors=1)
                    for c in COMPONENTS),
        )
    return orgs


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SramEnergy:
    name: str
    dynamic_mj: float
    static_mj: float
    wakeup_mj: float
    area_mm2: float

    @property
    def total_mj(self) -> float:
        return self.dynamic_mj + self.static_mj + self.wakeup_mj


@dataclasses.dataclass(frozen=True)
class OrgEvaluation:
    org: MemoryOrg
    per_sram: tuple[SramEnergy, ...]
    per_op_mj: dict[str, float]
    schedules: tuple[PMUSchedule, ...]

    @property
    def dynamic_mj(self) -> float:
        return sum(s.dynamic_mj for s in self.per_sram)

    @property
    def static_mj(self) -> float:
        return sum(s.static_mj for s in self.per_sram)

    @property
    def wakeup_mj(self) -> float:
        return sum(s.wakeup_mj for s in self.per_sram)

    @property
    def total_mj(self) -> float:
        return self.dynamic_mj + self.static_mj + self.wakeup_mj

    @property
    def area_mm2(self) -> float:
        return self.org.area_mm2

    @property
    def wakeup_latency_cycles(self) -> float:
        return sum(s.wakeup_latency_cycles for s in self.schedules)


def _component_routing(org: MemoryOrg, op: OperationProfile,
                       comp: str) -> list[tuple[str, float]]:
    """Where do `comp` accesses of `op` go?  [(sram_name, fraction), ...]"""
    kind = org.name.removeprefix("PG-")
    if kind == "SMP":
        return [("shared", 1.0)]
    if kind == "SEP":
        return [(comp, 1.0)]
    # HY: the separated memory absorbs up to its capacity; overflow goes to
    # the shared multi-port memory.
    sep_cap = org.sram(comp).capacity_bytes
    req = max(op.component(comp), 1e-9)
    frac_sep = min(sep_cap / req, 1.0)
    return [(comp, frac_sep), ("shared", 1.0 - frac_sep)]


def _phase_requirements(org: MemoryOrg, sram_name: str,
                        profiles: Sequence[OperationProfile],
                        phase_groups: Sequence[tuple[str, Sequence[str]]]
                        | None = None,
                        phase_durations: dict[str, float] | None = None
                        ) -> list[PhaseRequirement]:
    """Per-phase byte demand on one SRAM (drives the PMU schedule).

    ``phase_groups`` -- ``(phase_name, covered profile names)`` pairs from
    an ``ExecutionPlan`` -- merges the dataflow operations a fused kernel
    executes as ONE phase into one gating phase (peak demand over the
    members, summed duration), so the schedule scores what actually runs.
    ``phase_durations`` overrides a phase's duration with the plan's own
    cycle estimate (a STREAMED fused phase re-streams W ``iters + 1``
    times, so its leakage window is longer than the one-pass profile sum
    the members alone imply).  Without groups every profile is its own
    phase (the paper's model).
    """
    kind = org.name.removeprefix("PG-")
    per_op: dict[str, tuple[float, float]] = {}
    for op in profiles:
        if kind == "SMP":
            need = op.total_mem
        elif kind == "SEP":
            need = op.component(sram_name)
        else:  # HY
            if sram_name == "shared":
                need = sum(max(op.component(c) - org.sram(c).capacity_bytes, 0.0)
                           for c in COMPONENTS)
            else:
                need = min(op.component(sram_name),
                           org.sram(sram_name).capacity_bytes)
        per_op[op.name] = (need, op.total_cycles)
    if phase_groups is None:
        phase_groups = tuple((op.name, (op.name,)) for op in profiles)
    reqs = []
    for phase_name, members in phase_groups:
        duration = (phase_durations or {}).get(
            phase_name, sum(per_op[m][1] for m in members))
        reqs.append(PhaseRequirement(
            name=phase_name,
            required_bytes=max(per_op[m][0] for m in members),
            duration_cycles=duration))
    return reqs


def evaluate(org: MemoryOrg, profiles: Sequence[OperationProfile], *,
             phase_groups: Sequence[tuple[str, Sequence[str]]] | None = None,
             phase_durations: dict[str, float] | None = None
             ) -> OrgEvaluation:
    """Score ``org``: dynamic energy from the per-operation access counts,
    static/wakeup from the PMU gating schedule.  ``phase_groups`` (see
    ``_phase_requirements``) gates over fused executed phases instead of
    one phase per dataflow operation; ``phase_durations`` carries the
    plan's per-phase cycle estimates (pass-count-aware for streamed
    fused schedules)."""
    names = [op.name for op in profiles]
    if len(set(names)) != len(names):
        # Accesses and phase demands are keyed by profile name; a repeated
        # routing layer must carry its per-instance suffix ("...[k]") or
        # its instances would silently collapse into one phase here.
        dupes = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"duplicate operation profile names {dupes}: "
                         f"repeated layers need per-instance suffixes")
    dyn = {s.name: 0.0 for s in org.srams}
    per_op = {op.name: 0.0 for op in profiles}

    # Dynamic energy: route each component's accesses to its SRAM(s).
    for op in profiles:
        for comp in COMPONENTS:
            reads = {"data": op.data_reads, "weight": op.weight_reads,
                     "accum": op.accum_reads}[comp] * op.repeats
            writes = {"data": op.data_writes, "weight": op.weight_writes,
                      "accum": op.accum_writes}[comp] * op.repeats
            for sram_name, frac in _component_routing(org, op, comp):
                if frac <= 0.0:
                    continue
                s = org.sram(sram_name)
                e_pj = (reads * s.access_energy_pj(write=False)
                        + writes * s.access_energy_pj(write=True)) * frac
                dyn[sram_name] += e_pj * 1e-9
                per_op[op.name] += e_pj * 1e-9

    # Static + wakeup energy via the PMU schedule per SRAM.
    schedules = []
    per_sram = []
    for s in org.srams:
        sched = build_schedule(s, _phase_requirements(org, s.name, profiles,
                                                      phase_groups,
                                                      phase_durations))
        schedules.append(sched)
        per_sram.append(SramEnergy(
            name=s.name, dynamic_mj=dyn[s.name],
            static_mj=sched.static_mj, wakeup_mj=sched.wakeup_mj,
            area_mm2=s.area_mm2()))
        for ph in sched.phases:
            # fused phases carry the plan-op name, not a profile name
            per_op[ph.name] = (per_op.get(ph.name, 0.0)
                               + ph.leakage_mj + ph.wakeup_mj)

    return OrgEvaluation(org=org, per_sram=tuple(per_sram),
                         per_op_mj=per_op, schedules=tuple(schedules))


# ---------------------------------------------------------------------------
# Baselines (Fig. 5) and complete-accelerator accounting (Fig. 11)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SystemEnergy:
    """Complete-architecture energy breakdown (mJ)."""

    name: str
    accelerator_mj: float
    buffers_mj: float
    onchip_mj: float
    offchip_mj: float
    onchip_area_mm2: float

    @property
    def total_mj(self) -> float:
        return (self.accelerator_mj + self.buffers_mj + self.onchip_mj
                + self.offchip_mj)

    @property
    def memory_fraction(self) -> float:
        return (self.onchip_mj + self.offchip_mj) / self.total_mj

    @property
    def total_area_mm2(self) -> float:
        return self.onchip_area_mm2 + E.ACCEL_AREA_MM2 + E.BUFFER_AREA_MM2


def _common_terms(profiles: Sequence[OperationProfile]) -> tuple[float, float, float]:
    dur = E.cycles_to_s(analysis.total_cycles(profiles))
    macs = analysis.total_macs(profiles)
    accel = E.accelerator_dynamic_mj(macs) + E.accelerator_static_mj(dur)
    onchip_accesses = sum(
        (op.data_reads + op.data_writes + op.weight_reads + op.weight_writes
         + op.accum_reads + op.accum_writes) * op.repeats for op in profiles)
    buffers = E.buffer_energy_mj(onchip_accesses)
    return dur, accel, buffers


def all_onchip_system(profiles: Sequence[OperationProfile]) -> SystemEnergy:
    """Version (a): CapsAcc [11] with everything in one 8 MB on-chip SRAM."""
    dur, accel, buffers = _common_terms(profiles)
    # [11] uses one monolithic on-chip memory; the dedicated buffers of
    # Fig. 3 provide the multi-access paths, so the big SRAM is single-port.
    sram = SRAMConfig(name="all-onchip", capacity_bytes=ALL_ONCHIP_BYTES,
                      ports=1, banks=8)
    accesses = 0.0
    for op in profiles:
        accesses += (op.data_reads + op.data_writes + op.weight_reads
                     + op.weight_writes + op.accum_reads + op.accum_writes
                     ) * op.repeats
        # weights/fmaps that the hierarchy would spill now also hit the big
        # SRAM (they are the same values, kept resident).
        accesses += (op.offchip_reads + op.offchip_writes) * op.repeats
    onchip = (accesses * sram.access_energy_pj() * 1e-9
              + sram.leakage_mw() * dur)  # mW * s = mJ
    return SystemEnergy(name="all-onchip[11]", accelerator_mj=accel,
                        buffers_mj=buffers, onchip_mj=onchip, offchip_mj=0.0,
                        onchip_area_mm2=sram.area_mm2())


def hierarchy_system(profiles: Sequence[OperationProfile],
                     ev: OrgEvaluation) -> SystemEnergy:
    """Version (b)+: on-chip org `ev` + off-chip DRAM per Eqs. (1)/(2)."""
    dur, accel, buffers = _common_terms(profiles)
    off_accesses = analysis.total_offchip_accesses(profiles)
    off = E.dram_energy_pj(off_accesses) * 1e-9 + E.dram_static_mj(dur)
    return SystemEnergy(name=f"hierarchy/{ev.org.name}", accelerator_mj=accel,
                        buffers_mj=buffers, onchip_mj=ev.total_mj,
                        offchip_mj=off, onchip_area_mm2=ev.area_mm2)


# ---------------------------------------------------------------------------
# Full DSE (paper Sec. 4.2): sweep organizations x sector counts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DSEResult:
    org_name: str
    sectors: int
    total_mj: float
    area_mm2: float
    evaluation: OrgEvaluation


def explore(profiles: Sequence[OperationProfile] | None = None,
            sector_choices: Sequence[int] = (1, 2, 4, 8, 16, 32, 64, 128, 256),
            *, plan=None, train: bool = False) -> list[DSEResult]:
    """Evaluate every organization x sector count; sorted by energy.

    The profiles default to those of an ``ExecutionPlan`` compiled for the
    paper's CapsuleNet -- i.e. the PMU/energy schedule scored here is the
    SAME schedule the Pallas kernels execute, gated over the plan's FUSED
    phases (``plan.phase_groups()``: the votes+routing megakernel is one
    phase).  Pass ``plan=`` to score a differently-shaped network, or raw
    ``profiles`` for paper-model ablations (one phase per operation).

    ``train=True`` compiles the default plan with its backward OpPlans,
    so the organizations are sized for (and the PMU gates) a full
    training step: forward phases then backward phases in reverse
    network order, one per executed backward kernel.
    """
    phase_groups = None
    phase_durations = None
    if profiles is None:
        if plan is None:
            from repro.core import execplan
            from repro.core.capsnet import CapsNetConfig
            plan = execplan.compile_plan(CapsNetConfig(), train=train)
        profiles = plan.profiles
        phase_groups = plan.phase_groups()
        phase_durations = plan.phase_durations()
    elif plan is not None:
        raise ValueError("pass either profiles or plan, not both")
    profiles = list(profiles)
    results = []
    seen = set()
    for sectors, pg in itertools.product(sector_choices, (False, True)):
        if not pg and sectors != 1:
            continue  # sectors only matter with power gating
        orgs = design_organizations(profiles, sectors=sectors)
        for name, org in orgs.items():
            if org.power_gated != pg:
                continue
            key = (name, sectors if pg else 1)
            if key in seen:
                continue
            seen.add(key)
            ev = evaluate(org, profiles, phase_groups=phase_groups,
                          phase_durations=phase_durations)
            results.append(DSEResult(org_name=name, sectors=sectors if pg else 1,
                                     total_mj=ev.total_mj, area_mm2=ev.area_mm2,
                                     evaluation=ev))
    results.sort(key=lambda r: r.total_mj)
    return results


def best_design(profiles: Sequence[OperationProfile] | None = None,
                *, plan=None, train: bool = False) -> DSEResult:
    return explore(profiles, plan=plan, train=train)[0]


def evaluate_plan(org: MemoryOrg, plan) -> OrgEvaluation:
    """Score ``org`` against the schedule of an ``ExecutionPlan``: the
    dataflow access counts with the gating schedule built over the plan's
    fused executed phases (``plan.phase_groups()``) and the plan's
    pass-count-aware phase durations."""
    return evaluate(org, plan.profiles, phase_groups=plan.phase_groups(),
                    phase_durations=plan.phase_durations())
