"""Application-aware power management unit (PMU) model.

Implements the paper's Sec. 4.3: the PMU knows the CapsuleNet inference
schedule (which operation runs when, and how much of each on-chip memory it
needs -- Fig. 4a/4c) and drives one sleep transistor per sector index.  A
sleep transistor gates N sectors, one per bank (Fig. 6/8), so the gating
granularity of a memory is ``1 / sectors_per_bank`` of its capacity.

The model follows the paper's two-state scheme (ON at full swing, OFF at
zero voltage -- no retention states) with a 2-way handshake whose cost is a
wakeup energy + latency per ``OFF -> ON`` transition (Fig. 9).  Transitions
only happen at operation boundaries, which is why the paper (and this
model) finds the wakeup overhead negligible.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.energy import SRAMConfig, cycles_to_s


@dataclasses.dataclass(frozen=True)
class PhaseRequirement:
    """One operation's demand on one memory."""

    name: str
    required_bytes: float
    duration_cycles: float


@dataclasses.dataclass(frozen=True)
class PhaseState:
    name: str
    on_fraction: float          # sector-quantized fraction powered ON
    sectors_on: int
    sectors_woken: int          # OFF->ON transitions entering this phase
    duration_s: float
    leakage_mj: float
    wakeup_mj: float
    wakeup_latency_cycles: float


@dataclasses.dataclass(frozen=True)
class PMUSchedule:
    memory: SRAMConfig
    phases: tuple[PhaseState, ...]

    @property
    def static_mj(self) -> float:
        return sum(p.leakage_mj for p in self.phases)

    @property
    def wakeup_mj(self) -> float:
        return sum(p.wakeup_mj for p in self.phases)

    @property
    def wakeup_latency_cycles(self) -> float:
        return sum(p.wakeup_latency_cycles for p in self.phases)

    @property
    def total_transitions(self) -> int:
        return sum(p.sectors_woken for p in self.phases)


def schedule_from_plan(memory: SRAMConfig, plan) -> PMUSchedule:
    """PMU schedule for ``memory`` driven by an ``ExecutionPlan``.

    ``plan`` is any object with a ``phase_requirements()`` method (see
    ``repro.core.execplan.ExecutionPlan``); this is the path by which the
    gating model scores the SAME per-operation schedule the kernels
    execute, instead of a hand-built phase list.  The plan emits one
    phase per EXECUTED kernel, so a fused op (the votes+routing
    megakernel) is gated as the single phase it actually runs -- no
    spurious sector transitions at fused-away operation boundaries.  A
    training plan (``compile_plan(train=True)``) appends one phase per
    backward kernel in reverse network order, so the same schedule gates
    a full training step.
    """
    return build_schedule(memory, plan.phase_requirements())


def build_schedule(memory: SRAMConfig,
                   phases: Sequence[PhaseRequirement]) -> PMUSchedule:
    """Derive the sector ON/OFF schedule for one memory across the inference.

    All sectors start OFF (gated) for a power-gated memory; a non-gated
    memory is always fully ON.  The PMU wakes exactly the sectors an
    operation needs and gates the rest down at the boundary.
    """
    states: list[PhaseState] = []
    prev_on = 0
    total_sectors = memory.sectors_per_bank  # per-bank index granularity
    for ph in phases:
        if memory.capacity_bytes <= 0:
            wanted = 0.0
        else:
            wanted = min(ph.required_bytes / memory.capacity_bytes, 1.0)
        if memory.power_gated:
            frac = memory.quantize_on_fraction(wanted)
        else:
            frac = 1.0
        sectors_on = round(frac * total_sectors)
        woken = max(sectors_on - prev_on, 0)
        dur = cycles_to_s(ph.duration_cycles)
        leak_mw = memory.leakage_mw(on_fraction=frac)
        states.append(PhaseState(
            name=ph.name,
            on_fraction=frac,
            sectors_on=sectors_on,
            sectors_woken=woken if memory.power_gated else 0,
            duration_s=dur,
            leakage_mj=leak_mw * dur,  # mW * s = mJ
            wakeup_mj=memory.wakeup_energy_pj(woken) * 1e-9
            if memory.power_gated else 0.0,
            wakeup_latency_cycles=memory.wakeup_latency_cycles(woken)
            if memory.power_gated else 0.0,
        ))
        prev_on = sectors_on
    return PMUSchedule(memory=memory, phases=tuple(states))
