"""Shared fault-tolerant training-loop skeleton.

``train/loop.py`` (LM) and ``train/capsnet_loop.py`` (CapsNet) grew the
same production behaviours independently -- async atomic checkpoints,
resume-from-latest, NaN/divergence rollback to THIS run's last committed
step, JSON heartbeat, preemption save, straggler detection.  This module
is the one copy both loops subclass.

The skeleton is a template method (``run``); subclasses supply the
model-specific pieces as hooks:

  * ``_init_state()``        -> checkpoint-shaped state dict
  * ``_next_batch(step)``    -> batch for this step
  * ``_run_step(state, b)``  -> (new state dict, metrics with "loss")
  * ``_extra_record(m)``     -> extra per-step history fields
  * ``_log_line(rec)``       -> the periodic progress line
  * ``_ckpt_extra()``        -> manifest extras (model name, backend, ...)
  * ``_skip_batch(step)``    -> advance a stateful data stream past a
                               poisoned batch (stateless data: no-op)

State is ALWAYS the checkpoint dict (``{"params": ...}`` or
``{"params": ..., "opt": ...}``): restore, rollback and the preemption
save then need no per-loop packing logic.  ``_run_step`` must dispatch
through ``self._step_fn`` at CALL time, never capture it at construction
-- tests (and fault-injection harnesses) monkey-patch ``loop._step_fn``
after the loop is built.

The loop config is duck-typed: any dataclass with ``total_steps,
ckpt_every, ckpt_dir, keep, log_every, heartbeat_path, max_nan_skips``
(plus optional ``straggler_factor``) works.
"""

from __future__ import annotations

import json
import os
import pathlib
import signal
import time
from typing import Callable

import jax
import numpy as np

from repro.core import faults
from repro.train import checkpoint as ckpt


class FaultTolerantLoop:
    """Template-method base for checkpointed, NaN-guarded training."""

    def __init__(self, loop_cfg,
                 on_straggler: Callable[[int, float], None] | None = None):
        self.loop_cfg = loop_cfg
        self.on_straggler = on_straggler or (lambda step, t: None)
        self._stop = False
        self.step = 0
        self.nan_skips = 0               # lifetime count (reporting)
        self._nan_streak = 0             # CONSECUTIVE count (the bound)
        self._last_committed = 0         # latest step THIS run checkpointed
        self.history: list[dict] = []
        self._times: list[float] = []
        self.checkpointer = ckpt.AsyncCheckpointer(loop_cfg.ckpt_dir,
                                                   keep=loop_cfg.keep)

    # -- lifecycle ------------------------------------------------------------
    def request_stop(self, *_args) -> None:
        self._stop = True

    def install_signal_handler(self) -> None:       # pragma: no cover
        signal.signal(signal.SIGTERM, self.request_stop)

    # -- hooks (subclass responsibilities) ------------------------------------
    def _init_state(self) -> dict:
        raise NotImplementedError

    def _next_batch(self, step: int):
        raise NotImplementedError

    def _run_step(self, state: dict, batch) -> tuple[dict, dict]:
        raise NotImplementedError

    def _extra_record(self, metrics: dict) -> dict:
        return {}

    def _log_line(self, rec: dict) -> str:
        return (f"step {rec['step']:6d} loss {rec['loss']:9.4f} "
                f"{rec['time_s'] * 1e3:7.1f} ms")

    def _ckpt_extra(self) -> dict:
        return {}

    def _skip_batch(self, step: int) -> None:
        """Advance a stateful data stream to ``step`` (deterministic
        index-by-step data needs nothing here)."""

    def _shardings(self):
        """Shardings handed to ``ckpt.restore`` (elastic resume)."""
        return None

    def _begin(self, start: int) -> None:
        """Called once per ``run`` after restore, before the first step
        (LM loop: construct the data iterator at ``start``)."""

    # -- shared machinery -----------------------------------------------------
    def _try_restore(self, state: dict) -> tuple[dict, int]:
        latest = ckpt.latest_step(self.loop_cfg.ckpt_dir)
        if latest is None:
            return state, 0
        restored, manifest = ckpt.restore(state, self.loop_cfg.ckpt_dir,
                                          shardings=self._shardings())
        return restored, manifest["step"]

    def _restore_committed(self) -> dict:
        """Roll back to THIS run's last committed checkpoint (a shared
        ckpt_dir may hold later steps from an abandoned run --
        ``latest_step`` would silently resurrect them)."""
        restored, _ = ckpt.restore(self._init_state(),
                                   self.loop_cfg.ckpt_dir,
                                   step=self._last_committed,
                                   shardings=self._shardings())
        return restored

    def _heartbeat(self, step: int, metrics: dict) -> None:
        if self.loop_cfg.heartbeat_path is None:
            return
        hb = {"step": step, "time": time.time(),
              "loss": float(metrics.get("loss", np.nan))}
        p = pathlib.Path(self.loop_cfg.heartbeat_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        # NAME + ".tmp", not with_suffix(".tmp"): two heartbeat files
        # sharing a stem ("a.json"/"a.txt") must not race through one
        # "a.tmp"; os.replace is the atomic publish either way.
        tmp = p.with_name(p.name + ".tmp")
        tmp.write_text(json.dumps(hb))
        os.replace(tmp, p)

    def _note_time(self, dt: float) -> None:
        self._times.append(dt)
        factor = getattr(self.loop_cfg, "straggler_factor", None)
        if factor is None:
            return
        med = float(np.median(self._times[-21:]))
        if len(self._times) > 5 and dt > factor * med:
            self.on_straggler(self.step, dt)

    def _apply_step_faults(self, loss: float, dt: float) -> tuple[float,
                                                                  float]:
        """Chaos-test site (``train.step``, index = the step about to
        finish): ``nan_output``/``inf_output`` poison the loss so the
        rollback path runs for real; ``stall`` inflates the measured
        duration by ``seconds`` so straggler detection fires
        deterministically (no wall-clock sleep)."""
        for spec in faults.poll(faults.SITE_TRAIN_STEP, index=self.step):
            if spec.kind == "nan_output":
                loss = float("nan")
            elif spec.kind == "inf_output":
                loss = float("inf")
            elif spec.kind == "stall":
                dt += spec.seconds
        return loss, dt

    def _save(self, state: dict, step: int) -> None:
        self.checkpointer.save_async(state, step, extra=self._ckpt_extra())
        self._last_committed = step

    # -- main -----------------------------------------------------------------
    def run(self, resume: bool = True) -> list[dict]:
        state = self._init_state()
        start = 0
        if resume:
            state, start = self._try_restore(state)
        if start == 0:
            ckpt.save(state, self.loop_cfg.ckpt_dir, 0,
                      extra=self._ckpt_extra())
        self._begin(start)
        self.step = start
        self._last_committed = start
        self._times = []

        while self.step < self.loop_cfg.total_steps and not self._stop:
            batch = self._next_batch(self.step)
            t0 = time.time()
            state, metrics = self._run_step(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.time() - t0
            if faults.enabled():
                loss, dt = self._apply_step_faults(loss, dt)

            if not np.isfinite(loss):
                self.nan_skips += 1
                self._nan_streak += 1
                # max_nan_skips bounds CONSECUTIVE divergence: a long
                # healthy run must survive any number of transient NaNs,
                # but a params tree that diverges every step after
                # rollback is dead and should say so.
                if self._nan_streak > self.loop_cfg.max_nan_skips:
                    raise RuntimeError(
                        f"diverged: {self._nan_streak} consecutive "
                        f"non-finite steps (> max_nan_skips="
                        f"{self.loop_cfg.max_nan_skips})")
                self.checkpointer.wait()
                state = self._restore_committed()
                self._skip_batch(self.step + 1)   # drop the poisoned batch
                self.step += 1
                continue

            self._nan_streak = 0          # finite step: divergence ended
            self._note_time(dt)
            self.step += 1
            rec = {"step": self.step, "loss": loss, "time_s": dt,
                   **self._extra_record(metrics)}
            self.history.append(rec)
            self._heartbeat(self.step, metrics)
            if self.step % self.loop_cfg.log_every == 0:
                print(self._log_line(rec), flush=True)
            if self.step % self.loop_cfg.ckpt_every == 0 \
                    or self.step == self.loop_cfg.total_steps:
                self._save(state, self.step)

        if self._stop:   # preemption: commit state before exiting
            self.checkpointer.wait()
            ckpt.save(state, self.loop_cfg.ckpt_dir, self.step,
                      extra={**self._ckpt_extra(), "preempted": True})
        self.checkpointer.wait()
        return self.history
