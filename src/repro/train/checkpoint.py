"""Checkpointing: atomic, async, resharding-on-restore.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json        # tree structure, shapes, dtypes, step, config
        arrays.npz           # flat leaf -> array (host-local full arrays)
    <dir>/step_000123.COMMIT # empty commit marker (atomicity)

Writes go to ``step_X.tmp`` then rename + commit-marker, so a preempted
writer never leaves a readable-but-corrupt checkpoint.  ``save_async``
snapshots to host memory synchronously (cheap) and writes on a background
thread so the train loop is not blocked.  ``restore`` rebuilds the pytree
and (re)shards it onto whatever mesh the new job has -- elastic restart
onto a different topology is a first-class path.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], list[str]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    keys = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
        keys.append(key)
    return out, keys


def save(tree: Any, directory: str | pathlib.Path, step: int,
         extra: dict | None = None) -> pathlib.Path:
    """Synchronous atomic save."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    arrays, keys = _flatten(tree)
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "keys": keys,
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    (directory / f"step_{step:08d}.COMMIT").touch()
    return final


class AsyncCheckpointer:
    """Snapshot-now, write-later; at most one write in flight."""

    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save_async(self, tree: Any, step: int,
                   extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot

        def _write():
            try:
                save(host_tree, self.directory, step, extra)
                self._gc()
            except Exception as e:                  # pragma: no cover
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(committed_steps(self.directory))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)
            (self.directory / f"step_{s:08d}.COMMIT").unlink(missing_ok=True)


def committed_steps(directory: str | pathlib.Path) -> list[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return []
    out = []
    for marker in directory.glob("step_*.COMMIT"):
        s = int(marker.stem.split("_")[1])
        if (directory / f"step_{s:08d}" / "manifest.json").exists():
            out.append(s)
    return sorted(out)


def latest_step(directory: str | pathlib.Path) -> int | None:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def restore(template: Any, directory: str | pathlib.Path,
            step: int | None = None, shardings: Any = None
            ) -> tuple[Any, dict]:
    """Restore into the structure of ``template`` (pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (optional pytree) reshards each leaf
    onto the current mesh -- the elastic-restart path."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    arrays = np.load(d / "arrays.npz")

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(flat))
    leaves = []
    for (path, leaf), shard in zip(flat, shard_flat):
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest
