from repro.train.checkpoint import AsyncCheckpointer, restore, save  # noqa: F401
from repro.train.data import DataConfig, DataIterator  # noqa: F401
from repro.train.loop import LoopConfig, TrainLoop  # noqa: F401
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state  # noqa: F401
