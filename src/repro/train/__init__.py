# NOTE: capsnet_loop is intentionally NOT re-exported here -- it is a
# `python -m repro.train.capsnet_loop` CLI, and importing it from the
# package __init__ would trigger runpy's double-import RuntimeWarning.
from repro.train.checkpoint import AsyncCheckpointer, restore, save  # noqa: F401
from repro.train.data import DataConfig, DataIterator  # noqa: F401
from repro.train.loop import LoopConfig, TrainLoop  # noqa: F401
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state  # noqa: F401
