"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step, host) -- which is what makes
checkpoint/restart and elastic rescaling exact: a restored run at step S
regenerates precisely the batches S, S+1, ... regardless of how many hosts
now exist (skip-ahead is O(1), no state to persist beyond the step).

The token stream has learnable structure (a noisy ngram-ish recurrence) so
training losses actually fall in the examples/tests.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    kind: str                  # "lm" | "frames" | "mnist"
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    frontend_dim: int = 512
    seed: int = 0


def _fold(seed: int, step: int, host: int) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    return jax.random.fold_in(jax.random.fold_in(key, step), host)


def lm_batch(cfg: DataConfig, step: int, host: int = 0,
             num_hosts: int = 1) -> dict:
    """Structured token stream: x[t+1] = (a*x[t] + b + noise) % V."""
    b = cfg.global_batch // num_hosts
    key = _fold(cfg.seed, step, host)
    k1, k2, k3 = jax.random.split(key, 3)
    x0 = jax.random.randint(k1, (b, 1), 0, cfg.vocab_size)
    mult = 31 + 2 * jax.random.randint(k2, (b, 1), 0, 8)
    noise = (jax.random.uniform(k3, (b, cfg.seq_len + 1)) < 0.05)
    steps_ = jnp.arange(cfg.seq_len + 1)[None, :]
    seq = (x0 + mult * steps_) % cfg.vocab_size
    seq = jnp.where(noise, (seq * 7 + 3) % cfg.vocab_size, seq)
    return {"inputs": seq[:, :-1].astype(jnp.int32),
            "targets": seq[:, 1:].astype(jnp.int32)}


def frames_batch(cfg: DataConfig, step: int, host: int = 0,
                 num_hosts: int = 1) -> dict:
    """Audio-frontend stub: frame embeddings + cluster targets."""
    b = cfg.global_batch // num_hosts
    key = _fold(cfg.seed, step, host)
    k1, k2 = jax.random.split(key)
    centers = jax.random.normal(jax.random.PRNGKey(cfg.seed + 1),
                                (cfg.vocab_size, cfg.frontend_dim))
    labels = jax.random.randint(k1, (b, cfg.seq_len), 0, cfg.vocab_size)
    frames = centers[labels] + 0.3 * jax.random.normal(
        k2, (b, cfg.seq_len, cfg.frontend_dim))
    return {"inputs": frames.astype(jnp.float32),
            "targets": labels.astype(jnp.int32)}


def mnist_batch(cfg: DataConfig, step: int, host: int = 0,
                num_hosts: int = 1, image_hw: int = 28,
                channels: int = 1) -> dict:
    """Synthetic MNIST-like digits: class-dependent blobs, 10 classes.
    ``channels > 1`` (CIFAR/SVHN-geometry CapsuleNet configs) tints the
    blob per channel so color carries class signal too."""
    b = cfg.global_batch // num_hosts
    key = _fold(cfg.seed, step, host)
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (b,), 0, 10)
    yy, xx = jnp.meshgrid(jnp.arange(image_hw), jnp.arange(image_hw),
                          indexing="ij")
    cy = 6 + 2 * (labels % 5)
    cx = 6 + 4 * (labels // 5)
    sigma = 2.0 + 0.35 * labels
    blob = jnp.exp(-(((yy[None] - cy[:, None, None]) ** 2
                      + (xx[None] - cx[:, None, None]) ** 2)
                     / (2 * sigma[:, None, None] ** 2)))
    noise = 0.08 * jax.random.uniform(k2, (b, image_hw, image_hw))
    img = jnp.clip(blob + noise, 0.0, 1.0)[..., None]
    if channels > 1:
        tint = 0.5 + 0.5 * jnp.cos(
            labels[:, None] * (1.0 + jnp.arange(channels)))
        img = img * tint[:, None, None, :]
    return {"images": img.astype(jnp.float32),
            "labels": labels.astype(jnp.int32)}


def batch_for_step(cfg: DataConfig, step: int, host: int = 0,
                   num_hosts: int = 1) -> dict:
    if cfg.kind == "lm":
        return lm_batch(cfg, step, host, num_hosts)
    if cfg.kind == "frames":
        return frames_batch(cfg, step, host, num_hosts)
    if cfg.kind == "mnist":
        return mnist_batch(cfg, step, host, num_hosts)
    raise ValueError(cfg.kind)


class DataIterator:
    """Stateful convenience wrapper with O(1) skip-ahead."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, host: int = 0,
                 num_hosts: int = 1):
        self.cfg, self.step, self.host, self.num_hosts = (
            cfg, start_step, host, num_hosts)

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        batch = batch_for_step(self.cfg, self.step, self.host,
                               self.num_hosts)
        self.step += 1
        return {k: np.asarray(v) for k, v in batch.items()}

    def skip_to(self, step: int) -> None:
        self.step = step
