"""Fault-tolerant training loop.

Production behaviours, all exercised by tests on CPU:

  * checkpoint/restart: async atomic checkpoints every N steps; on start,
    resume from the latest committed step (elastic: restore reshards onto
    the current mesh) and skip the data stream ahead deterministically;
  * NaN/divergence guard: a non-finite loss rolls params back to the last
    committed checkpoint and *skips the offending batch* (deterministic
    data makes the skip exact);
  * preemption: ``request_stop()`` (or SIGTERM) checkpoints and exits
    cleanly at the next step boundary;
  * heartbeat: a JSON heartbeat file per step for a cluster supervisor;
  * straggler hook: per-step wall time is tracked; steps slower than
    ``straggler_factor`` x running median invoke ``on_straggler`` (in a
    real deployment: trigger re-sharding / hot-spare swap; here: logged).

The skeleton (checkpoint/rollback/heartbeat/preemption) lives in
``train.harness.FaultTolerantLoop``; this subclass binds it to the LM
objective: jitted AdamW step, ``DataIterator`` stream with deterministic
skip-ahead, elastic restore through ``param_shardings``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.models.config import ModelConfig
from repro.models.transformer import init_model
from repro.train.data import DataConfig, DataIterator
from repro.train.harness import FaultTolerantLoop
from repro.train.optimizer import OptConfig, init_opt_state


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    log_every: int = 10
    heartbeat_path: str | None = None
    straggler_factor: float = 3.0
    max_nan_skips: int = 5
    seed: int = 0


class TrainLoop(FaultTolerantLoop):
    def __init__(self, model_cfg: ModelConfig, opt_cfg: OptConfig,
                 data_cfg: DataConfig, loop_cfg: LoopConfig,
                 shd=None, param_shardings=None,
                 on_straggler: Callable[[int, float], None] | None = None):
        super().__init__(loop_cfg, on_straggler=on_straggler)
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.shd = shd
        self.param_shardings = param_shardings
        self._data: DataIterator | None = None
        from repro.launch.steps import make_train_step  # avoid import cycle
        self._step_fn = jax.jit(make_train_step(model_cfg, opt_cfg, shd),
                                donate_argnums=(0, 1))

    # -- state ----------------------------------------------------------------
    def init_state(self) -> tuple[Any, Any]:
        params = init_model(jax.random.PRNGKey(self.loop_cfg.seed),
                            self.model_cfg)
        return params, init_opt_state(params)

    def try_restore(self, params, opt_state):
        state, start = self._try_restore({"params": params,
                                          "opt": opt_state})
        return state["params"], state["opt"], start

    # -- harness hooks ---------------------------------------------------------
    def _init_state(self) -> dict:
        params, opt_state = self.init_state()
        return {"params": params, "opt": opt_state}

    def _shardings(self):
        return self.param_shardings

    def _ckpt_extra(self) -> dict:
        return {"model": self.model_cfg.name}

    def _begin(self, start: int) -> None:
        self._data = DataIterator(self.data_cfg, start_step=start)

    def _next_batch(self, step: int):
        return next(self._data)

    def _skip_batch(self, step: int) -> None:
        self._data.skip_to(step)

    def _run_step(self, state: dict, batch) -> tuple[dict, dict]:
        params, opt_state, metrics = self._step_fn(state["params"],
                                                   state["opt"], batch)
        return {"params": params, "opt": opt_state}, metrics

    def _extra_record(self, metrics: dict) -> dict:
        return {"grad_norm": float(jax.device_get(metrics["grad_norm"]))}

    def _log_line(self, rec: dict) -> str:
        return (f"step {rec['step']:6d} loss {rec['loss']:9.4f} "
                f"gnorm {rec['grad_norm']:8.3f} {rec['time_s']*1e3:7.1f} ms")
