"""Fault-tolerant training loop.

Production behaviours, all exercised by tests on CPU:

  * checkpoint/restart: async atomic checkpoints every N steps; on start,
    resume from the latest committed step (elastic: restore reshards onto
    the current mesh) and skip the data stream ahead deterministically;
  * NaN/divergence guard: a non-finite loss rolls params back to the last
    committed checkpoint and *skips the offending batch* (deterministic
    data makes the skip exact);
  * preemption: ``request_stop()`` (or SIGTERM) checkpoints and exits
    cleanly at the next step boundary;
  * heartbeat: a JSON heartbeat file per step for a cluster supervisor;
  * straggler hook: per-step wall time is tracked; steps slower than
    ``straggler_factor`` x running median invoke ``on_straggler`` (in a
    real deployment: trigger re-sharding / hot-spare swap; here: logged).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import signal
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import init_model
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, DataIterator
from repro.train.optimizer import OptConfig, init_opt_state


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    log_every: int = 10
    heartbeat_path: str | None = None
    straggler_factor: float = 3.0
    max_nan_skips: int = 5
    seed: int = 0


class TrainLoop:
    def __init__(self, model_cfg: ModelConfig, opt_cfg: OptConfig,
                 data_cfg: DataConfig, loop_cfg: LoopConfig,
                 shd=None, param_shardings=None,
                 on_straggler: Callable[[int, float], None] | None = None):
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.loop_cfg = loop_cfg
        self.shd = shd
        self.param_shardings = param_shardings
        self.on_straggler = on_straggler or (lambda step, t: None)
        self._stop = False
        self.step = 0
        self.nan_skips = 0
        self._last_committed = 0         # latest step THIS run checkpointed
        self.history: list[dict] = []
        self.checkpointer = ckpt.AsyncCheckpointer(loop_cfg.ckpt_dir,
                                                   keep=loop_cfg.keep)
        from repro.launch.steps import make_train_step  # avoid import cycle
        self._step_fn = jax.jit(make_train_step(model_cfg, opt_cfg, shd),
                                donate_argnums=(0, 1))

    # -- lifecycle -----------------------------------------------------------
    def request_stop(self, *_args) -> None:
        self._stop = True

    def install_signal_handler(self) -> None:       # pragma: no cover
        signal.signal(signal.SIGTERM, self.request_stop)

    # -- state ----------------------------------------------------------------
    def init_state(self) -> tuple[Any, Any]:
        params = init_model(jax.random.PRNGKey(self.loop_cfg.seed),
                            self.model_cfg)
        return params, init_opt_state(params)

    def try_restore(self, params, opt_state):
        latest = ckpt.latest_step(self.loop_cfg.ckpt_dir)
        if latest is None:
            return params, opt_state, 0
        state = {"params": params, "opt": opt_state}
        restored, manifest = ckpt.restore(state, self.loop_cfg.ckpt_dir,
                                          shardings=self.param_shardings)
        return restored["params"], restored["opt"], manifest["step"]

    def _save(self, params, opt_state, step: int) -> None:
        self.checkpointer.save_async({"params": params, "opt": opt_state},
                                     step, extra={"model": self.model_cfg.name})
        self._last_committed = step

    def _heartbeat(self, step: int, metrics: dict) -> None:
        if self.loop_cfg.heartbeat_path is None:
            return
        hb = {"step": step, "time": time.time(),
              "loss": float(metrics.get("loss", np.nan))}
        p = pathlib.Path(self.loop_cfg.heartbeat_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(json.dumps(hb))
        tmp.rename(p)

    # -- main -----------------------------------------------------------------
    def run(self, resume: bool = True) -> list[dict]:
        params, opt_state = self.init_state()
        start = 0
        if resume:
            params, opt_state, start = self.try_restore(params, opt_state)
        if start == 0:
            ckpt.save({"params": params, "opt": opt_state},
                      self.loop_cfg.ckpt_dir, 0,
                      extra={"model": self.model_cfg.name})
        data = DataIterator(self.data_cfg, start_step=start)
        self.step = start
        self._last_committed = start
        times: list[float] = []

        while self.step < self.loop_cfg.total_steps and not self._stop:
            batch = next(data)
            t0 = time.time()
            params, opt_state, metrics = self._step_fn(params, opt_state,
                                                       batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.time() - t0

            if not np.isfinite(loss):
                # Roll back to THIS run's last committed checkpoint (a
                # shared ckpt_dir may hold later steps from an abandoned
                # run -- `latest_step` would silently resurrect them),
                # then skip the poisoned batch.
                self.nan_skips += 1
                if self.nan_skips > self.loop_cfg.max_nan_skips:
                    raise RuntimeError("too many non-finite steps")
                self.checkpointer.wait()
                params, opt_state = self.init_state()
                restored, _ = ckpt.restore(
                    {"params": params, "opt": opt_state},
                    self.loop_cfg.ckpt_dir, step=self._last_committed,
                    shardings=self.param_shardings)
                params, opt_state = restored["params"], restored["opt"]
                data.skip_to(self.step + 1)   # drop the poisoned batch
                self.step += 1
                continue

            times.append(dt)
            med = float(np.median(times[-21:]))
            if len(times) > 5 and dt > self.loop_cfg.straggler_factor * med:
                self.on_straggler(self.step, dt)

            self.step += 1
            rec = {"step": self.step, "loss": loss, "time_s": dt,
                   "grad_norm": float(jax.device_get(metrics["grad_norm"]))}
            self.history.append(rec)
            self._heartbeat(self.step, metrics)
            if self.step % self.loop_cfg.log_every == 0:
                print(f"step {self.step:6d} loss {loss:9.4f} "
                      f"gnorm {rec['grad_norm']:8.3f} {dt*1e3:7.1f} ms",
                      flush=True)
            if self.step % self.loop_cfg.ckpt_every == 0 \
                    or self.step == self.loop_cfg.total_steps:
                self._save(params, opt_state, self.step)

        if self._stop:   # preemption: commit state before exiting
            self.checkpointer.wait()
            ckpt.save({"params": params, "opt": opt_state},
                      self.loop_cfg.ckpt_dir, self.step,
                      extra={"model": self.model_cfg.name,
                             "preempted": True})
        self.checkpointer.wait()
        return self.history
