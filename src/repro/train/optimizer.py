"""AdamW with warmup+cosine schedule, global-norm clipping, ZeRO-1-ready
state (pure pytrees -- the sharding rules in ``repro.parallel`` shard m/v
over the data axes).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0


def lr_at(step: jax.Array, cfg: OptConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params: Any) -> dict:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(params: Any, grads: Any, state: dict,
                 cfg: OptConfig) -> tuple[Any, dict, dict]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
