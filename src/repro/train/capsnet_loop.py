"""Fault-tolerant CapsuleNet training through the Pallas backend.

The custom VJPs (``kernels/conv_im2col``, ``kernels/votes_routing``,
``kernels/squash``) make ``backend="pallas"`` differentiable end to end,
so the margin-loss + masked-reconstruction objective trains through the
SAME plan-driven kernels that serve inference -- with the backward
schedule pinned by ``compile_plan(train=True)`` (backward OpPlans:
per-mode VMEM footprints, ``u_hat``/``d u_hat`` never in HBM).

The loop reuses the repo's production training machinery on the CapsNet
objective:

  * checkpoint/restart: async atomic checkpoints every N steps
    (``train.checkpoint``), resume from the latest committed step with
    deterministic data skip-ahead;
  * NaN/divergence guard: a non-finite loss rolls params back to the
    last committed checkpoint and skips the offending batch;
  * heartbeat: a JSON heartbeat file per step for a supervisor.

CLI:  python -m repro.train.capsnet_loop --steps 20 --backend pallas
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import numpy as np

from repro.core import capsnet
from repro.core.capsnet import CapsNetConfig
from repro.core.execplan import compile_plan
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, mnist_batch

SMOKE = CapsNetConfig(image_hw=14, conv1_channels=16, conv1_kernel=5,
                      pc_kernel=3, num_primary_groups=4, primary_dim=4,
                      class_dim=8, decoder_hidden=(32, 64))
CONFIGS = {"smoke": SMOKE, "mnist": CapsNetConfig()}


@dataclasses.dataclass
class CapsLoopConfig:
    total_steps: int = 20
    batch: int = 16
    lr: float = 3e-2
    ckpt_every: int = 10
    ckpt_dir: str = "caps_checkpoints"
    keep: int = 3
    log_every: int = 5
    backend: str = "pallas"
    interpret: bool = True
    max_nan_skips: int = 5
    heartbeat_path: str | None = None
    seed: int = 0


class CapsTrainLoop:
    """SGD over ``capsnet.total_loss`` with checkpoint + NaN-guard."""

    def __init__(self, cfg: CapsNetConfig = SMOKE,
                 loop_cfg: CapsLoopConfig = CapsLoopConfig()):
        self.cfg = cfg
        self.loop_cfg = loop_cfg
        self.step = 0
        self.nan_skips = 0
        self._last_committed = 0         # latest step THIS run checkpointed
        self.history: list[dict] = []
        self.checkpointer = ckpt.AsyncCheckpointer(loop_cfg.ckpt_dir,
                                                   keep=loop_cfg.keep)
        self.data_cfg = DataConfig(kind="mnist",
                                   global_batch=loop_cfg.batch,
                                   seed=loop_cfg.seed)
        # ONE training plan: pins both the forward schedule and the
        # backward OpPlans the custom VJPs execute.
        self.plan = (compile_plan(cfg, batch=loop_cfg.batch, train=True)
                     if loop_cfg.backend == "pallas" else None)

        def step_fn(params, images, labels):
            (_, metrics), grads = jax.value_and_grad(
                capsnet.total_loss, has_aux=True)(
                    params, images, labels, cfg,
                    backend=loop_cfg.backend, plan=self.plan,
                    interpret=loop_cfg.interpret)
            params = jax.tree_util.tree_map(
                lambda p, g: p - loop_cfg.lr * g, params, grads)
            return params, metrics

        self._step_fn = jax.jit(step_fn, donate_argnums=(0,))

    # -- state ----------------------------------------------------------------
    def init_params(self):
        return capsnet.init_params(jax.random.PRNGKey(self.loop_cfg.seed),
                                   self.cfg)

    def try_restore(self, params):
        latest = ckpt.latest_step(self.loop_cfg.ckpt_dir)
        if latest is None:
            return params, 0
        restored, manifest = ckpt.restore({"params": params},
                                          self.loop_cfg.ckpt_dir)
        return restored["params"], manifest["step"]

    def _batch(self, step: int) -> dict:
        return mnist_batch(self.data_cfg, step,
                           image_hw=self.cfg.image_hw)

    def _heartbeat(self, step: int, loss: float) -> None:
        if self.loop_cfg.heartbeat_path is None:
            return
        p = pathlib.Path(self.loop_cfg.heartbeat_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(json.dumps({"step": step, "time": time.time(),
                                   "loss": loss}))
        tmp.rename(p)

    # -- main -----------------------------------------------------------------
    def run(self, resume: bool = True) -> list[dict]:
        params = self.init_params()
        start = 0
        if resume:
            params, start = self.try_restore(params)
        if start == 0:
            ckpt.save({"params": params}, self.loop_cfg.ckpt_dir, 0,
                      extra={"backend": self.loop_cfg.backend})
        self.step = start
        self._last_committed = start

        while self.step < self.loop_cfg.total_steps:
            batch = self._batch(self.step)
            t0 = time.time()
            params, metrics = self._step_fn(params, batch["images"],
                                            batch["labels"])
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.time() - t0

            if not np.isfinite(loss):
                # Roll back to THIS run's last committed checkpoint (a
                # shared ckpt_dir may hold later steps from an abandoned
                # run -- `latest_step` would silently resurrect them),
                # then skip the poisoned batch.
                self.nan_skips += 1
                if self.nan_skips > self.loop_cfg.max_nan_skips:
                    raise RuntimeError("too many non-finite steps")
                self.checkpointer.wait()
                restored, _ = ckpt.restore({"params": self.init_params()},
                                           self.loop_cfg.ckpt_dir,
                                           step=self._last_committed)
                params = restored["params"]
                self.step += 1             # drop the poisoned batch
                continue

            self.step += 1
            rec = {"step": self.step, "loss": loss,
                   "accuracy": float(jax.device_get(metrics["accuracy"])),
                   "time_s": dt}
            self.history.append(rec)
            self._heartbeat(self.step, loss)
            if self.step % self.loop_cfg.log_every == 0:
                print(f"step {self.step:6d} loss {loss:9.4f} "
                      f"acc {rec['accuracy']:5.2f} {dt * 1e3:7.1f} ms",
                      flush=True)
            if self.step % self.loop_cfg.ckpt_every == 0 \
                    or self.step == self.loop_cfg.total_steps:
                self.checkpointer.save_async(
                    {"params": params}, self.step,
                    extra={"backend": self.loop_cfg.backend})
                self._last_committed = self.step

        self.checkpointer.wait()
        return self.history


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--backend", choices=("jnp", "pallas"),
                    default="pallas")
    ap.add_argument("--config", choices=sorted(CONFIGS), default="smoke")
    ap.add_argument("--ckpt-dir", default="caps_checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--assert-improves", action="store_true",
                    help="exit nonzero unless the loss decreased and no "
                         "NaN-guard rollback fired (the CI smoke gate)")
    args = ap.parse_args(argv)

    loop = CapsTrainLoop(CONFIGS[args.config], CapsLoopConfig(
        total_steps=args.steps, batch=args.batch, lr=args.lr,
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        backend=args.backend))
    hist = loop.run(resume=not args.no_resume)
    if not hist:
        print("nothing to do (already at the requested step)")
        return 0
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    print(f"loss {first:.4f} -> {last:.4f} over {len(hist)} steps "
          f"({loop.nan_skips} NaN-guard rollbacks)")
    if args.assert_improves and (last >= first or loop.nan_skips > 0):
        print("FAIL: loss did not decrease (or a NaN rollback fired)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
