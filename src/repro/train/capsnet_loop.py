"""Fault-tolerant CapsuleNet training through the Pallas backend.

The custom VJPs (``kernels/conv_im2col``, ``kernels/votes_routing``,
``kernels/primary_routing``, ``kernels/squash``) make
``backend="pallas"`` differentiable end to end, so the margin-loss +
masked-reconstruction objective trains through the SAME plan-driven
kernels that serve inference -- with the backward schedule pinned by
``compile_plan(train=True)`` (backward OpPlans: per-mode VMEM
footprints, ``u_hat``/``d u_hat`` never in HBM).  The forward side of
the training plan is PIPELINED: PrimaryCaps epilogue streams into the
routing megakernel when the combined footprint fits, per-op fallback
otherwise.

Two optimizers:

  * ``sgd`` (default): plain SGD at a fixed ``lr`` -- the original CI
    smoke configuration, checkpoint state is params-only;
  * ``adam``: AdamW + warmup/cosine decay from ``train.optimizer``
    (``decay_steps`` pinned to the run horizon), checkpoint state gains
    the m/v/step optimizer tree.

The checkpoint/NaN-guard/heartbeat skeleton is
``train.harness.FaultTolerantLoop`` -- shared with the LM loop.

CLI:  python -m repro.train.capsnet_loop --steps 20 --backend pallas \
          --optimizer adam
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.core import capsnet
from repro.core.capsnet import CapsNetConfig
from repro.core.execplan import compile_plan
from repro.train.data import DataConfig, mnist_batch
from repro.train.harness import FaultTolerantLoop
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state

SMOKE = CapsNetConfig(image_hw=14, conv1_channels=16, conv1_kernel=5,
                      pc_kernel=3, num_primary_groups=4, primary_dim=4,
                      class_dim=8, decoder_hidden=(32, 64))
CONFIGS = {"smoke": SMOKE, "mnist": CapsNetConfig()}


@dataclasses.dataclass
class CapsLoopConfig:
    total_steps: int = 20
    batch: int = 16
    lr: float = 3e-2
    optimizer: str = "sgd"            # "sgd" | "adam"
    warmup_steps: int = 2             # adam only
    weight_decay: float = 0.0         # adam only
    ckpt_every: int = 10
    ckpt_dir: str = "caps_checkpoints"
    keep: int = 3
    log_every: int = 5
    backend: str = "pallas"
    interpret: bool = True
    max_nan_skips: int = 5            # bounds CONSECUTIVE non-finite steps
    straggler_factor: float | None = None   # step-time multiple that flags
    heartbeat_path: str | None = None
    seed: int = 0


class CapsTrainLoop(FaultTolerantLoop):
    """SGD/AdamW over ``capsnet.total_loss`` with checkpoint + NaN-guard."""

    def __init__(self, cfg: CapsNetConfig = SMOKE,
                 loop_cfg: CapsLoopConfig = CapsLoopConfig(),
                 on_straggler=None):
        if loop_cfg.optimizer not in ("sgd", "adam"):
            raise ValueError(f"unknown optimizer {loop_cfg.optimizer!r}")
        super().__init__(loop_cfg, on_straggler=on_straggler)
        self.cfg = cfg
        self.data_cfg = DataConfig(kind="mnist",
                                   global_batch=loop_cfg.batch,
                                   seed=loop_cfg.seed)
        # ONE training plan: pins both the forward schedule and the
        # backward OpPlans the custom VJPs execute.  Pipelined: the
        # forward runs the PrimaryCaps->ClassCaps pair as one kernel
        # when it fits; the backward OpPlans are per-op either way.
        self.plan = (compile_plan(cfg, batch=loop_cfg.batch, train=True,
                                  pipeline=True)
                     if loop_cfg.backend == "pallas" else None)
        self.opt_cfg = (OptConfig(peak_lr=loop_cfg.lr,
                                  warmup_steps=loop_cfg.warmup_steps,
                                  decay_steps=loop_cfg.total_steps,
                                  weight_decay=loop_cfg.weight_decay)
                        if loop_cfg.optimizer == "adam" else None)

        def loss_and_grads(params, images, labels):
            return jax.value_and_grad(capsnet.total_loss, has_aux=True)(
                params, images, labels, cfg,
                backend=loop_cfg.backend, plan=self.plan,
                interpret=loop_cfg.interpret)

        if loop_cfg.optimizer == "adam":
            def step_fn(params, opt, images, labels):
                (_, metrics), grads = loss_and_grads(params, images, labels)
                params, opt, opt_m = adamw_update(params, grads, opt,
                                                  self.opt_cfg)
                return params, opt, {**metrics, **opt_m}

            self._step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        else:
            def step_fn(params, images, labels):
                (_, metrics), grads = loss_and_grads(params, images, labels)
                params = jax.tree_util.tree_map(
                    lambda p, g: p - loop_cfg.lr * g, params, grads)
                return params, metrics

            self._step_fn = jax.jit(step_fn, donate_argnums=(0,))

    # -- state ----------------------------------------------------------------
    def init_params(self):
        return capsnet.init_params(jax.random.PRNGKey(self.loop_cfg.seed),
                                   self.cfg)

    def try_restore(self, params):
        state = {"params": params}
        if self.opt_cfg is not None:
            state["opt"] = init_opt_state(params)
        state, start = self._try_restore(state)
        return state["params"], start

    # -- harness hooks ---------------------------------------------------------
    def _init_state(self) -> dict:
        params = self.init_params()
        if self.opt_cfg is not None:
            return {"params": params, "opt": init_opt_state(params)}
        return {"params": params}

    def _ckpt_extra(self) -> dict:
        return {"backend": self.loop_cfg.backend,
                "optimizer": self.loop_cfg.optimizer}

    def _next_batch(self, step: int) -> dict:
        return self._batch(step)

    def _batch(self, step: int) -> dict:
        return mnist_batch(self.data_cfg, step,
                           image_hw=self.cfg.image_hw,
                           channels=self.cfg.in_channels)

    def _run_step(self, state: dict, batch) -> tuple[dict, dict]:
        if "opt" in state:
            params, opt, metrics = self._step_fn(
                state["params"], state["opt"],
                batch["images"], batch["labels"])
            return {"params": params, "opt": opt}, metrics
        params, metrics = self._step_fn(state["params"], batch["images"],
                                        batch["labels"])
        return {"params": params}, metrics

    def _extra_record(self, metrics: dict) -> dict:
        rec = {"accuracy": float(jax.device_get(metrics["accuracy"]))}
        if "lr" in metrics:
            rec["lr"] = float(jax.device_get(metrics["lr"]))
        return rec

    def _log_line(self, rec: dict) -> str:
        return (f"step {rec['step']:6d} loss {rec['loss']:9.4f} "
                f"acc {rec['accuracy']:5.2f} {rec['time_s'] * 1e3:7.1f} ms")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--optimizer", choices=("sgd", "adam"), default="sgd",
                    help="sgd: fixed-lr SGD (default); adam: AdamW + "
                         "warmup/cosine from train.optimizer")
    ap.add_argument("--backend", choices=("jnp", "pallas"),
                    default="pallas")
    ap.add_argument("--config", choices=sorted(CONFIGS), default="smoke")
    ap.add_argument("--arch", default=None,
                    help="registry architecture id (e.g. capsnet_mnist, "
                         "capsnet_cifar10, capsnet_svhn); overrides "
                         "--config.  Deep-stack archs train through the "
                         "per-layer graph plan + reversible backward.")
    ap.add_argument("--smoke", action="store_true",
                    help="with --arch: use the arch's smoke_config() "
                         "(toy widths, same topology)")
    ap.add_argument("--ckpt-dir", default="caps_checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--assert-improves", action="store_true",
                    help="exit nonzero unless the loss decreased and no "
                         "NaN-guard rollback fired (the CI smoke gate)")
    args = ap.parse_args(argv)

    if args.arch is not None:
        from repro.configs import registry
        cfg = (registry.get_smoke_config(args.arch) if args.smoke
               else registry.get_config(args.arch))
        if not isinstance(cfg, CapsNetConfig):
            ap.error(f"--arch {args.arch} is not a CapsuleNet workload "
                     f"(CapsuleNet archs: {registry.CAPSNET_ARCHS})")
    else:
        cfg = CONFIGS[args.config]
    loop = CapsTrainLoop(cfg, CapsLoopConfig(
        total_steps=args.steps, batch=args.batch, lr=args.lr,
        optimizer=args.optimizer, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, backend=args.backend))
    hist = loop.run(resume=not args.no_resume)
    if not hist:
        print("nothing to do (already at the requested step)")
        return 0
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    print(f"loss {first:.4f} -> {last:.4f} over {len(hist)} steps "
          f"({loop.nan_skips} NaN-guard rollbacks)")
    if args.assert_improves and (last >= first or loop.nan_skips > 0):
        print("FAIL: loss did not decrease (or a NaN rollback fired)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
