"""Mamba2 (SSD -- state-space duality) block: chunked matrix form for
train/prefill, O(1)-state recurrent form for decode.

The chunked SSD algorithm is the SSM analogue of flash attention: within a
chunk the quadratic "attention-like" term runs on the MXU; across chunks a
small recurrent state [H, P, N] carries -- which is also exactly the
CapStore story: the inter-chunk state is the accumulator memory (resident),
X/B/C stream through like conv weights, and the chunk length is the tile
size the planner reasons about.

Decode cache per layer:
    {"conv": [B, d_conv-1, CH], "ssd": [B, H, P, N]}
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_linear, rmsnorm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_ch = di + 2 * s.n_groups * s.d_state
    return s, di, nh, conv_ch


def init_mamba_params(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    s, di, nh, conv_ch = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[6], (nh,))
                 * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    return {
        "z_proj": init_linear(ks[0], d, di, dtype),
        "x_proj": init_linear(ks[1], d, di, dtype),
        "b_proj": init_linear(ks[2], d, s.n_groups * s.d_state, dtype),
        "c_proj": init_linear(ks[3], d, s.n_groups * s.d_state, dtype),
        "dt_proj": init_linear(ks[4], d, nh, dtype),
        "conv_w": 0.1 * jax.random.normal(ks[5], (conv_ch, s.d_conv), dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)).astype(dtype),
        "ssm_d": jnp.ones((nh,), dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype),  # inv softplus
        "mamba_norm": jnp.zeros((di,), dtype),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  xbc: [B, T, CH], w: [CH, K].

    Returns (out [B, T, CH], new_tail [B, K-1, CH]).
    """
    k = w.shape[1]
    if tail is None:
        tail = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    # Keep compute in the activation dtype regardless of cache storage
    # dtype (a f32 cache must not promote the whole block to f32).
    full = jnp.concatenate([tail.astype(xbc.dtype), xbc], axis=1)
    out = sum(full[:, i:i + xbc.shape[1], :] * w[None, None, :, k - 1 - i]
              for i in range(k))
    new_tail = full[:, -(k - 1):, :] if k > 1 else tail
    return jax.nn.silu(out + b), new_tail


def ssd_chunked(x: jax.Array, a: jax.Array, bmat: jax.Array, cmat: jax.Array,
                chunk: int, h0: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """SSD scan in chunked matrix form.

    x:    [B, T, H, P]   (dt already folded in: x * dt)
    a:    [B, T, H]      (log-decay per step: A * dt, negative)
    bmat: [B, T, H, N], cmat: [B, T, H, N]  (already group-expanded)
    Returns (y [B, T, H, P], final_state [B, H, P, N]).
    """
    b, t, h, p = x.shape
    n = bmat.shape[-1]
    cl = min(chunk, t)
    while t % cl:
        cl //= 2
    nc = t // cl
    xr = x.reshape(b, nc, cl, h, p)
    br = bmat.reshape(b, nc, cl, h, n)
    cr = cmat.reshape(b, nc, cl, h, n)
    ar = a.reshape(b, nc, cl, h).transpose(0, 3, 1, 2)    # [B, H, C, L]
    cs = jnp.cumsum(ar, axis=-1)

    # Intra-chunk (quadratic, MXU-friendly).
    diff = cs[..., :, None] - cs[..., None, :]           # [B,H,C,L,L]
    mask = jnp.tril(jnp.ones((cl, cl), bool))
    lmat = jnp.where(mask, jnp.exp(diff), 0.0).astype(x.dtype)
    scores = jnp.einsum("bclhn,bcshn->bhcls", cr, br) * lmat
    y_diag = jnp.einsum("bhcls,bcshp->bclhp", scores, xr)

    # Per-chunk boundary states.
    decay_states = jnp.exp(cs[..., -1:] - cs).astype(x.dtype)   # [B,H,C,L]
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", br, decay_states, xr)
    chunk_decay = jnp.exp(cs[..., -1]).astype(x.dtype)          # [B,H,C]
    decay_out = jnp.exp(cs).astype(x.dtype)                     # [B,H,C,L]

    def step(carry, inp):
        st, cd, c_c, dout = inp
        y_off = jnp.einsum("blhn,bhpn->blhp", c_c, carry) \
            * dout.transpose(0, 2, 1)[..., None]
        new = cd[..., None, None] * carry + st
        return new, y_off

    h_init = (jnp.zeros((b, h, p, n), x.dtype) if h0 is None
              else h0.astype(x.dtype))
    xs = (states.transpose(1, 0, 2, 3, 4),               # [C,B,H,P,N]
          chunk_decay.transpose(2, 0, 1),                # [C,B,H]
          cr.transpose(1, 0, 2, 3, 4),                   # [C,B,L,H,N]
          decay_out.transpose(2, 0, 1, 3))               # [C,B,H,L]
    final, y_off = jax.lax.scan(step, h_init, xs)
    y = y_diag + y_off.transpose(1, 0, 2, 3, 4)
    return y.reshape(b, t, h, p), final


def ssd_recurrent_step(x, a, bmat, cmat, h):
    """One decode step.  x: [B,1,H,P], a: [B,1,H], b/c: [B,1,H,N].

    The recurrent state stays in fp32 (it integrates over the whole
    sequence); the output is cast back to the activation dtype.
    """
    decay = jnp.exp(a[:, 0].astype(jnp.float32))         # [B,H]
    h32 = h.astype(jnp.float32)
    upd = jnp.einsum("bhp,bhn->bhpn", x[:, 0].astype(jnp.float32),
                     bmat[:, 0].astype(jnp.float32))
    h_new = decay[..., None, None] * h32 + upd
    y = jnp.einsum("bhpn,bhn->bhp", h_new,
                   cmat[:, 0].astype(jnp.float32))
    return y[:, None].astype(x.dtype), h_new


def mamba_forward(params: dict, x: jax.Array, *, cfg: ModelConfig,
                  cache: dict | None, shd=None) -> tuple[jax.Array, dict | None]:
    """x: [B, T, D] -> (out [B, T, D], new_cache)."""
    s, di, nh, conv_ch = _dims(cfg)
    b, t, d = x.shape
    p = s.head_dim
    g, n = s.n_groups, s.d_state

    z = x @ params["z_proj"]
    xi = x @ params["x_proj"]
    bm = x @ params["b_proj"]
    cm = x @ params["c_proj"]
    dt = x @ params["dt_proj"]
    if shd is not None:
        z = shd.act(z, "btf")
        xi = shd.act(xi, "btf")

    xbc = jnp.concatenate([xi, bm, cm], axis=-1)         # [B, T, CH]
    conv_tail = cache["conv"] if cache is not None else None
    xbc, new_tail = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_tail)
    xi, bm, cm = jnp.split(xbc, [di, di + g * n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # [B,T,H]
    a = (-jnp.exp(params["a_log"].astype(jnp.float32)))[None, None] * dt

    xh = xi.reshape(b, t, nh, p) * dt[..., None].astype(xi.dtype)
    rep = nh // g
    bh = jnp.repeat(bm.reshape(b, t, g, n), rep, axis=2)
    ch = jnp.repeat(cm.reshape(b, t, g, n), rep, axis=2)

    h0 = cache["ssd"] if cache is not None else None
    if t == 1 and cache is not None:
        y, h_final = ssd_recurrent_step(xh, a, bh, ch, h0)
    else:
        y, h_final = ssd_chunked(xh, a, bh, ch, s.chunk, h0)

    y = y + params["ssm_d"][None, None, :, None] * xi.reshape(b, t, nh, p)
    y = y.reshape(b, t, di)
    y = rmsnorm(y * jax.nn.silu(z), params["mamba_norm"], cfg.norm_eps,
                cfg.norm_fp32)
    out = y @ params["out_proj"] if "out_proj" in params else y
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_tail.astype(cache["conv"].dtype),
                     "ssd": h_final.astype(cache["ssd"].dtype)}
    return out, new_cache


def init_mamba_block(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    s, di, _, _ = _dims(cfg)
    k1, k2 = jax.random.split(key)
    p = init_mamba_params(k1, cfg, dtype)
    p["out_proj"] = init_linear(k2, di, cfg.d_model, dtype)
    return p


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    s, di, nh, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
        "ssd": jnp.zeros((batch, nh, s.head_dim, s.d_state), dtype),
    }
