"""Unified model configuration covering the assigned architecture pool.

One ``ModelConfig`` describes every family in the pool (dense GQA, MLA,
MoE, Mamba2/SSD, hybrid, encoder-only, early-fusion VLM) via a repeating
*block pattern* -- e.g. Gemma-2 is ``("local", "global") * 21``.  The stack
is lowered as ``prefix layers + scan(pattern) * repeats + suffix layers``
so the compiled HLO stays compact at any depth.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal[
    "global",        # full causal attention + MLP
    "local",         # sliding-window causal attention + MLP
    "bidir",         # bidirectional attention + MLP (encoder-only)
    "mamba",         # Mamba2/SSD block
    "shared_attn",   # attention+MLP block with weights shared across uses
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0       # DeepSeek shared experts (always on)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | ssm | moe | hybrid | audio | vlm
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- stack structure ---
    pattern: tuple[BlockKind, ...]
    repeats: int
    prefix: tuple[BlockKind, ...] = ()
    suffix: tuple[BlockKind, ...] = ()
    # --- attention flavour ---
    causal: bool = True
    sliding_window: int | None = None
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    query_scale: float | None = None  # overrides 1/sqrt(head_dim)
    rope_theta: float = 10000.0
    mla: MLAConfig | None = None
    # --- mlp flavour ---
    mlp_act: str = "silu"             # silu | gelu (GeGLU when gated)
    use_post_norms: bool = False      # Gemma-2/3 post-attn/post-mlp norms
    # --- optional subsystems ---
    moe: MoEConfig | None = None      # applied to attention blocks' MLP
    moe_in_prefix: bool = False       # prefix layers use dense MLP if False
    ssm: SSMConfig | None = None
    # --- embedding ---
    tie_embeddings: bool = True
    scale_embeddings: bool = False    # Gemma: x *= sqrt(d_model)
    frontend: str | None = None       # None | "audio_frames" (stub embeds)
    frontend_dim: int = 512
    norm_eps: float = 1e-6
    # --- remat / numerics knobs (hillclimb levers) ---
    remat: str = "full"               # full | dots | none
    logits_fp32: bool = True
    attn_fp32_softmax: bool = True    # False: bf16 logits (hillclimb lever)
    norm_fp32: bool = True            # False: bf16 norm-apply (hillclimb)
    manual_tp: bool = False           # shard_map Megatron-SP (RS+AG wire)

    @property
    def num_layers(self) -> int:
        return len(self.prefix) + len(self.pattern) * self.repeats + len(self.suffix)

    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to a multiple of 256 (Megatron-style) so the
        embedding shards evenly over any TP degree up to 256; padded
        logit columns are masked to -inf in the LM head."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_dim(self) -> int:
        if self.mla:
            return self.num_heads * (self.mla.qk_nope_head_dim
                                     + self.mla.qk_rope_head_dim)
        return self.num_heads * self.head_dim

    @property
    def has_attention(self) -> bool:
        kinds = set(self.prefix) | set(self.pattern) | set(self.suffix)
        return bool(kinds & {"global", "local", "bidir", "shared_attn"})

    @property
    def has_decode(self) -> bool:
        return self.causal   # encoder-only models have no autoregressive step

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md skip policy)."""
        if self.ssm is not None and self.mla is None:
            return True                       # SSM / hybrid
        if self.mla is not None:
            return True                       # compressed-KV (MLA)
        kinds = [k for k in (list(self.prefix)
                             + list(self.pattern) * self.repeats
                             + list(self.suffix))]
        local = sum(1 for k in kinds if k == "local")
        return self.sliding_window is not None and local >= len(kinds) // 2

    def layer_kinds(self) -> list[BlockKind]:
        return (list(self.prefix) + list(self.pattern) * self.repeats
                + list(self.suffix))

    def validate(self) -> "ModelConfig":
        assert self.num_heads % self.num_kv_heads == 0, "GQA group mismatch"
        if self.ssm is None:
            assert "mamba" not in self.layer_kinds()
        if self.moe is None:
            assert self.family not in ("moe",)
        return self


# ---------------------------------------------------------------------------
# Parameter counting (drives MODEL_FLOPS = 6*N*D in the roofline)
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Analytical parameter count; `active_only` counts top-k experts only."""
    d = cfg.d_model
    n = 0
    n += cfg.padded_vocab_size * d                # embedding (as lowered)
    if not cfg.tie_embeddings:
        n += cfg.padded_vocab_size * d
    kinds = cfg.layer_kinds()
    shared_done = False
    for pos, kind in enumerate(kinds):
        if kind == "shared_attn":
            if shared_done:
                continue
            shared_done = True
        if kind == "mamba":
            s = cfg.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            conv_ch = di + 2 * s.n_groups * s.d_state
            n += d * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj
            n += conv_ch * s.d_conv + conv_ch                    # conv1d
            n += nh * 2                                          # A_log, D
            n += nh                                              # dt_bias
            n += di * d                                          # out_proj
            n += d                                               # norm
            continue
        # attention block
        if cfg.mla:
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            n += d * cfg.num_heads * qk                          # q
            n += d * (m.kv_lora_rank + m.qk_rope_head_dim)       # kv down
            n += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim
                                                   + m.v_head_dim)
            n += cfg.num_heads * m.v_head_dim * d                # o
        else:
            n += d * cfg.num_heads * cfg.head_dim                # q
            n += 2 * d * cfg.num_kv_heads * cfg.head_dim         # k, v
            n += cfg.num_heads * cfg.head_dim * d                # o
        # mlp (dense or MoE); prefix layers are dense unless moe_in_prefix.
        in_prefix = pos < len(cfg.prefix)
        is_moe_layer = (cfg.moe is not None and kind != "shared_attn"
                        and (cfg.moe_in_prefix or not in_prefix))
        if is_moe_layer:
            e = cfg.moe
            per_expert = 3 * d * e.d_ff_expert
            experts = (e.top_k if active_only else e.num_experts)
            n += experts * per_expert
            n += e.num_shared_experts * per_expert
            n += d * e.num_experts                               # router
        else:
            n += 3 * d * cfg.d_ff                                # gate/up/down
        n += 2 * d                                               # norms
        if cfg.use_post_norms:
            n += 2 * d
    n += d                                                       # final norm
    return n
