"""Block-pattern transformer stack: init / train forward / prefill / decode.

The stack is ``prefix + scan(pattern) * repeats + suffix``.  Scanning the
repeating pattern keeps the HLO compact for 28..48-layer models (one
while-loop regardless of depth), which is what makes the 512-device AOT
dry-run tractable.  ``shared_attn`` slots (Zamba-2) read their weights from
an unscanned ``shared`` branch, so the weights are truly shared while each
occurrence keeps its own KV cache slice.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig
from repro.models.layers import (embed_tokens, gated_mlp, init_linear,
                                 lm_head, rmsnorm)

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Per-block init
# ---------------------------------------------------------------------------

def _init_attn_block(key, cfg: ModelConfig, dtype, use_moe: bool) -> Params:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p: Params = {"input_norm": jnp.zeros((cfg.d_model,), dtype),
                 "pre_mlp_norm": jnp.zeros((cfg.d_model,), dtype)}
    p.update(attn.init_attn_params(k1, cfg, dtype))
    if use_moe:
        p.update(moe_mod.init_moe_params(k2, cfg, dtype))
    else:
        p["gate_proj"] = init_linear(k2, cfg.d_model, cfg.d_ff, dtype)
        p["up_proj"] = init_linear(k3, cfg.d_model, cfg.d_ff, dtype)
        p["down_proj"] = init_linear(k4, cfg.d_ff, cfg.d_model, dtype)
    if cfg.use_post_norms:
        p["post_attn_norm"] = jnp.zeros((cfg.d_model,), dtype)
        p["post_mlp_norm"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _init_block(key, kind: str, cfg: ModelConfig, dtype,
                in_prefix: bool = False) -> Params:
    if kind == "mamba":
        p = mb.init_mamba_block(key, cfg, dtype)
        p["input_norm"] = jnp.zeros((cfg.d_model,), dtype)
        return p
    if kind == "shared_attn":
        return {}                      # weights live in params["shared"]
    use_moe = cfg.moe is not None and (cfg.moe_in_prefix or not in_prefix)
    return _init_attn_block(key, cfg, dtype, use_moe)


def init_model(key: jax.Array, cfg: ModelConfig,
               dtype=jnp.float32) -> Params:
    cfg.validate()
    keys = iter(jax.random.split(key, 8 + cfg.num_layers + len(cfg.pattern)))
    params: Params = {
        "embed": (1.0 / cfg.d_model ** 0.5) * jax.random.normal(
            next(keys), (cfg.padded_vocab_size, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_linear(next(keys), cfg.d_model,
                                        cfg.padded_vocab_size, dtype)
    if cfg.frontend == "audio_frames":
        params["frontend_proj"] = init_linear(next(keys), cfg.frontend_dim,
                                              cfg.d_model, dtype)
    params["prefix"] = [
        _init_block(next(keys), kind, cfg, dtype, in_prefix=True)
        for kind in cfg.prefix]
    params["suffix"] = [
        _init_block(next(keys), kind, cfg, dtype) for kind in cfg.suffix]
    if "shared_attn" in cfg.pattern or "shared_attn" in cfg.prefix \
            or "shared_attn" in cfg.suffix:
        params["shared"] = _init_attn_block(next(keys), cfg, dtype,
                                            use_moe=False)
    # Stacked pattern blocks: slot s{i} -> [repeats, ...] leaves.
    blocks: Params = {}
    for i, kind in enumerate(cfg.pattern):
        per_repeat = [_init_block(next(keys), kind, cfg, dtype)
                      for _ in range(cfg.repeats)]
        blocks[f"s{i}"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *per_repeat) if per_repeat[0] else {}
    params["blocks"] = blocks
    return params


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def _block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                 dtype) -> dict | None:
    if kind == "mamba":
        return mb.init_mamba_cache(cfg, batch, jnp.float32)
    if kind in ("global", "local", "shared_attn"):
        return attn.init_cache(cfg, batch, max_len, dtype)
    return None


def init_model_cache(cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16) -> dict:
    cache: dict = {"prefix": [], "suffix": [], "blocks": {}}
    for kind in cfg.prefix:
        cache["prefix"].append(_block_cache(kind, cfg, batch, max_len, dtype))
    for kind in cfg.suffix:
        cache["suffix"].append(_block_cache(kind, cfg, batch, max_len, dtype))
    for i, kind in enumerate(cfg.pattern):
        one = _block_cache(kind, cfg, batch, max_len, dtype)
        cache["blocks"][f"s{i}"] = (
            None if one is None else jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    x[None], (cfg.repeats,) + x.shape).copy(), one))
    return cache


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _apply_block(p: Params, kind: str, x, positions, *, cfg: ModelConfig,
                 cache, cache_index, shd, shared: Params | None,
                 in_prefix: bool = False):
    aux = jnp.zeros((), jnp.float32)
    if kind == "mamba":
        h = rmsnorm(x, p["input_norm"], cfg.norm_eps, cfg.norm_fp32)
        out, new_cache = mb.mamba_forward(p, h, cfg=cfg, cache=cache, shd=shd)
        x = x + out
        if shd is not None:
            x = shd.act(x, "btd")
        return x, new_cache, aux

    blk = shared if kind == "shared_attn" else p
    window = cfg.sliding_window if kind == "local" else None
    manual = cfg.manual_tp and cache is None and shd is not None
    h = rmsnorm(x, blk["input_norm"], cfg.norm_eps, cfg.norm_fp32)
    if manual:
        from repro.models.layers import ag_seq
        h = ag_seq(h, shd)      # SP -> TP transition (explicit all-gather)
    if cfg.mla:
        a_out, new_cache = attn.mla_forward(
            blk, h, positions, cfg=cfg, cache=cache,
            cache_index=cache_index, shd=shd)
    else:
        a_out, new_cache = attn.gqa_forward(
            blk, h, positions, cfg=cfg, window=window, cache=cache,
            cache_index=cache_index, shd=shd)
    if shd is not None:
        # Pin the TP reduction of the o_proj output HERE, on the bf16
        # tensor -- otherwise the partitioner rides the all-reduce on the
        # f32 side of the next norm's stats cast (2x wire bytes).
        a_out = shd.act(a_out, "btd")
    if cfg.use_post_norms:
        a_out = rmsnorm(a_out, blk["post_attn_norm"], cfg.norm_eps, cfg.norm_fp32)
    x = x + a_out
    if shd is not None:
        x = shd.act(x, "btd")

    h = rmsnorm(x, blk["pre_mlp_norm"], cfg.norm_eps, cfg.norm_fp32)
    use_moe = (cfg.moe is not None and kind != "shared_attn"
               and (cfg.moe_in_prefix or not in_prefix))
    if use_moe:
        m_out, aux = moe_mod.moe_forward(blk, h, cfg=cfg, shd=shd)
    else:
        if manual:
            from repro.models.layers import ag_seq
            h = ag_seq(h, shd)
        m_out = gated_mlp(h, blk["gate_proj"], blk["up_proj"],
                          blk["down_proj"], cfg.mlp_act, shd=shd,
                          manual_tp=manual)
    if shd is not None:
        m_out = shd.act(m_out, "btd")   # pin the down_proj TP reduction
    if cfg.use_post_norms:
        m_out = rmsnorm(m_out, blk["post_mlp_norm"], cfg.norm_eps, cfg.norm_fp32)
    x = x + m_out
    if shd is not None:
        x = shd.act(x, "btd")
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------

def forward(params: Params, inputs: jax.Array, *, cfg: ModelConfig,
            shd=None, cache: dict | None = None,
            cache_index: jax.Array | None = None
            ) -> tuple[jax.Array, dict | None, jax.Array]:
    """inputs: int tokens [B, T] or frontend frames [B, T, F].

    Returns (logits [B, T, V], new_cache, aux_loss).
    """
    if cfg.frontend == "audio_frames":
        x = inputs @ params["frontend_proj"]
        if cfg.scale_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    else:
        x = embed_tokens(params["embed"], inputs, cfg.scale_embeddings,
                         cfg.d_model)
    if shd is not None:
        x = shd.act(x, "btd")
    b, t = x.shape[:2]
    if cache_index is None:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    else:
        positions = attn.query_positions(cache_index, b, t)
    aux_total = jnp.zeros((), jnp.float32)
    shared = params.get("shared")

    new_cache: dict = {"prefix": [], "suffix": [], "blocks": {}}
    for i, kind in enumerate(cfg.prefix):
        c = cache["prefix"][i] if cache is not None else None
        x, nc, aux = _apply_block(params["prefix"][i], kind, x, positions,
                                  cfg=cfg, cache=c, cache_index=cache_index,
                                  shd=shd, shared=shared, in_prefix=True)
        new_cache["prefix"].append(nc)
        aux_total += aux

    # Scanned pattern stack.
    if cfg.repeats > 0 and cfg.pattern:
        block_caches = (cache["blocks"] if cache is not None else
                        {f"s{i}": None for i in range(len(cfg.pattern))})

        def body(carry, xs):
            xx, aux_sum = carry
            slot_params, slot_caches = xs
            out_caches = {}
            for i, kind in enumerate(cfg.pattern):
                xx, nc, aux = _apply_block(
                    slot_params[f"s{i}"], kind, xx, positions, cfg=cfg,
                    cache=slot_caches[f"s{i}"], cache_index=cache_index,
                    shd=shd, shared=shared)
                out_caches[f"s{i}"] = nc
            return (xx, aux_sum + aux), out_caches

        if cfg.remat == "full":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        elif cfg.remat == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        (x, aux_total), scanned_caches = jax.lax.scan(
            body, (x, aux_total), (params["blocks"], block_caches))
        new_cache["blocks"] = scanned_caches if cache is not None else {}

    for i, kind in enumerate(cfg.suffix):
        c = cache["suffix"][i] if cache is not None else None
        x, nc, aux = _apply_block(params["suffix"][i], kind, x, positions,
                                  cfg=cfg, cache=c, cache_index=cache_index,
                                  shd=shd, shared=shared)
        new_cache["suffix"].append(nc)
        aux_total += aux

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps, cfg.norm_fp32)
    logits = lm_head(x, params["unembed"] if not cfg.tie_embeddings
                     else params["embed"], cfg.tie_embeddings,
                     cfg.final_logit_softcap, cfg.logits_fp32,
                     valid_vocab=cfg.vocab_size)
    if shd is not None:
        logits = shd.act(logits, "logits")
    return logits, (new_cache if cache is not None else None), aux_total


# ---------------------------------------------------------------------------
# Losses / steps
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Memory-lean CE: logsumexp - target logit (no full log_softmax)."""
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None],
                              axis=-1)[..., 0].astype(jnp.float32)
    nll = lse - tgt
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def lm_loss(params: Params, batch: dict, cfg: ModelConfig,
            shd=None) -> tuple[jax.Array, dict]:
    logits, _, aux = forward(params, batch["inputs"], cfg=cfg, shd=shd)
    ce = cross_entropy(logits, batch["targets"], batch.get("mask"))
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
            max_len: int, shd=None, cache_dtype=jnp.bfloat16):
    """Run the prompt through the model, returning (logits, cache)."""
    cache = init_model_cache(cfg, tokens.shape[0], max_len, cache_dtype)
    logits, cache, _ = forward(params, tokens, cfg=cfg, shd=shd, cache=cache,
                               cache_index=jnp.asarray(0, jnp.int32))
    return logits, cache


def decode_step(params: Params, cache: dict, token: jax.Array,
                index: jax.Array, cfg: ModelConfig, shd=None):
    """One autoregressive step.  token: [B, 1] -> (logits [B, 1, V], cache)."""
    logits, cache, _ = forward(params, token, cfg=cfg, shd=shd, cache=cache,
                               cache_index=index)
    return logits, cache


def greedy_generate(params: Params, prompt: jax.Array, steps: int,
                    cfg: ModelConfig, max_len: int | None = None):
    """Reference sampler for tests/examples (greedy)."""
    b, t = prompt.shape
    max_len = max_len or (t + steps)
    logits, cache = prefill(params, prompt, cfg, max_len)
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    out = [tok]
    idx = jnp.asarray(t, jnp.int32)
    for _ in range(steps - 1):
        logits, cache = decode_step(params, cache, tok, idx, cfg)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(tok)
        idx = idx + 1
    return jnp.concatenate(out, axis=1)
