"""Mixture-of-Experts layer: top-k router + capacity-bounded dispatch/combine.

Dispatch/combine are expressed as dense one-hot einsums (Shazeer-style) so
the SPMD partitioner turns them into all-to-alls when the expert dimension
is sharded over the ``model`` axis (EP).  Capacity bounds the dispatch
buffer: tokens beyond ``capacity`` per expert are dropped (their combine
weight is zero), which keeps the buffer shape static -- the MoE analogue
of CapStore's fixed-size accumulator sectors.

DeepSeek-style shared experts (always-on) run as a plain dense MLP in
parallel with the routed experts.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_linear


def init_moe_params(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    e = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    scale = (2.0 / (d + e.d_ff_expert)) ** 0.5
    p = {
        "router": init_linear(ks[0], d, e.num_experts, dtype),
        "experts_gate": scale * jax.random.normal(
            ks[1], (e.num_experts, d, e.d_ff_expert), dtype),
        "experts_up": scale * jax.random.normal(
            ks[2], (e.num_experts, d, e.d_ff_expert), dtype),
        "experts_down": scale * jax.random.normal(
            ks[3], (e.num_experts, e.d_ff_expert, d), dtype),
    }
    if e.num_shared_experts:
        f = e.d_ff_expert * e.num_shared_experts
        p["shared_gate_proj"] = init_linear(ks[4], d, f, dtype)
        p["shared_up_proj"] = init_linear(ks[5], d, f, dtype)
        p["shared_down_proj"] = init_linear(ks[6], f, d, dtype)
    return p


def capacity_for(tokens: int, cfg_moe) -> int:
    cap = math.ceil(tokens * cfg_moe.top_k / cfg_moe.num_experts
                    * cfg_moe.capacity_factor)
    return max(8, -(-cap // 8) * 8)   # round up to 8 for TPU tiling


def moe_forward(params: dict, x: jax.Array, *, cfg: ModelConfig,
                shd=None) -> tuple[jax.Array, jax.Array]:
    """x: [B, T, D] -> (out [B, T, D], aux_loss scalar).

    GROUPED dispatch (Switch/MaxText formulation): tokens compete for
    expert capacity within their batch row, so every dispatch/combine
    tensor carries the batch dim and stays sharded over data parallelism.
    The naive global formulation builds a [N_glob, K, E, C_glob] one-hot
    (terabytes at 1M tokens -- see EXPERIMENTS.md Perf iteration 1); this
    one peaks at [B, T, K, C_row].
    """
    e = cfg.moe
    b, t, d = x.shape

    logits = (x @ params["router"]).astype(jnp.float32)      # [B, T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    if shd is not None:
        probs = shd.act(probs, "bte")
    gate_vals, gate_idx = jax.lax.top_k(probs, e.top_k)      # [B, T, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # Load-balancing auxiliary loss (Switch-style), over all tokens.
    me = jnp.mean(probs, axis=(0, 1))                        # [E]
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e.num_experts),
                  axis=(0, 1))
    aux = e.num_experts * jnp.sum(me * ce) * e.aux_loss_weight

    cap = capacity_for(t, e)                                 # per row
    onehot = jax.nn.one_hot(gate_idx, e.num_experts,
                            dtype=jnp.int32)                 # [B, T, K, E]
    # Buffer position of each (t, k) inside its expert, per row.
    flat = onehot.reshape(b, t * e.top_k, e.num_experts)
    pos_all = jnp.cumsum(flat, axis=1) * flat - 1            # [B, T*K, E]
    pos_all = pos_all.reshape(b, t, e.top_k, e.num_experts)
    pos_sel = jnp.take_along_axis(
        pos_all, gate_idx[..., None], axis=-1)[..., 0]       # [B, T, K]
    within = (pos_sel >= 0) & (pos_sel < cap)
    sel = (onehot * within[..., None]).astype(x.dtype)       # [B, T, K, E]
    pos_oh = jax.nn.one_hot(jnp.clip(pos_sel, 0, cap - 1), cap,
                            dtype=x.dtype) * within[..., None]  # [B,T,K,C]

    # dispatch: [B, E, C, D]; the E dim is model-sharded -> all-to-all.
    expert_in = jnp.einsum("btke,btkc,btd->becd", sel, pos_oh, x)
    if shd is not None:
        expert_in = shd.act(expert_in, "becd")
    g = jnp.einsum("becd,edf->becf", expert_in, params["experts_gate"])
    u = jnp.einsum("becd,edf->becf", expert_in, params["experts_up"])
    h = jax.nn.silu(g) * u
    expert_out = jnp.einsum("becf,efd->becd", h, params["experts_down"])
    if shd is not None:
        expert_out = shd.act(expert_out, "becd")
    out = jnp.einsum("becd,btke,btkc,btk->btd", expert_out, sel, pos_oh,
                     gate_vals.astype(x.dtype))

    if e.num_shared_experts:
        sg = jax.nn.silu(x @ params["shared_gate_proj"])
        su = x @ params["shared_up_proj"]
        out = out + (sg * su) @ params["shared_down_proj"]
    return out, aux
