"""Attention: GQA/MQA/MHA, sliding-window, softcap, bidirectional, and MLA
(DeepSeek multi-head latent attention) -- with KV caches for decode.

Decode caches:
  * GQA:  {"k": [B, KvH, S, Dh], "v": [B, KvH, S, Dh]}
  * MLA:  {"c_kv": [B, S, R], "k_rope": [B, S, Rr]}  (compressed -- the
    paper's minimize-off-chip-traffic policy applied to the KV stream).

MLA decode uses the *absorbed* form: q is projected into the latent space
(q' = q_nope @ W_uk) so attention runs directly against the compressed
cache; values are combined in latent space and up-projected once.  Tests
verify absorbed-decode == explicit-prefill.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_linear, rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_attn_params(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if cfg.mla:
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        return {
            "q_proj": init_linear(ks[0], d, cfg.num_heads * qk, dtype),
            "kv_down": init_linear(ks[1], d, m.kv_lora_rank
                                   + m.qk_rope_head_dim, dtype),
            "kv_up": init_linear(ks[2], m.kv_lora_rank,
                                 cfg.num_heads * (m.qk_nope_head_dim
                                                  + m.v_head_dim), dtype),
            "o_proj": init_linear(ks[3], cfg.num_heads * m.v_head_dim, d,
                                  dtype),
        }
    return {
        "q_proj": init_linear(ks[0], d, cfg.num_heads * cfg.head_dim, dtype),
        "k_proj": init_linear(ks[1], d, cfg.num_kv_heads * cfg.head_dim,
                              dtype),
        "v_proj": init_linear(ks[2], d, cfg.num_kv_heads * cfg.head_dim,
                              dtype),
        "o_proj": init_linear(ks[3], cfg.num_heads * cfg.head_dim, d, dtype),
    }


# ---------------------------------------------------------------------------
# Cache plumbing: ``cache_index`` may be a scalar (all rows aligned) or a
# per-batch vector [B] (slot-based serving engine).
# ---------------------------------------------------------------------------

def _cache_update(buf: jax.Array, val: jax.Array, cache_index: jax.Array,
                  seq_axis: int = 1) -> jax.Array:
    """Insert ``val`` into ``buf`` at sequence position ``cache_index``
    (scalar or per-batch vector) along ``seq_axis``.  Caches are stored in
    attention layout ([B, S, ...]) so no transposes touch the full cache."""
    val = val.astype(buf.dtype)
    ci = jnp.asarray(cache_index)
    if ci.ndim == 0:
        start = tuple(ci if d == seq_axis else 0 for d in range(buf.ndim))
        return jax.lax.dynamic_update_slice(buf, val, start)
    def upd(b_row, v_row, i):
        start = tuple(i if d == seq_axis - 1 else 0
                      for d in range(b_row.ndim))
        return jax.lax.dynamic_update_slice(b_row, v_row, start)
    return jax.vmap(upd)(buf, val, ci)


def _cache_positions(cache_index: jax.Array, b: int, s: int,
                     t: int) -> jax.Array:
    """kv positions [B, S] with unwritten slots marked -1."""
    ci = jnp.asarray(cache_index)
    end = jnp.broadcast_to(jnp.atleast_1d(ci), (b,))
    idx = jnp.arange(s)[None, :]
    return jnp.where(idx <= end[:, None] + t - 1, idx, -1)


def query_positions(cache_index, b: int, t: int) -> jax.Array:
    ci = jnp.asarray(cache_index)
    base = jnp.atleast_1d(ci).reshape(-1, 1)
    return jnp.broadcast_to(base + jnp.arange(t)[None], (b, t))


# ---------------------------------------------------------------------------
# Masked grouped attention core (positions-based masking)
# ---------------------------------------------------------------------------

def _mask(q_pos, kv_pos, causal: bool, window: int | None):
    """q_pos: [B, T], kv_pos: [B, S] (< 0 marks invalid slots)."""
    m = (kv_pos >= 0)[:, None, :]
    if causal:
        m &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        m &= kv_pos[:, None, :] > q_pos[:, :, None] - window
    return m  # [B, T, S]


def grouped_attention(q, k, v, q_pos, kv_pos, *, causal, window, softcap,
                      scale, fp32_softmax: bool = True) -> jax.Array:
    """q: [B, T, H, Dh], k/v: [B, S, KvH, Dh] -> [B, T, H, Dh]."""
    b, t, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q5 = q.reshape(b, t, kvh, g, dh)
    logits = jnp.einsum("btkgd,bskd->bkgts", q5, k)
    if fp32_softmax:
        logits = logits.astype(jnp.float32)
    logits *= jnp.asarray(scale, logits.dtype)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    m = _mask(q_pos, kv_pos, causal, window)                # [B, T, S]
    neg = jnp.asarray(NEG_INF if fp32_softmax else -3e38, logits.dtype)
    logits = jnp.where(m[:, None, None], logits, neg)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v)
    return out.reshape(b, t, h, dh)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def gqa_forward(params: dict, x: jax.Array, positions: jax.Array, *,
                cfg: ModelConfig, window: int | None, cache: dict | None,
                cache_index: jax.Array | None, shd) -> tuple[jax.Array, dict | None]:
    b, t, d = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    scale = cfg.query_scale if cfg.query_scale is not None else dh ** -0.5

    q = (x @ params["q_proj"]).reshape(b, t, h, dh)
    k = (x @ params["k_proj"]).reshape(b, t, kvh, dh)
    v = (x @ params["v_proj"]).reshape(b, t, kvh, dh)
    if shd is not None:
        q = shd.act(q, "bthd")
        k = shd.act(k, "btkd")
        v = shd.act(v, "btkd")
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        kv_pos = positions
        ks, vs = k, v
        new_cache = None
    else:
        # cache layout == attention layout [B, S, KvH, Dh]: the update
        # writes one [B, T, KvH, Dh] slice and attention reads in place
        # (no full-cache transpose/copy per step -- see EXPERIMENTS.md
        # Perf hillclimb 3).
        s = cache["k"].shape[1]
        ks = _cache_update(cache["k"], k, cache_index)
        vs = _cache_update(cache["v"], v, cache_index)
        new_cache = {"k": ks, "v": vs}
        kv_pos = _cache_positions(cache_index, b, s, t)

    out = grouped_attention(q, ks.astype(q.dtype), vs.astype(q.dtype),
                            positions, kv_pos, causal=cfg.causal,
                            window=window, softcap=cfg.attn_logit_softcap,
                            scale=scale,
                            fp32_softmax=cfg.attn_fp32_softmax)
    if shd is not None:
        out = shd.act(out, "bthd")
    out = out.reshape(b, t, h * dh)
    if cfg.manual_tp and cache is None:
        from repro.models.layers import rs_proj
        return rs_proj(out, params["o_proj"], shd), new_cache
    return out @ params["o_proj"], new_cache


# ---------------------------------------------------------------------------
# MLA block
# ---------------------------------------------------------------------------

def _mla_split_up(params, cfg) -> tuple[jax.Array, jax.Array]:
    m = cfg.mla
    up = params["kv_up"].reshape(m.kv_lora_rank, cfg.num_heads,
                                 m.qk_nope_head_dim + m.v_head_dim)
    return up[..., :m.qk_nope_head_dim], up[..., m.qk_nope_head_dim:]


def mla_forward(params: dict, x: jax.Array, positions: jax.Array, *,
                cfg: ModelConfig, cache: dict | None,
                cache_index: jax.Array | None, shd) -> tuple[jax.Array, dict | None]:
    m = cfg.mla
    b, t, d = x.shape
    h = cfg.num_heads
    nope, rd, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    scale = (nope + rd) ** -0.5

    q = (x @ params["q_proj"]).reshape(b, t, h, nope + rd)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    down = x @ params["kv_down"]                        # [B, T, R + Rr]
    c_kv, k_rope = down[..., :m.kv_lora_rank], down[..., m.kv_lora_rank:]
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    w_uk, w_uv = _mla_split_up(params, cfg)             # [R, H, nope], [R, H, vd]

    if cache is None:
        # Explicit (prefill/train) form: up-project the whole sequence.
        k_nope = jnp.einsum("btr,rhn->bthn", c_kv, w_uk)
        v = jnp.einsum("btr,rhv->bthv", c_kv, w_uv)
        logits = (jnp.einsum("bthn,bshn->bhts", q_nope, k_nope)
                  + jnp.einsum("bthr,bsr->bhts", q_rope, k_rope))
        if cfg.attn_fp32_softmax:
            logits = logits.astype(jnp.float32)
        logits = logits * jnp.asarray(scale, logits.dtype)
        msk = _mask(positions, positions, cfg.causal, None)
        logits = jnp.where(msk[:, None],
                           logits, jnp.asarray(NEG_INF, logits.dtype)
                           if cfg.attn_fp32_softmax
                           else jnp.asarray(-3e38, logits.dtype))
        p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhts,bshv->bthv", p, v)
        new_cache = None
    else:
        # Absorbed (decode) form: attend in the compressed latent space.
        s = cache["c_kv"].shape[1]
        c_all = _cache_update(cache["c_kv"], c_kv, cache_index)
        r_all = _cache_update(cache["k_rope"], k_rope, cache_index)
        new_cache = {"c_kv": c_all, "k_rope": r_all}
        kv_pos = _cache_positions(cache_index, b, s, t)
        q_lat = jnp.einsum("bthn,rhn->bthr", q_nope, w_uk)   # [B,T,H,R]
        logits = (jnp.einsum("bthr,bsr->bhts", q_lat,
                             c_all.astype(q_lat.dtype))
                  + jnp.einsum("bthr,bsr->bhts", q_rope,
                               r_all.astype(q_rope.dtype)))
        if cfg.attn_fp32_softmax:
            logits = logits.astype(jnp.float32)
        logits = logits * jnp.asarray(scale, logits.dtype)
        msk = _mask(positions, kv_pos, cfg.causal, None)
        logits = jnp.where(msk[:, None],
                           logits, jnp.asarray(NEG_INF, logits.dtype)
                           if cfg.attn_fp32_softmax
                           else jnp.asarray(-3e38, logits.dtype))
        p = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhts,bsr->bthr", p, c_all.astype(p.dtype))
        out = jnp.einsum("bthr,rhv->bthv", ctx, w_uv)
    return out.reshape(b, t, h * vd) @ params["o_proj"], new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
               num_layers: int | None = None) -> dict:
    """Per-layer cache pytree (unstacked; the stack adds a leading dim)."""
    if cfg.mla:
        m = cfg.mla
        return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim),
                                    dtype)}
    # Attention layout [B, S, KvH, Dh] (NOT [B, KvH, S, Dh]) -- avoids a
    # full-cache transpose per decode step.
    return {"k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                           dtype),
            "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                           dtype)}
