"""Shared layers: RMSNorm, RoPE, gated MLP, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_lowp(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    """RMSNorm with fp32 statistics but a hand-written backward in which
    every FULL-SIZE tensor stays in the activation dtype (f32 appears only
    in the [..., 1] reductions).  This is what keeps the backward residual
    path -- and therefore the Megatron-TP all-reduces -- on bf16 wire
    (EXPERIMENTS.md Perf hillclimb 2)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * scale * (1.0 + weight.astype(x.dtype))


def _rmsnorm_lowp_fwd(x, weight, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    scale32 = jax.lax.rsqrt(var + eps)
    scale = scale32.astype(x.dtype)
    y = x * scale * (1.0 + weight.astype(x.dtype))
    return y, (x, weight, scale32)


def _rmsnorm_lowp_bwd(eps, res, dy):
    x, weight, scale32 = res
    scale = scale32.astype(x.dtype)
    w1 = (1.0 + weight.astype(x.dtype))
    dxhat = dy * w1                                          # bf16 full-size
    # tiny fp32 reduction: mean over the feature dim
    m = jnp.mean((dxhat * x).astype(jnp.float32), -1, keepdims=True)
    coef = (scale32 ** 3 * m).astype(x.dtype)                # [..., 1]
    dx = dxhat * scale - x * coef                            # bf16 full-size
    dw = jnp.sum((dy * x * scale).astype(jnp.float32),
                 axis=tuple(range(dy.ndim - 1)))             # [D] fp32
    return dx, dw.astype(weight.dtype)


_rmsnorm_lowp.defvjp(_rmsnorm_lowp_fwd, _rmsnorm_lowp_bwd)


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6,
            fp32: bool = True) -> jax.Array:
    """RMSNorm with fp32 statistics.

    ``fp32=True`` (paper-faithful default) also APPLIES the normalization
    in fp32; its cast-backward promotes every backward cotangent on the
    residual path to f32 -- doubling TP collective bytes (EXPERIMENTS.md
    Perf hillclimb 2).  ``fp32=False`` uses the custom-VJP low-precision
    variant (fp32 statistics, bf16 full-size tensors fwd AND bwd).
    """
    if fp32:
        dtype = x.dtype
        x32 = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        return ((x32 * jax.lax.rsqrt(var + eps))
                * (1.0 + weight.astype(jnp.float32))).astype(dtype)
    return _rmsnorm_lowp(x, weight, eps)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
         rope_dim: int | None = None) -> jax.Array:
    """Rotary embedding.  x: [B, T, H, D], positions: [B, T] (absolute)."""
    d = x.shape[-1] if rope_dim is None else rope_dim
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, T, half]
    # Cast cos/sin to the activation dtype BEFORE the multiply: keeps the
    # backward cotangents in bf16 instead of silently promoting to f32.
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    rot, rest = x[..., :d], x[..., d:]
    x1, x2 = rot[..., :half], rot[..., half:]
    rotated = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated, rest], axis=-1) if rest.size else rotated


def gated_mlp(x: jax.Array, gate_w: jax.Array, up_w: jax.Array,
              down_w: jax.Array, act: str = "silu", shd=None,
              manual_tp: bool = False) -> jax.Array:
    g = x @ gate_w
    u = x @ up_w
    if act == "gelu":
        h = jax.nn.gelu(g, approximate=True) * u
    else:
        h = jax.nn.silu(g) * u
    if shd is not None:
        h = shd.act(h, "btf")
    if manual_tp:
        return rs_proj(h, down_w, shd)
    return h @ down_w


# ---------------------------------------------------------------------------
# Manual Megatron-SP collectives (shard_map): the XLA:CPU partitioner emits
# all-reduce(+slice) where reduce-scatter suffices; these express the SP
# transitions explicitly, halving TP wire bytes (EXPERIMENTS.md Perf
# hillclimb 2).
# ---------------------------------------------------------------------------

def _tp_size(shd) -> int:
    return shd.mesh.shape[shd.rules.tp]


def rs_proj(x: jax.Array, w: jax.Array, shd) -> jax.Array:
    """Row-parallel projection with an explicit reduce-scatter over the
    sequence dim: x [B, T, F] (F model-sharded) @ w [F, D] -> [B, T, D]
    sequence-sharded over the model axis."""
    if shd is None or shd.mesh is None or x.shape[1] % _tp_size(shd):
        return x @ w
    from jax.sharding import PartitionSpec as P
    dp, tp = shd.rules.dp, shd.rules.tp

    def f(xl, wl):
        return jax.lax.psum_scatter(xl @ wl, tp, scatter_dimension=1,
                                    tiled=True)

    from repro.parallel.compat import shard_map
    return shard_map(f, mesh=shd.mesh,
                     in_specs=(P(dp, None, tp), P(tp, None)),
                     out_specs=P(dp, tp, None))(x, w)


def ag_seq(x: jax.Array, shd) -> jax.Array:
    """All-gather the sequence-sharded residual (the SP->TP transition)."""
    if shd is None or shd.mesh is None or x.shape[1] % _tp_size(shd):
        return x
    from jax.sharding import PartitionSpec as P
    dp, tp = shd.rules.dp, shd.rules.tp

    def f(xl):
        return jax.lax.all_gather(xl, tp, axis=1, tiled=True)

    from repro.parallel.compat import shard_map
    return shard_map(f, mesh=shd.mesh,
                     in_specs=P(dp, tp, None),
                     out_specs=P(dp, None, None))(x)


def embed_tokens(embed: jax.Array, tokens: jax.Array,
                 scale: bool, d_model: int) -> jax.Array:
    x = jnp.take(embed, tokens, axis=0)
    if scale:
        x = x * jnp.asarray(d_model ** 0.5, x.dtype)
    return x


def lm_head(x: jax.Array, embed_or_unembed: jax.Array, tied: bool,
            softcap: float | None, fp32: bool = True,
            valid_vocab: int | None = None) -> jax.Array:
    w = embed_or_unembed.T if tied else embed_or_unembed
    logits = x @ w.astype(x.dtype)
    if fp32:
        logits = logits.astype(jnp.float32)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        # Mask Megatron-style vocab padding columns.
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < valid_vocab, logits, -1e30)
    return logits


def init_linear(key: jax.Array, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return scale * jax.random.normal(key, (d_in, d_out), dtype)
