from repro.models.config import (  # noqa: F401
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    count_params,
)
from repro.models.transformer import (  # noqa: F401
    decode_step,
    forward,
    greedy_generate,
    init_model,
    init_model_cache,
    lm_loss,
    prefill,
)
