"""Serve CapsuleNet classifications through the plan-driven batched engine.

Compiles ONE ExecutionPlan for the configured CapsNet, prints its
per-operation schedule (block shapes, VMEM footprints, PMU phases), then
streams MNIST-like requests through the slot-based ``CapsuleEngine`` and
reports per-request latency and throughput.

    PYTHONPATH=src python examples/serve_capsnet.py [--backend pallas]
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import capsnet  # noqa: E402
from repro.core.energy import SRAMConfig  # noqa: E402
from repro.core.execplan import compile_plan  # noqa: E402
from repro.core.pmu import schedule_from_plan  # noqa: E402
from repro.serve.capsule import CapsRequest, CapsuleEngine  # noqa: E402
from repro.train.data import DataConfig, mnist_batch  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    args = ap.parse_args()

    cfg = capsnet.CapsNetConfig(image_hw=14, conv1_channels=16,
                                conv1_kernel=5, pc_kernel=3,
                                num_primary_groups=4, primary_dim=4,
                                class_dim=8, use_decoder=False)
    params = capsnet.init_params(jax.random.PRNGKey(0), cfg)
    # pipeline=True: PrimaryCaps -> ClassCaps served as ONE fused
    # kernel when the pair fits VMEM (per-op plan otherwise).
    plan = compile_plan(cfg, batch=args.slots, pipeline=True)

    print("== ExecutionPlan (one schedule: kernels + PMU + serving) ==")
    print(f"{'op':14s} {'kernel':18s} {'block':>18s} {'vmem KiB':>9s} "
          f"{'phase KiB':>10s}")
    for r in plan.summary():
        print(f"{r['name']:14s} {r['kernel']:18s} {str(r['block']):>18s} "
              f"{r['vmem_kib']:9.1f} {r['req_kib']:10.1f}")

    mem = SRAMConfig("shared", 1 << 20, power_gated=True, sectors_per_bank=64)
    sched = schedule_from_plan(mem, plan)
    print("\n== PMU gating schedule derived from the SAME plan ==")
    for ph in sched.phases:
        print(f"{ph.name:14s} on={ph.on_fraction:5.1%} "
              f"woken={ph.sectors_woken:3d} leak={ph.leakage_mj:.4f} mJ")

    engine = CapsuleEngine(params, cfg, slots=args.slots,
                           backend=args.backend, plan=plan)
    dc = DataConfig(kind="mnist", global_batch=args.requests)
    batch = mnist_batch(dc, 0, image_hw=cfg.image_hw)
    images = np.asarray(batch["images"])
    for i in range(args.requests):
        engine.submit(CapsRequest(rid=i, image=images[i % images.shape[0]]))
    done = engine.run()
    s = engine.stats()

    print(f"\n== served {s['requests']} requests "
          f"({args.backend} backend, {args.slots} slots) ==")
    for r in done[:8]:
        print(f"req {r.rid:3d}: pred={r.pred} "
              f"latency={1e3 * r.latency_s:7.2f} ms "
              f"queued {r.queue_ticks} ticks")
    print(f"throughput {s['requests_per_s']:8.1f} req/s   "
          f"occupancy {s['occupancy']:.2f}   "
          f"mean latency {s['mean_latency_ms']:.2f} ms")


if __name__ == "__main__":
    main()
