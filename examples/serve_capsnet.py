"""Serve CapsuleNet classifications through the plan-driven batched engine.

Compiles ONE ExecutionPlan for the configured CapsNet, prints its
per-operation schedule (block shapes, VMEM footprints, PMU phases), then
streams MNIST-like requests through the slot-based ``CapsuleEngine`` and
reports per-request latency and throughput.

    PYTHONPATH=src python examples/serve_capsnet.py [--backend pallas]

``--shards N`` shards the slot batch over an N-device mesh (ONE
compile_plan producing the per-shard plan, ``slots = n_shards *
plan.batch``); on a CPU-only machine force virtual devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/serve_capsnet.py --shards 4

``--use-async`` drives the same engine through ``AsyncCapsuleServer``:
requests are submitted concurrently from asyncio tasks and each awaits
its own terminal status while the driver recycles slots continuously.
"""

import argparse
import asyncio
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import capsnet  # noqa: E402
from repro.core.energy import SRAMConfig  # noqa: E402
from repro.core.execplan import compile_plan  # noqa: E402
from repro.core.pmu import schedule_from_plan  # noqa: E402
from repro.serve.capsule import (AsyncCapsuleServer, CapsRequest,  # noqa: E402
                                 CapsuleEngine)
from repro.train.data import DataConfig, mnist_batch  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--shards", type=int, default=None,
                    help="shard the slot batch over this many devices "
                         "(slots must divide; needs that many visible "
                         "devices)")
    ap.add_argument("--use-async", action="store_true",
                    help="submit through the asyncio host loop")
    args = ap.parse_args()

    cfg = capsnet.CapsNetConfig(image_hw=14, conv1_channels=16,
                                conv1_kernel=5, pc_kernel=3,
                                num_primary_groups=4, primary_dim=4,
                                class_dim=8, use_decoder=False)
    params = capsnet.init_params(jax.random.PRNGKey(0), cfg)
    # pipeline=True: PrimaryCaps -> ClassCaps served as ONE fused
    # kernel when the pair fits VMEM (per-op plan otherwise).  Sharded,
    # the plan is compiled for the PER-SHARD batch: slots = shards *
    # plan.batch, and each shard runs the same schedule.
    per_shard = args.slots // (args.shards or 1)
    plan = compile_plan(cfg, batch=per_shard, pipeline=True)

    print("== ExecutionPlan (one schedule: kernels + PMU + serving) ==")
    print(f"{'op':14s} {'kernel':18s} {'block':>18s} {'vmem KiB':>9s} "
          f"{'phase KiB':>10s}")
    for r in plan.summary():
        print(f"{r['name']:14s} {r['kernel']:18s} {str(r['block']):>18s} "
              f"{r['vmem_kib']:9.1f} {r['req_kib']:10.1f}")

    mem = SRAMConfig("shared", 1 << 20, power_gated=True, sectors_per_bank=64)
    sched = schedule_from_plan(mem, plan)
    print("\n== PMU gating schedule derived from the SAME plan ==")
    for ph in sched.phases:
        print(f"{ph.name:14s} on={ph.on_fraction:5.1%} "
              f"woken={ph.sectors_woken:3d} leak={ph.leakage_mj:.4f} mJ")

    engine = CapsuleEngine(params, cfg, slots=args.slots,
                           backend=args.backend, plan=plan,
                           n_shards=args.shards)
    dc = DataConfig(kind="mnist", global_batch=args.requests)
    batch = mnist_batch(dc, 0, image_hw=cfg.image_hw)
    images = np.asarray(batch["images"])
    if args.use_async:
        async def serve_async():
            async with AsyncCapsuleServer(engine) as server:
                return await asyncio.gather(
                    *(server.submit(images[i % images.shape[0]])
                      for i in range(args.requests)))

        done = asyncio.run(serve_async())
    else:
        for i in range(args.requests):
            engine.submit(CapsRequest(rid=i,
                                      image=images[i % images.shape[0]]))
        done = engine.run()
    s = engine.stats()

    mesh_note = (f", {engine.n_shards} shards x {engine.slots_per_shard} "
                 f"slots/shard" if args.shards else "")
    print(f"\n== served {s['requests']} requests "
          f"({args.backend} backend, {args.slots} slots{mesh_note}"
          f"{', async' if args.use_async else ''}) ==")
    for r in done[:8]:
        print(f"req {r.rid:3d}: pred={r.pred} "
              f"latency={1e3 * r.latency_s:7.2f} ms "
              f"queued {r.queue_ticks} ticks")
    print(f"throughput {s['requests_per_s']:8.1f} req/s   "
          f"occupancy {s['occupancy']:.2f}   "
          f"mean latency {s['mean_latency_ms']:.2f} ms")


if __name__ == "__main__":
    main()
