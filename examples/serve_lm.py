"""Batched serving demo (deliverable b): continuous batching with mixed
prompt lengths, slot refill and EOS handling, on a smoke-size model.

    PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.models import init_model  # noqa: E402
from repro.serve import Request, ServeEngine  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b",
                    help="smoke config of this arch serves the demo")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, slots=args.slots, max_len=128)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(3, 24))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        engine.submit(Request(rid=i, prompt=prompt,
                              max_new_tokens=args.max_new))

    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s) using {engine.ticks} engine ticks "
          f"on {args.slots} slots")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.output}")


if __name__ == "__main__":
    main()
