"""CapStore design-space exploration, end to end (paper Secs. 4-5 + the
TPU planner adaptation of DESIGN.md Sec. 2):

  * evaluates all six on-chip organizations (Table 2 / Fig. 10),
  * sweeps sector counts for the power-gated variants,
  * prints the complete-accelerator breakdown (Fig. 11),
  * runs the SAME energy-objective DSE over Pallas block shapes for the
    CapsuleNet and LM hot-spot matmuls (the TPU adaptation).

    PYTHONPATH=src python examples/capstore_dse.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import dse  # noqa: E402
from repro.core.capsnet import CapsNetConfig  # noqa: E402
from repro.core.execplan import compile_plan  # noqa: E402
from repro.core.planner import (CAPSNET_WORKLOADS, MatmulWorkload,  # noqa: E402
                                arithmetic_intensity, plan_matmul)


def main() -> None:
    # ONE ExecutionPlan: the schedule below is what the Pallas kernels run.
    plan = compile_plan(CapsNetConfig())
    profiles = list(plan.profiles)
    orgs = dse.design_organizations(profiles)

    print("== ASIC organizations (paper Table 2) ==")
    print(f"{'org':8s} {'bytes':>8s} {'area mm2':>9s} {'dyn mJ':>8s} "
          f"{'stat mJ':>8s} {'total mJ':>9s}")
    for name in ("SMP", "PG-SMP", "SEP", "PG-SEP", "HY", "PG-HY"):
        ev = dse.evaluate(orgs[name], profiles)
        print(f"{name:8s} {ev.org.total_bytes:8.0f} {ev.area_mm2:9.3f} "
              f"{ev.dynamic_mj:8.4f} {ev.static_mj:8.4f} {ev.total_mj:9.4f}")

    print("\n== sector sweep (power-gated orgs) ==")
    for r in dse.explore(profiles)[:6]:
        print(f"{r.org_name:8s} S={r.sectors:4d} {r.total_mj:8.4f} mJ")

    best = dse.best_design(profiles)
    a = dse.all_onchip_system(profiles)
    c = dse.hierarchy_system(profiles, best.evaluation)
    print(f"\n== complete accelerator with {best.org_name} (Fig. 11) ==")
    print(f"accelerator {c.accelerator_mj:7.3f} mJ")
    print(f"buffers     {c.buffers_mj:7.3f} mJ")
    print(f"on-chip mem {c.onchip_mj:7.3f} mJ")
    print(f"off-chip    {c.offchip_mj:7.3f} mJ")
    print(f"total       {c.total_mj:7.3f} mJ "
          f"(-{1 - c.total_mj/a.total_mj:.0%} vs all-on-chip [11])")

    print("\n== TPU planner: same DSE over Pallas BlockSpecs ==")
    lm = [("gemma2-mlp", MatmulWorkload(m=4096, k=3584, n=14336)),
          ("vocab-head", MatmulWorkload(m=4096, k=3584, n=256128))]
    for name, w in CAPSNET_WORKLOADS + lm:
        p = plan_matmul(w)
        print(f"{name:20s} block {p.block_m:5d}x{p.block_k:5d}x{p.block_n:5d}"
              f"  VMEM {p.vmem_total/2**20:5.2f} MiB"
              f"  gated {p.gated_fraction:5.1%}"
              f"  AI {arithmetic_intensity(p, w):7.1f} flops/B")


if __name__ == "__main__":
    main()
