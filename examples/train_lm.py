"""End-to-end LM training driver (deliverable b): the full production
loop -- deterministic data, AdamW, async atomic checkpoints, NaN guard,
heartbeat, resume -- on a ~100M-param model (or a tiny preset for CI).

    # tiny preset (seconds on CPU):
    PYTHONPATH=src python examples/train_lm.py --steps 30

    # ~100M params, a few hundred steps (the deliverable run):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

    # kill it at any point, then resume exactly:
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.models.config import ModelConfig, count_params  # noqa: E402
from repro.train.data import DataConfig  # noqa: E402
from repro.train.loop import LoopConfig, TrainLoop  # noqa: E402
from repro.train.optimizer import OptConfig  # noqa: E402

PRESETS = {
    "tiny": ModelConfig(
        name="lm-tiny", family="dense", d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=2048,
        pattern=("global",), repeats=4, remat="none"),
    "100m": ModelConfig(
        name="lm-100m", family="dense", d_model=768, num_heads=12,
        num_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32000,
        pattern=("local", "global"), repeats=6, sliding_window=512,
        remat="none"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"model {cfg.name}: {count_params(cfg)/1e6:.1f}M params, "
          f"{cfg.num_layers} layers")
    data = DataConfig(kind="lm", vocab_size=cfg.vocab_size,
                      seq_len=args.seq, global_batch=args.batch)
    loop = TrainLoop(
        cfg,
        OptConfig(peak_lr=3e-4, warmup_steps=20, decay_steps=args.steps),
        data,
        LoopConfig(total_steps=args.steps, ckpt_every=max(args.steps // 4, 10),
                   ckpt_dir=f"{args.ckpt_dir}/{args.preset}", log_every=5,
                   heartbeat_path=f"{args.ckpt_dir}/{args.preset}/hb.json"))
    loop.install_signal_handler()
    hist = loop.run(resume=not args.no_resume)
    if hist:
        first = sum(h["loss"] for h in hist[:5]) / min(len(hist), 5)
        last = sum(h["loss"] for h in hist[-5:]) / min(len(hist), 5)
        print(f"\nloss {first:.3f} -> {last:.3f} over {len(hist)} steps "
              f"({'improved' if last < first else 'check config'})")


if __name__ == "__main__":
    main()
