"""Quickstart: the paper end-to-end in ~a minute on CPU.

1. Train the CapsuleNet (Sabour et al. 2017) on synthetic MNIST digits.
2. Profile its inference on the CapsAcc 16x16 array (paper Fig. 4).
3. Run the CapStore DSE and report the selected memory design (Table 2).

    PYTHONPATH=src python examples/quickstart.py [--steps 60]
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.core import analysis, capsnet, dse  # noqa: E402
from repro.train.data import DataConfig, mnist_batch  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    # 1. train a reduced CapsuleNet on synthetic digits -------------------
    cfg = get_smoke_config("capsnet-mnist")
    params = capsnet.init_params(jax.random.PRNGKey(0), cfg)
    dc = DataConfig(kind="mnist", global_batch=args.batch)
    print(f"== training CapsuleNet ({cfg.num_primary} primary capsules) ==")
    for step in range(args.steps):
        b = mnist_batch(dc, step, image_hw=cfg.image_hw)
        params, m = capsnet.train_step(params, b["images"], b["labels"],
                                       cfg, lr=3e-2)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"acc {float(m['accuracy']):.2f}")

    # 2. memory analysis of the full-size CapsuleNet (paper Fig. 4) -------
    print("\n== CapsAcc memory analysis (full MNIST CapsuleNet) ==")
    profiles = analysis.capsnet_profiles()
    for p in profiles:
        print(f"{p.name:14s} mem {p.total_mem/1024:7.1f} KiB  "
              f"cycles {p.total_cycles:9.0f}  offchip "
              f"{(p.offchip_reads + p.offchip_writes)*p.repeats:9.0f}")

    # 3. CapStore DSE (paper Table 2) --------------------------------------
    print("\n== CapStore design space exploration ==")
    results = dse.explore(profiles)
    for r in results[:4]:
        print(f"{r.org_name:7s} S={r.sectors:4d}  {r.total_mj:7.4f} mJ  "
              f"{r.area_mm2:7.2f} mm^2")
    best = results[0]
    print(f"\nselected design: {best.org_name} with {best.sectors} "
          f"sectors/bank (paper selects PG-SEP)")


if __name__ == "__main__":
    main()
